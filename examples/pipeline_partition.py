"""The paper's technique on the multi-pod mesh: pick the DNN partition point
with the paper's bisection (fed TPU per-layer costs instead of WiFi rates)
and run the two-stage GPipe split over the 'pod' axis.

Needs >= 2 local devices; run under
    XLA_FLAGS=--xla_force_host_platform_device_count=2 \
        PYTHONPATH=src python examples/pipeline_partition.py
"""
import os

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"

import jax                                              # noqa: E402
import numpy as np                                      # noqa: E402

from repro import configs as cfg_lib                    # noqa: E402
from repro.core import costmodel as cm                  # noqa: E402
from repro.launch.pipeline import (build_demo, choose_cut,  # noqa: E402
                                   reference_forward)

# 1. partition point from the paper's bisection on arch layer costs
cfg = cfg_lib.get_config("jamba-v0.1-52b")
layers = cm.arch_layers(cfg, seq=4096)
costs = cm.flops_vector(layers)
mem = cm.mem_vector(layers, batch=1)
cut = choose_cut(costs, mem, hbm_per_pod=256 * 16e9)
print(f"jamba-v0.1-52b: {len(layers)} cost-model layers, "
      f"cut at {cut.cut} -> stages of {cut.stage_layers} layers")
hetero = np.array([c.flops() for c in layers])
print(f"  (hybrid per-layer costs span {hetero.min():.2e}..{hetero.max():.2e} "
      "FLOPs/token — the non-uniform cut is doing real work)")

# 2. run the actual 2-stage GPipe split on this host's devices
mesh = jax.make_mesh((2,), ("pod",))
params, x, y = build_demo(mesh, n_layers=8, width=256, batch=16, n_micro=4)
ref = reference_forward(params, x)
err = float(jax.numpy.max(jax.numpy.abs(y - ref)))
print(f"GPipe over pod axis matches unpipelined forward: max err {err:.2e}")
