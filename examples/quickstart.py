"""Quickstart: the paper's pipeline in 60 lines.

1. Build the Table II layer-level cost model for VGG-11.
2. Derive each shop floor's participation rate from the divergence bound.
3. Stream a few DDSRA-scheduled FL rounds with real split training through
   the composable simulation API (Scenario -> Simulation -> rounds()).

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import costmodel as cm
from repro.fl import Scenario, Simulation

# 1. layer-level cost model ---------------------------------------------------
layers = cm.vgg11_layers(width_mult=0.25)
flops = cm.flops_vector(layers)
mem = cm.mem_vector(layers, batch=50)
print(f"VGG-11: {len(layers)} layers, "
      f"{flops.sum():.2e} FLOPs/sample (fwd+bwd), "
      f"model size {cm.model_size_bytes(layers)/1e6:.1f} MB")
print(f"  heaviest layer: {layers[int(np.argmax(flops))].name}")

# 2. scenario -> simulation ---------------------------------------------------
scenario = Scenario(model="mlp", rounds=10, eval_every=5, v=0.01, seed=0)
sim = Simulation(scenario)
print("\nDerived participation rates (Eq. 13):", np.round(sim.gamma, 2))
print("  (gateway 0 holds the widest class variety -> highest rate)")

# 3. stream the round loop ----------------------------------------------------
records = []
for rec in sim.rounds("ddsra"):
    records.append(rec)
    if rec.accuracy is not None:
        print(f"  round {rec.t + 1:2d}: accuracy {rec.accuracy:.3f}  "
              f"delay so far {rec.cum_delay:.1f}s")
result = sim.result_of(records)

print(f"\nAfter {scenario.rounds} rounds:")
print(f"  test accuracy {result.accuracy[-1]:.3f}")
print(f"  cumulative delay {result.cum_delay[-1]:.1f}s "
      f"({result.failures} resource failures)")
print(f"  participation rates {np.round(result.participation.mean(0), 2)}")
print(f"  targets             {np.round(result.gamma_targets, 2)}")
