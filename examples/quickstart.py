"""Quickstart: the paper's pipeline in 60 lines.

1. Build the Table II layer-level cost model for VGG-11.
2. Derive each shop floor's participation rate from the divergence bound.
3. Run a few DDSRA-scheduled FL rounds with real split training.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import costmodel as cm
from repro.core.participation import participation_rates
from repro.fl import FLConfig, FLTrainer

# 1. layer-level cost model ---------------------------------------------------
layers = cm.vgg11_layers(width_mult=0.25)
flops = cm.flops_vector(layers)
mem = cm.mem_vector(layers, batch=50)
print(f"VGG-11: {len(layers)} layers, "
      f"{flops.sum():.2e} FLOPs/sample (fwd+bwd), "
      f"model size {cm.model_size_bytes(layers)/1e6:.1f} MB")
print(f"  heaviest layer: {layers[int(np.argmax(flops))].name}")

# 2+3. FL with DDSRA scheduling ----------------------------------------------
cfg = FLConfig(model="mlp", rounds=10, eval_every=5, v=0.01, seed=0)
trainer = FLTrainer(cfg)
print("\nDerived participation rates (Eq. 13):",
      np.round(trainer.gamma, 2))
print("  (gateway 0 holds the widest class variety -> highest rate)")

result = trainer.run("ddsra")
print(f"\nAfter {cfg.rounds} rounds:")
print(f"  test accuracy {result.accuracy[-1]:.3f}")
print(f"  cumulative delay {result.cum_delay[-1]:.1f}s "
      f"({result.failures} resource failures)")
print(f"  participation rates {np.round(result.participation.mean(0), 2)}")
print(f"  targets             {np.round(result.gamma_targets, 2)}")
