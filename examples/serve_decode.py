"""Serve a small model with batched requests: KV-cache decode for a dense
arch and recurrent-state decode for the SSM arch, via the same serve_step.

    PYTHONPATH=src python examples/serve_decode.py
"""
from repro.launch.serve import serve

for arch in ("deepseek-7b", "mamba2-2.7b", "jamba-v0.1-52b"):
    serve(arch, smoke=True, batch=4, prompt_len=16, gen=16)
