"""End-to-end driver: DDSRA-scheduled federated training of VGG-11 on
synthetic non-IID data, comparing against a baseline scheduler — the
paper's headline experiment (Figs. 4-5) at reduced scale.

Each scheduler runs from ``Simulation.reset()``: identical model init,
batch draws and channel-state sequence, so the comparison is fair.

    PYTHONPATH=src python examples/fl_split_training.py [--rounds 40] [--vgg]
"""
import argparse

import numpy as np

from repro.fl import Scenario, Simulation


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--vgg", action="store_true",
                    help="use VGG-11 (slower) instead of the MLP")
    ap.add_argument("--v", type=float, default=0.01,
                    help="Lyapunov trade-off parameter V")
    args = ap.parse_args()

    sim = Simulation(Scenario(model="vgg" if args.vgg else "mlp",
                              width_mult=0.125, rounds=args.rounds, v=args.v,
                              eval_every=max(args.rounds // 6, 1), seed=0))
    print(f"participation targets: {np.round(sim.gamma, 2)}")
    for sched in ("ddsra", "round_robin"):
        sim.reset()
        res = sim.run(sched)
        print(f"\n[{sched}]")
        for r, a in zip(res.acc_rounds, res.accuracy):
            print(f"  round {r:3d}: accuracy {a:.3f}")
        print(f"  cumulative delay {res.cum_delay[-1]:.1f}s, "
              f"failures {res.failures}")


if __name__ == "__main__":
    main()
