"""Regenerate the §Dry-run / §Roofline / §Perf sections of EXPERIMENTS.md
from artifacts/{dryrun,hillclimb}. Idempotent; keyed on HTML markers."""
from __future__ import annotations

import json
import pathlib
import re

ROOT = pathlib.Path(__file__).resolve().parent.parent
ART = ROOT / "artifacts"


def dryrun_rows():
    return [json.loads(f.read_text()) for f in sorted((ART / "dryrun").glob("*.json"))]


def roofline_detail(rows) -> str:
    from benchmarks.roofline_report import to_markdown
    one = [r for r in rows if r["mesh"] == "16x16"]
    lines = [to_markdown(rows, "16x16"), ""]
    worst = sorted(one, key=lambda r: r["useful_flops_frac"])[:3]
    coll = sorted(one, key=lambda r: (r["roofline"]["t_collective_s"]
                                      / max(r["roofline"]["t_compute_s"], 1e-12)),
                  reverse=True)[:3]
    lines.append("**Per-row one-liners (what would move the dominant term):**")
    for r in one:
        ro = r["roofline"]
        b = ro["bottleneck"]
        hint = {
            "memory": "cut operand traffic: fewer remat re-reads / bf16 "
                      "grad accumulation / larger fused blocks",
            "collective": "re-shard the hot tensor (see §Perf), batch weight "
                          "gathers across microbatches, or drop FSDP for small weights",
            "compute": "already compute-bound: kernel-level tiling is the next lever",
        }[b]
        lines.append(f"- `{r['arch']} x {r['shape']}`: {b}-bound "
                     f"(t={max(ro['t_compute_s'], ro['t_memory_s'], ro['t_collective_s']):.2e}s); {hint}.")
    lines.append("")
    lines.append(f"Most collective-dominated: "
                 f"{', '.join(r['arch'] + ' x ' + r['shape'] for r in coll)}. "
                 f"Lowest useful-FLOPs fraction: "
                 f"{', '.join(r['arch'] + ' x ' + r['shape'] for r in worst)} "
                 "(decode shapes: one token's FLOPs vs full cache traffic — "
                 "inherently bandwidth-dominated, as expected).")
    return "\n".join(lines)


def dryrun_summary(rows) -> str:
    one = [r for r in rows if r["mesh"] == "16x16"]
    two = [r for r in rows if r["mesh"] == "2x16x16"]
    fit1 = sum(r["memory"]["peak_bytes"] <= 16 * 2**30 for r in one)
    lines = [
        f"* {len(one)}/40 (arch x shape) combinations **lower + compile** on the "
        f"single-pod 16x16 mesh; {len(two)}/40 on the 2x16x16 multi-pod mesh "
        "(512 placeholder devices). Zero failures.",
        f"* {fit1}/40 single-pod cases fit the 16 GiB/device HBM budget at "
        "baseline shardings; the over-budget ones (large-model train_4k, "
        "decode with replicated-dim fallbacks) are exactly the §Perf targets "
        "— see the hillclimb deltas there.",
        "* Collective schedules (per compiled HLO): weight all-gathers (FSDP), "
        "gradient all-reduce/reduce-scatter, logits all-reduce over the vocab "
        "contraction, MoE dispatch all-gathers, and for long_500k the "
        "context-parallel softmax all-reduces.",
    ]
    return "\n".join(lines)


def perf_section() -> str:
    files = sorted((ART / "hillclimb").glob("*.json"))
    if not files:
        return "(hillclimb artifacts pending)"
    out = []
    for f in files:
        log = json.loads(f.read_text())
        iters = [i for i in log["iterations"] if "error" not in i]
        if not iters:
            continue
        base = next(i for i in iters if i["variant"] == "baseline")
        dom = base["bottleneck"]
        key = f"t_{dom}_s" if dom != "compute" else "t_compute_s"
        best = min(iters, key=lambda i: max(i["t_compute_s"], i["t_memory_s"],
                                            i["t_collective_s"]))
        out.append(f"### {log['arch']} × {log['shape']} (mesh {log['mesh']})\n")
        out.append(f"Baseline bottleneck: **{dom}** "
                   f"({base[key]:.3e}s). Iterations:\n")
        out.append("| variant | hypothesis (abridged) | t_comp | t_mem | t_coll "
                   "| HBM temp (GiB) | verdict |")
        out.append("|---|---|---|---|---|---|---|")
        for i in log["iterations"]:
            if "error" in i:
                out.append(f"| {i['variant']} | {i['hypothesis'][:60]}... | — | — "
                           f"| — | — | failed: {i['error'][:40]} |")
                continue
            dom_t = i[key]
            verdict = ("baseline" if i["variant"] == "baseline" else
                       ("confirmed" if dom_t < base[key] * 0.95 else
                        ("refuted" if dom_t > base[key] * 1.05 else "neutral")))
            out.append(
                f"| {i['variant']} | {i['hypothesis'][:60]}... "
                f"| {i['t_compute_s']:.2e} | {i['t_memory_s']:.2e} "
                f"| {i['t_collective_s']:.2e} | {i['temp_gib']:.1f} | {verdict} |")
        step_base = max(base["t_compute_s"], base["t_memory_s"],
                        base["t_collective_s"])
        step_best = max(best["t_compute_s"], best["t_memory_s"],
                        best["t_collective_s"])
        out.append(
            f"\n**Best variant: `{best['variant']}`** — dominant-term step time "
            f"{step_base:.3e}s → {step_best:.3e}s "
            f"({step_base / max(step_best, 1e-12):.1f}× better), now "
            f"{best['bottleneck']}-bound.\n")
    return "\n".join(out)


def splice(text: str, marker: str, payload: str) -> str:
    pat = re.compile(re.escape(f"<!-- {marker} -->") + r".*?(?=\n## |\Z)",
                     re.DOTALL)
    return pat.sub(f"<!-- {marker} -->\n\n{payload}\n\n", text)


def main(fast: bool = True):
    rows = dryrun_rows()
    exp = (ROOT / "EXPERIMENTS.md").read_text()
    exp = splice(exp, "ROOFLINE_TABLE", dryrun_summary(rows))
    exp = splice(exp, "ROOFLINE_DETAIL", roofline_detail(rows))
    exp = splice(exp, "PERF_SECTION", perf_section())
    (ROOT / "EXPERIMENTS.md").write_text(exp)
    print(f"EXPERIMENTS.md updated: {len(rows)} dryrun rows, "
          f"{len(list((ART / 'hillclimb').glob('*.json')))} hillclimb logs")


if __name__ == "__main__":
    main()
