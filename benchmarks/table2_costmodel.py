"""Paper Table II: the layer-level FLOPs model vs XLA's measured cost.

For each VGG-11 layer we jit the isolated forward (and backward) and compare
``cost_analysis()['flops']`` against the closed-form o_l / o_l'. Claim:
the conv/fc forward formulas match XLA within ~2x (the table's intent is
relative sizing for the partition optimizer, not ns-level accuracy).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, save_json, timed
from repro.core import costmodel as cm
from repro.models import vgg


def run(width_mult: float = 0.5, batch: int = 16):
    plan, params = vgg.init_vgg11(jax.random.PRNGKey(0), width_mult)
    layers = cm.vgg11_layers(width_mult)
    x = jnp.zeros((batch, 32, 32, 3))
    rows = []
    for i, (kind, lc) in enumerate(zip(plan, layers)):
        fwd = jax.jit(lambda p, xx, i=i: vgg.forward_range(plan, p, xx, i, i + 1))
        compiled = fwd.lower(params, x).compile()
        ca = compiled.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0]
        measured = float(ca.get("flops", 0.0))
        predicted = lc.flops_fwd * batch
        rows.append({"layer": lc.name, "kind": lc.kind,
                     "predicted_fwd": predicted, "measured_fwd": measured,
                     "ratio": measured / max(predicted, 1.0)})
        x = fwd(params, x)
    return rows


def main(fast: bool = True):
    with timed() as t:
        rows = run(width_mult=0.25 if fast else 1.0)
    save_json("table2_costmodel", rows)
    conv_fc = [r for r in rows if r["kind"] in ("conv", "fc")]
    ratios = np.array([r["ratio"] for r in conv_fc])
    emit("table2_flops_model", t["s"] * 1e6,
         f"median_ratio={np.median(ratios):.2f};n={len(rows)}")
    for r in rows:
        print(f"  {r['layer']:8s} {r['kind']:5s} predicted {r['predicted_fwd']:.3e} "
              f"measured {r['measured_fwd']:.3e} ratio {r['ratio']:.2f}")


if __name__ == "__main__":
    main()
