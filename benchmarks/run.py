"""Benchmark harness: one entry per paper table/figure + roofline report.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only NAME] [--list]

Prints ``name,us_per_call,derived`` CSV lines (plus human-readable detail).
"""
from __future__ import annotations

import argparse
import sys
import traceback

# (name, module, extra main() kwargs, description) — `--only NAME` and
# `--list` use the name; several names may share one module.
BENCHES = [
    ("table2_costmodel", "table2_costmodel", {},
     "Table II layer-level FLOPs model vs XLA"),
    ("kernel_bench", "kernel_bench", {},
     "Pallas-kernel reference micro-benchmarks (forward)"),
    ("kernel_bench --backward", "kernel_bench", {"backward": True},
     "fused_linear backward (dx / dw+db / grad) micro-benchmarks"),
    ("kernel_bench --autotune", "kernel_bench", {"autotune_sweep": True},
     "block-shape sweeps -> artifacts/autotune selection tables"),
    ("fl_round_bench", "fl_round_bench", {},
     "Cohort engine vs sequential FL round (speedup)"),
    ("fl_round_bench --churn", "fl_round_bench", {"churn_sweep": True},
     "churn/straggler sweep: sync barrier vs buffered async delay"),
    ("fl_round_bench --fused", "fl_round_bench", {"fused_sweep": True},
     "fused scan-the-round-loop vs stepwise rounds/sec + sweep farm"),
    ("fl_round_bench --model vgg", "fl_round_bench", {"model": "vgg"},
     "model-zoo round bench: VGG-11 (the paper's model)"),
    ("fl_round_bench --model transformer", "fl_round_bench",
     {"model": "transformer"},
     "model-zoo round bench: GQA decoder on the flash-attention path"),
    ("fl_round_bench --model ssm", "fl_round_bench", {"model": "ssm"},
     "model-zoo round bench: Mamba-2/SSD decoder"),
    ("scheduler_bench", "scheduler_bench", {},
     "DDSRA decide latency: numpy oracle vs jitted control plane"),
    ("theorem2_tradeoff", "theorem2_tradeoff", {},
     "Theorem 2 [O(1/V), O(sqrt V)] trade-off"),
    ("fig2_participation", "fig2_participation", {},
     "Fig 2 derived vs experimental participation"),
    ("fig456_schedulers", "fig456_schedulers", {},
     "Figs 4-6 DDSRA vs baselines"),
    ("roofline_report", "roofline_report", {},
     "Roofline table from dry-run artifacts"),
]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true",
                    help="full-size runs (slower, closer to paper scale)")
    ap.add_argument("--only", default=None)
    ap.add_argument("--list", action="store_true",
                    help="list benchmark names and exit")
    args = ap.parse_args()

    if args.list:
        for name, _, _, desc in BENCHES:
            print(f"{name:24s} {desc}")
        return
    if args.only and args.only not in {name for name, _, _, _ in BENCHES}:
        ap.error(f"unknown benchmark {args.only!r} (see --list)")

    failures = []
    for name, mod_name, kwargs, desc in BENCHES:
        if args.only and args.only != name:
            continue
        print(f"# {name}: {desc}", flush=True)
        try:
            mod = __import__(f"benchmarks.{mod_name}", fromlist=["main"])
            mod.main(fast=not args.full, **kwargs)
        except Exception:  # noqa: BLE001
            traceback.print_exc()
            failures.append(name)
    if failures:
        print(f"FAILED: {failures}")
        sys.exit(1)


if __name__ == "__main__":
    main()
