"""Theorem 2: the [O(1/V), O(sqrt(V))] trade-off.

Sweep the Lyapunov control parameter V; measure (a) average per-round delay
and (b) participation-rate constraint violation (queue stability gap).
Claim: delay decreases (to a floor) as V grows; the participation gap grows.

Two sweeps run: the host-side numpy loop (oracle, one V at a time), and
the fused JAX sweep — all V values ``vmap``-ed over a ``lax.scan`` of
jitted DDSRA rounds with on-device channel draws, i.e. the entire figure
as ONE XLA program (``DDSRAPlan.simulate_v_sweep``). The two use
different RNG streams, so the claim is checked qualitatively on both.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, save_json, timed
from repro.core.ddsra import Workload, ddsra_round
from repro.core.ddsra_jax import DDSRAPlan
from repro.core import costmodel as cm
from repro.core.network import Network, NetworkConfig
from repro.core.participation import participation_rates


def _jax_sweep(w, net, gamma, v_values, rounds: int, seed: int):
    """The whole V sweep as one jitted program; returns sweep entries."""
    import jax
    plan = DDSRAPlan.build(w, net)
    taus, sel = plan.simulate_v_sweep(jax.random.PRNGKey(seed), gamma,
                                      list(v_values), rounds)
    entries = []
    for i, v in enumerate(v_values):
        t = np.where(np.isfinite(taus[i]), taus[i], np.nan)
        rate = sel[i].mean(axis=0)
        entries.append({"v": v, "mean_delay": float(np.nanmean(t)),
                        "participation_gap":
                            float(np.maximum(gamma - rate, 0).max()),
                        "rates": rate.tolist()})
    return entries


def run(v_values=(0.01, 1.0, 100.0, 10000.0), rounds: int = 150, seed: int = 0):
    # wide distance heterogeneity + a comms-dominated workload (MLP) so that
    # picking low-delay gateways and honouring participation targets
    # genuinely conflict: delay then scales ~d^2 across gateways
    net = Network(NetworkConfig(dist_range=(300.0, 4000.0)),
                  np.random.default_rng(seed))
    from repro.models.vgg import mlp_layer_costs
    layers = mlp_layer_costs((3072, 512, 512, 10))
    o, g = cm.flops_vector(layers), cm.mem_vector(layers, batch=50)
    rng = np.random.default_rng(seed)
    d_tilde = np.maximum((rng.uniform(0, 2000, net.cfg.n_devices) * 0.05).astype(int), 4)
    w = Workload(o, g, cm.model_size_bytes(layers), 5, d_tilde.astype(float))
    # uneven targets so the constraint binds
    gamma = participation_rates(rng.uniform(0.3, 3.0, net.cfg.n_gateways),
                                net.cfg.n_channels)
    out = {"gamma": gamma.tolist(), "sweep": []}
    with timed() as t_np:
        for v in v_values:
            q = np.zeros(net.cfg.n_gateways)
            taus, hist = [], []
            for t in range(rounds):
                dec = ddsra_round(w, net, net.draw(), q, gamma, v)
                q = dec.queues
                taus.append(dec.delay if np.isfinite(dec.delay) else np.nan)
                hist.append(dec.selected)
            rate = np.mean(hist, axis=0)
            gap = float(np.maximum(gamma - rate, 0).max())
            out["sweep"].append({"v": v,
                                 "mean_delay": float(np.nanmean(taus)),
                                 "participation_gap": gap,
                                 "rates": rate.tolist()})
    out["numpy_seconds"] = t_np["s"]
    with timed() as t_jx:
        out["jax_sweep"] = _jax_sweep(w, net, gamma, v_values, rounds, seed)
    out["jax_seconds"] = t_jx["s"]
    return out


def main(fast: bool = True):
    with timed() as t:
        res = run(rounds=60 if fast else 300)
    save_json("theorem2_tradeoff", res)
    d = [s["mean_delay"] for s in res["sweep"]]
    g = [s["participation_gap"] for s in res["sweep"]]
    emit("theorem2_V_tradeoff", t["s"] * 1e6,
         f"delay:{d[0]:.2f}->{d[-1]:.2f};gap:{g[0]:.3f}->{g[-1]:.3f}")
    for key, label in (("sweep", "numpy"), ("jax_sweep", "fused-jax")):
        print(f"  [{label}]")
        for s in res[key]:
            print(f"  V={s['v']:<8g} delay {s['mean_delay']:7.2f}s  "
                  f"gap {s['participation_gap']:.3f}  "
                  f"rates {np.round(s['rates'], 2)}")
    print(f"  sweep wall: numpy {res['numpy_seconds']:.1f}s, "
          f"fused jax {res['jax_seconds']:.1f}s (incl. compile)")


if __name__ == "__main__":
    main()
