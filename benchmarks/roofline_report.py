"""Roofline report: aggregates artifacts/dryrun/*.json into the per-
(arch x shape x mesh) table consumed by EXPERIMENTS.md §Roofline."""
from __future__ import annotations

import json
import pathlib

from benchmarks.common import ARTIFACTS, emit, save_json

DRYRUN = ARTIFACTS / "dryrun"


def load_all():
    rows = []
    for f in sorted(DRYRUN.glob("*.json")):
        rows.append(json.loads(f.read_text()))
    return rows


def to_markdown(rows, mesh: str = "16x16") -> str:
    lines = [
        "| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | bound | "
        "useful frac | HBM/dev (GiB) |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r["mesh"] != mesh:
            continue
        ro, m = r["roofline"], r["memory"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {ro['t_compute_s']:.2e} | "
            f"{ro['t_memory_s']:.2e} | {ro['t_collective_s']:.2e} | "
            f"{ro['bottleneck']} | {r['useful_flops_frac']:.2f} | "
            f"{m['peak_bytes']/2**30:.2f} |")
    return "\n".join(lines)


def main(fast: bool = True):
    rows = load_all()
    if not rows:
        emit("roofline_table", 0.0, "no dryrun artifacts yet")
        return
    n1 = sum(r["mesh"] == "16x16" for r in rows)
    n2 = sum(r["mesh"] == "2x16x16" for r in rows)
    bounds = {}
    for r in rows:
        if r["mesh"] == "16x16":
            bounds[r["roofline"]["bottleneck"]] = bounds.get(
                r["roofline"]["bottleneck"], 0) + 1
    save_json("roofline_rows", rows)
    (ARTIFACTS / "roofline_16x16.md").write_text(to_markdown(rows))
    (ARTIFACTS / "roofline_2x16x16.md").write_text(to_markdown(rows, "2x16x16"))
    emit("roofline_table", 0.0,
         f"1pod={n1}/40;2pod={n2}/40;bounds={bounds}")


if __name__ == "__main__":
    main()
