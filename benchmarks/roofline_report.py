"""Roofline report: aggregates artifacts/dryrun/*.json into the per-
(arch x shape x mesh) table consumed by EXPERIMENTS.md §Roofline, plus a
kernel-stack section that converts ``kernel_bench.json`` rows into roofline
*fractions* (``tpu_roofline_us / us_per_call`` — the backend-comparable
number; the absolute µs of a ref/interpret row is CPU trivia)."""
from __future__ import annotations

import json
import pathlib

from benchmarks.common import ARTIFACTS, emit, save_json

DRYRUN = ARTIFACTS / "dryrun"
KERNEL_BENCH = ARTIFACTS / "benchmarks" / "kernel_bench.json"


def load_all():
    rows = []
    for f in sorted(DRYRUN.glob("*.json")):
        rows.append(json.loads(f.read_text()))
    return rows


def to_markdown(rows, mesh: str = "16x16") -> str:
    lines = [
        "| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | bound | "
        "useful frac | HBM/dev (GiB) |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r["mesh"] != mesh:
            continue
        ro, m = r["roofline"], r["memory"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {ro['t_compute_s']:.2e} | "
            f"{ro['t_memory_s']:.2e} | {ro['t_collective_s']:.2e} | "
            f"{ro['bottleneck']} | {r['useful_flops_frac']:.2f} | "
            f"{m['peak_bytes']/2**30:.2f} |")
    return "\n".join(lines)


def kernel_fractions() -> list:
    """Per-row roofline fractions from ``kernel_bench.json`` (rows written
    before the tagging scheme — plain us/roofline pairs — are upgraded on
    the fly; ``autotune_*`` rows report speedup instead)."""
    if not KERNEL_BENCH.exists():
        return []
    payload = json.loads(KERNEL_BENCH.read_text())
    out = []
    for name, row in sorted(payload.items()):
        if not isinstance(row, dict):
            continue
        if "us_per_call" not in row and "us" not in row:
            continue
        us = float(row.get("us_per_call", row.get("us", 0.0)))
        roof = float(row.get("tpu_roofline_us", 0.0))
        frac = row.get("roofline_frac",
                       roof / us if us > 0 else 0.0)
        out.append({
            "name": name,
            "impl": row.get("impl", "ref"),
            "blocks": row.get("blocks"),
            "us_per_call": us,
            "tpu_roofline_us": roof,
            "roofline_frac": float(frac),
            "speedup_vs_default": row.get("speedup_vs_default"),
        })
    return out


def kernels_markdown(rows: list) -> str:
    lines = [
        "| kernel row | impl | blocks | µs/call | TPU roofline µs | "
        "roofline frac | autotune speedup |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        blocks = "x".join(str(b) for b in r["blocks"]) if r["blocks"] else "—"
        sp = f"{r['speedup_vs_default']:.2f}x" \
            if r.get("speedup_vs_default") else "—"
        lines.append(
            f"| {r['name']} | {r['impl']} | {blocks} | "
            f"{r['us_per_call']:.1f} | {r['tpu_roofline_us']:.2f} | "
            f"{r['roofline_frac']:.2e} | {sp} |")
    return "\n".join(lines)


def main(fast: bool = True):
    rows = load_all()
    if rows:
        n1 = sum(r["mesh"] == "16x16" for r in rows)
        n2 = sum(r["mesh"] == "2x16x16" for r in rows)
        bounds = {}
        for r in rows:
            if r["mesh"] == "16x16":
                bounds[r["roofline"]["bottleneck"]] = bounds.get(
                    r["roofline"]["bottleneck"], 0) + 1
        save_json("roofline_rows", rows)
        (ARTIFACTS / "roofline_16x16.md").write_text(to_markdown(rows))
        (ARTIFACTS / "roofline_2x16x16.md").write_text(
            to_markdown(rows, "2x16x16"))
        emit("roofline_table", 0.0,
             f"1pod={n1}/40;2pod={n2}/40;bounds={bounds}")
    else:
        emit("roofline_table", 0.0, "no dryrun artifacts yet")

    krows = kernel_fractions()
    if krows:
        save_json("roofline_kernels", krows)
        (ARTIFACTS / "roofline_kernels.md").write_text(
            kernels_markdown(krows) + "\n")
        tuned = [r for r in krows if r.get("speedup_vs_default")]
        emit("roofline_kernels", 0.0,
             f"rows={len(krows)};tuned={len(tuned)}")
    else:
        emit("roofline_kernels", 0.0, "no kernel_bench artifact yet")


if __name__ == "__main__":
    main()
