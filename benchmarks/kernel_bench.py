"""Kernel micro-benchmarks: µs/call of the jnp reference paths on CPU (the
Pallas kernels target TPU; interpret-mode timing is not meaningful), plus an
analytic MXU-roofline estimate of the kernel's TPU-side time."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.fused_linear.ref import fused_linear_ref
from repro.kernels.ssd_scan.ref import ssd_ref

PEAK = 197e12


def _bench(fn, *args, iters: int = 5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def main(fast: bool = True):
    k = jax.random.PRNGKey(0)
    # flash attention: B=2 H=8 S=1024 D=128
    b, h, s, d = 2, 8, 1024, 128
    q, kk, v = (jax.random.normal(jax.random.fold_in(k, i), (b, h, s, d),
                                  jnp.float32) for i in range(3))
    f = jax.jit(lambda a, b_, c: attention_ref(a, b_, c, causal=True))
    us = _bench(f, q, kk, v)
    flops = 4 * b * h * s * s * d / 2
    emit("kernel_flash_attention_ref", us, f"tpu_roofline_us={flops/PEAK*1e6:.1f}")

    # ssd scan: B=2 S=512 n=8 p=64 ds=64
    b2, s2, n, p, ds = 2, 512, 8, 64, 64
    xh = jax.random.normal(k, (b2, s2, n, p))
    dt = jax.nn.softplus(jax.random.normal(k, (b2, s2, n))) * 0.5
    a_log = jax.random.normal(k, (n,)) * 0.3
    bs = jax.random.normal(k, (b2, s2, ds)) * 0.5
    cs = jax.random.normal(k, (b2, s2, ds)) * 0.5
    f2 = jax.jit(ssd_ref)
    us = _bench(f2, xh, dt, a_log, bs, cs)
    q_chunk = 128
    flops2 = b2 * s2 * n * (2 * q_chunk * p + 4 * ds * p)
    emit("kernel_ssd_scan_ref", us, f"tpu_roofline_us={flops2/PEAK*1e6:.1f}")

    # fused linear: 1024x1024x1024
    m = 1024
    x = jax.random.normal(k, (m, m))
    w = jax.random.normal(k, (m, m)) / 32
    bvec = jnp.zeros((m,))
    f3 = jax.jit(lambda a, b_, c: fused_linear_ref(a, b_, c, "relu"))
    us = _bench(f3, x, w, bvec)
    emit("kernel_fused_linear_ref", us, f"tpu_roofline_us={2*m**3/PEAK*1e6:.1f}")


if __name__ == "__main__":
    main()
