"""Kernel micro-benchmarks: µs/call of every implementation of the kernel
stack, each row tagged with the implementation (``ref`` / ``interpret`` /
``pallas``), the block plan the selection table chose, and its **roofline
fraction** — ``tpu_roofline_us / us_per_call``, the fraction of the analytic
MXU roofline the measured path achieves (the comparable number across
backends; absolute CPU µs of a TPU kernel is not).

``--backward`` adds the fused_linear training-step contractions — the
transposed-operand ``dx = dz @ wᵀ`` / ``(dw, db) = (xᵀ @ dz, Σ dz)`` refs
and the end-to-end ``jax.grad`` of the custom-VJP ``linear`` op — i.e. the
two-thirds of per-step FLOPs the backward subsystem moved onto kernels.

``--autotune`` runs the block-shape sweeps (``repro.kernels.autotune``) over
the benched shapes, persists the winners into the selection tables under
``artifacts/autotune/`` and records each winner's speedup over the fixed
clamped-128 plan in an ``autotune_*`` row.

Timings accumulate into ``artifacts/benchmarks/kernel_bench.json`` (all
sections merge, so any invocation order leaves them populated).
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp

from benchmarks.common import ARTIFACTS, emit, save_json
from repro.kernels import autotune
from repro.kernels.flash_attention.ops import gqa_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.fused_linear import ops as fused_ops
from repro.kernels.fused_linear.ref import (fused_linear_bwd_dw_db_ref,
                                            fused_linear_bwd_dx_ref,
                                            fused_linear_ref)
from repro.kernels.ssd_scan.ops import ssd
from repro.kernels.ssd_scan.ref import ssd_ref

PEAK = 197e12

# the shapes the kernel-path section benches and --autotune sweeps; the two
# fused_linear GEMMs are deliberately non-square (the shapes where the fixed
# 128^3 plan leaves the most on the table).
GEMM_SHAPES = ((256, 512, 128), (512, 128, 256))
ATTN_SHAPE = (1, 2, 256, 64)           # (B, H, S, hd), kernel layout
SSD_SHAPE = (1, 256, 8, 64, 64)        # (B, S, n, p, ds)


def _bench(fn, *args, iters: int = 5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def _row(record: dict, name: str, us: float, roofline_us: float, *,
         impl: str, blocks=None, flops: float = None) -> None:
    frac = roofline_us / us if us > 0 else 0.0
    tag = f"roofline_frac={frac:.2e};impl={impl}"
    if blocks is not None:
        tag += ";blocks=" + "x".join(str(b) for b in blocks)
    emit(name, us, tag)
    record[name] = {
        "us_per_call": us,
        "tpu_roofline_us": roofline_us,
        "roofline_frac": frac,
        "impl": impl,
        "blocks": list(blocks) if blocks is not None else None,
        "flops": flops,
    }


def _gemm_inputs(m: int, k: int, n: int, key=0, dtype=jnp.float32):
    kk = jax.random.PRNGKey(key)
    x = jax.random.normal(kk, (m, k), jnp.float32).astype(dtype)
    w = (jax.random.normal(jax.random.fold_in(kk, 1), (k, n), jnp.float32)
         / 32).astype(dtype)
    b = jnp.zeros((n,), dtype)
    return x, w, b


def _forward(record: dict) -> None:
    k = jax.random.PRNGKey(0)
    # flash attention: B=2 H=8 S=1024 D=128
    b, h, s, d = 2, 8, 1024, 128
    q, kk, v = (jax.random.normal(jax.random.fold_in(k, i), (b, h, s, d),
                                  jnp.float32) for i in range(3))
    f = jax.jit(lambda a, b_, c: attention_ref(a, b_, c, causal=True))
    flops = 4 * b * h * s * s * d / 2
    _row(record, "kernel_flash_attention_ref", _bench(f, q, kk, v),
         flops / PEAK * 1e6, impl="ref", flops=flops)

    # ssd scan: B=2 S=512 n=8 p=64 ds=64
    b2, s2, n, p, ds = 2, 512, 8, 64, 64
    xh = jax.random.normal(k, (b2, s2, n, p))
    dt = jax.nn.softplus(jax.random.normal(k, (b2, s2, n))) * 0.5
    a_log = jax.random.normal(k, (n,)) * 0.3
    bs = jax.random.normal(k, (b2, s2, ds)) * 0.5
    cs = jax.random.normal(k, (b2, s2, ds)) * 0.5
    f2 = jax.jit(ssd_ref)
    q_chunk = 128
    flops2 = b2 * s2 * n * (2 * q_chunk * p + 4 * ds * p)
    _row(record, "kernel_ssd_scan_ref", _bench(f2, xh, dt, a_log, bs, cs),
         flops2 / PEAK * 1e6, impl="ref", flops=flops2)

    # fused linear: 1024x1024x1024
    m = 1024
    x, w, bvec = _gemm_inputs(m, m, m)
    f3 = jax.jit(lambda a, b_, c: fused_linear_ref(a, b_, c, "relu"))
    flops3 = 2 * m**3
    _row(record, "kernel_fused_linear_ref", _bench(f3, x, w, bvec),
         flops3 / PEAK * 1e6, impl="ref", flops=flops3)


def _backward(record: dict) -> None:
    k = jax.random.PRNGKey(1)
    m = 1024
    gemm_flops = 2 * m**3
    gemm_roof = gemm_flops / PEAK * 1e6
    x = jax.random.normal(k, (m, m))
    w = jax.random.normal(jax.random.fold_in(k, 1), (m, m)) / 32
    bvec = jnp.zeros((m,))
    dy = jax.random.normal(jax.random.fold_in(k, 2), (m, m))
    y = fused_linear_ref(x, w, bvec, "relu")

    # the two backward contractions, relu mask fused (ref = CPU hot path;
    # on TPU these become the transposed-operand Pallas kernels)
    fdx = jax.jit(lambda d, w_, y_: fused_linear_bwd_dx_ref(d, w_, y_, "relu"))
    _row(record, "kernel_fused_linear_bwd_dx_ref", _bench(fdx, dy, w, y),
         gemm_roof, impl="ref", flops=gemm_flops)
    fdw = jax.jit(lambda x_, d, y_: fused_linear_bwd_dw_db_ref(x_, d, y_,
                                                               "relu"))
    _row(record, "kernel_fused_linear_bwd_dw_db_ref", _bench(fdw, x, dy, y),
         gemm_roof, impl="ref", flops=gemm_flops)

    # end-to-end training step of the op: value+grad through the custom VJP
    # (fwd GEMM + dx + dw ≈ 3 GEMMs of work)
    fstep = jax.jit(jax.grad(
        lambda x_, w_, b_: fused_ops.linear(x_, w_, b_, activation="relu",
                                            impl="ref").sum(),
        argnums=(0, 1, 2)))
    _row(record, "kernel_fused_linear_grad_ref", _bench(fstep, x, w, bvec),
         3 * gemm_roof, impl="ref", flops=3 * gemm_flops)


def _kernel_paths(record: dict) -> None:
    """Time the kernels through their real op-layer entry points — compiled
    Pallas on TPU, the Pallas interpreter elsewhere — with whatever blocks
    the selection table resolves, and tag the rows with both."""
    impl = "pallas" if jax.default_backend() == "tpu" else "interpret"
    interpret = impl == "interpret"

    for m, k, n in GEMM_SHAPES:
        x, w, b = _gemm_inputs(m, k, n)
        blocks = autotune.blocks_for("fused_linear", (m, k, n), "float32",
                                     interpret=interpret)
        fn = jax.jit(lambda a, b_, c: fused_ops.linear(a, b_, c,
                                                       activation="relu",
                                                       impl=impl))
        flops = 2 * m * k * n
        _row(record, f"kernel_fused_linear_{m}x{k}x{n}_{impl}",
             _bench(fn, x, w, b, iters=3), flops / PEAK * 1e6,
             impl=impl, blocks=blocks, flops=flops)

    b, h, s, d = ATTN_SHAPE
    kk = jax.random.PRNGKey(2)
    # gqa_attention takes the model layout (B, S, H, hd)
    q, kt, vt = (jax.random.normal(jax.random.fold_in(kk, i), (b, s, h, d))
                 for i in range(3))
    blocks = autotune.blocks_for("flash_attention", (b, h, s, d), "float32",
                                 interpret=interpret)
    fn = jax.jit(lambda a, b_, c: gqa_attention(a, b_, c, causal=True,
                                                interpret=interpret))
    flops = 4 * b * h * s * s * d / 2
    _row(record, f"kernel_flash_attention_{impl}",
         _bench(fn, q, kt, vt, iters=3), flops / PEAK * 1e6,
         impl=impl, blocks=blocks, flops=flops)

    b2, s2, n, p, ds = SSD_SHAPE
    xh = jax.random.normal(kk, (b2, s2, n, p))
    dt = jax.nn.softplus(jax.random.normal(kk, (b2, s2, n))) * 0.5
    a_log = jax.random.normal(kk, (n,)) * 0.3
    bs = jax.random.normal(kk, (b2, s2, ds)) * 0.5
    cs = jax.random.normal(kk, (b2, s2, ds)) * 0.5
    blocks = autotune.blocks_for("ssd_scan", SSD_SHAPE, "float32",
                                 interpret=interpret)
    fn = jax.jit(lambda *a: ssd(*a, interpret=interpret))
    chunk = blocks[0]
    flops2 = b2 * s2 * n * (2 * chunk * p + 4 * ds * p)
    _row(record, f"kernel_ssd_scan_{impl}",
         _bench(fn, xh, dt, a_log, bs, cs, iters=3), flops2 / PEAK * 1e6,
         impl=impl, blocks=blocks, flops=flops2)


def _autotune(record: dict) -> None:
    """Sweep block shapes for the benched shapes, persist the winners to the
    selection tables, and record each winner's speedup over the fixed
    clamped-128 default plan."""
    interpret = jax.default_backend() != "tpu"

    def note(name: str, entry: dict) -> None:
        if entry is None:
            return
        emit(name, entry["us"],
             f"speedup_vs_default={entry['speedup_vs_default']:.2f};"
             f"blocks=" + "x".join(str(b) for b in entry["blocks"]))
        record[name] = dict(entry)

    for m, k, n in GEMM_SHAPES:
        note(f"autotune_fused_linear_{m}x{k}x{n}",
             autotune.sweep_fused_linear(m, k, n, interpret=interpret))
    # a bf16 entry for the mixed-precision data plane's hottest shape
    m, k, n = GEMM_SHAPES[0]
    note(f"autotune_fused_linear_{m}x{k}x{n}_bf16",
         autotune.sweep_fused_linear(m, k, n, dtype="bfloat16",
                                     interpret=interpret))
    note("autotune_flash_attention",
         autotune.sweep_flash_attention(*ATTN_SHAPE, interpret=interpret))
    note("autotune_ssd_scan",
         autotune.sweep_ssd_scan(*SSD_SHAPE, interpret=interpret))


def main(fast: bool = True, backward: bool = False,
         autotune_sweep: bool = False) -> None:
    record: dict = {}
    if autotune_sweep:
        _autotune(record)
        _kernel_paths(record)      # re-times the ops at the tuned blocks
    elif backward:
        _backward(record)
    else:
        _forward(record)
        _kernel_paths(record)
    # merge with whatever section ran before, so sections accumulate
    out = ARTIFACTS / "benchmarks" / "kernel_bench.json"
    payload = json.loads(out.read_text()) if out.exists() else {}
    payload.update(record)
    save_json("kernel_bench", payload)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--backward", action="store_true",
                    help="bench the fused_linear backward contractions")
    ap.add_argument("--autotune", action="store_true",
                    help="sweep block shapes and persist the winners to "
                         "artifacts/autotune/")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    main(fast=not args.full, backward=args.backward,
         autotune_sweep=args.autotune)
