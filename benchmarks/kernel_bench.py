"""Kernel micro-benchmarks: µs/call of the jnp reference paths on CPU (the
Pallas kernels target TPU; interpret-mode timing is not meaningful), plus an
analytic MXU-roofline estimate of the kernel's TPU-side time.

``--backward`` adds the fused_linear training-step contractions — the
transposed-operand ``dx = dz @ wᵀ`` / ``(dw, db) = (xᵀ @ dz, Σ dz)`` refs
and the end-to-end ``jax.grad`` of the custom-VJP ``linear`` op — i.e. the
two-thirds of per-step FLOPs the backward subsystem moved onto kernels.

Timings accumulate into ``artifacts/benchmarks/kernel_bench.json`` (the
forward and backward sections merge, so either invocation order leaves
both populated).
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp

from benchmarks.common import ARTIFACTS, emit, save_json
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.fused_linear import ops as fused_ops
from repro.kernels.fused_linear.ref import (fused_linear_bwd_dw_db_ref,
                                            fused_linear_bwd_dx_ref,
                                            fused_linear_ref)
from repro.kernels.ssd_scan.ref import ssd_ref

PEAK = 197e12


def _bench(fn, *args, iters: int = 5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def _emit(record: dict, name: str, us: float, roofline_us: float) -> None:
    emit(name, us, f"tpu_roofline_us={roofline_us:.1f}")
    record[name] = {"us_per_call": us, "tpu_roofline_us": roofline_us}


def _forward(record: dict) -> None:
    k = jax.random.PRNGKey(0)
    # flash attention: B=2 H=8 S=1024 D=128
    b, h, s, d = 2, 8, 1024, 128
    q, kk, v = (jax.random.normal(jax.random.fold_in(k, i), (b, h, s, d),
                                  jnp.float32) for i in range(3))
    f = jax.jit(lambda a, b_, c: attention_ref(a, b_, c, causal=True))
    flops = 4 * b * h * s * s * d / 2
    _emit(record, "kernel_flash_attention_ref", _bench(f, q, kk, v),
          flops / PEAK * 1e6)

    # ssd scan: B=2 S=512 n=8 p=64 ds=64
    b2, s2, n, p, ds = 2, 512, 8, 64, 64
    xh = jax.random.normal(k, (b2, s2, n, p))
    dt = jax.nn.softplus(jax.random.normal(k, (b2, s2, n))) * 0.5
    a_log = jax.random.normal(k, (n,)) * 0.3
    bs = jax.random.normal(k, (b2, s2, ds)) * 0.5
    cs = jax.random.normal(k, (b2, s2, ds)) * 0.5
    f2 = jax.jit(ssd_ref)
    q_chunk = 128
    flops2 = b2 * s2 * n * (2 * q_chunk * p + 4 * ds * p)
    _emit(record, "kernel_ssd_scan_ref", _bench(f2, xh, dt, a_log, bs, cs),
          flops2 / PEAK * 1e6)

    # fused linear: 1024x1024x1024
    m = 1024
    x = jax.random.normal(k, (m, m))
    w = jax.random.normal(k, (m, m)) / 32
    bvec = jnp.zeros((m,))
    f3 = jax.jit(lambda a, b_, c: fused_linear_ref(a, b_, c, "relu"))
    _emit(record, "kernel_fused_linear_ref", _bench(f3, x, w, bvec),
          2 * m**3 / PEAK * 1e6)


def _backward(record: dict) -> None:
    k = jax.random.PRNGKey(1)
    m = 1024
    gemm_roof = 2 * m**3 / PEAK * 1e6
    x = jax.random.normal(k, (m, m))
    w = jax.random.normal(jax.random.fold_in(k, 1), (m, m)) / 32
    bvec = jnp.zeros((m,))
    dy = jax.random.normal(jax.random.fold_in(k, 2), (m, m))
    y = fused_linear_ref(x, w, bvec, "relu")

    # the two backward contractions, relu mask fused (ref = CPU hot path;
    # on TPU these become the transposed-operand Pallas kernels)
    fdx = jax.jit(lambda d, w_, y_: fused_linear_bwd_dx_ref(d, w_, y_, "relu"))
    _emit(record, "kernel_fused_linear_bwd_dx_ref", _bench(fdx, dy, w, y),
          gemm_roof)
    fdw = jax.jit(lambda x_, d, y_: fused_linear_bwd_dw_db_ref(x_, d, y_,
                                                               "relu"))
    _emit(record, "kernel_fused_linear_bwd_dw_db_ref", _bench(fdw, x, dy, y),
          gemm_roof)

    # end-to-end training step of the op: value+grad through the custom VJP
    # (fwd GEMM + dx + dw ≈ 3 GEMMs of work)
    fstep = jax.jit(jax.grad(
        lambda x_, w_, b_: fused_ops.linear(x_, w_, b_, activation="relu",
                                            impl="ref").sum(),
        argnums=(0, 1, 2)))
    _emit(record, "kernel_fused_linear_grad_ref", _bench(fstep, x, w, bvec),
          3 * gemm_roof)


def main(fast: bool = True, backward: bool = False) -> None:
    record: dict = {}
    if backward:
        _backward(record)
    else:
        _forward(record)
    # merge with whatever section ran before, so fwd+bwd accumulate
    out = ARTIFACTS / "benchmarks" / "kernel_bench.json"
    payload = json.loads(out.read_text()) if out.exists() else {}
    payload.update(record)
    save_json("kernel_bench", payload)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--backward", action="store_true",
                    help="bench the fused_linear backward contractions")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    main(fast=not args.full, backward=args.backward)
