"""Shared helpers for the benchmark harness."""
from __future__ import annotations

import contextlib
import json
import pathlib
import time

ARTIFACTS = pathlib.Path(__file__).resolve().parent.parent / "artifacts"


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    """CSV contract: name,us_per_call,derived."""
    print(f"{name},{us_per_call:.2f},{derived}")


@contextlib.contextmanager
def timed():
    t = {}
    t0 = time.perf_counter()
    yield t
    t["s"] = time.perf_counter() - t0


def save_json(name: str, payload) -> pathlib.Path:
    out = ARTIFACTS / "benchmarks"
    out.mkdir(parents=True, exist_ok=True)
    p = out / f"{name}.json"
    p.write_text(json.dumps(payload, indent=2, default=float))
    return p
