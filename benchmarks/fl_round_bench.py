"""Cohort engine vs the seed sequential path: 10-round, 20-device FL sim.

The seed trainer ran devices one-by-one — a jitted step per device per local
epoch, retraced for every distinct (partition point, batch shape) pair, with
sequential per-sample-grad estimation at init. The cohort engine fuses each
round (and the whole stats estimation) into one XLA program each.

Both engines run in this process back-to-back on the same scheduler trace
and dataset, so the ratio is robust to machine noise. "Simulation" = stats
estimation + the 10-round training loop (dataset synthesis is identical
common setup for both). Values are emitted in MILLISECONDS, as named.

NOTE the baseline here is conservative: the in-tree sequential engine
already benefits from this PR's shared speedups (vectorized DDSRA partition
search and Hungarian inner loop, jitted FedAvg, cached eval forward), which
the seed did not have. Measured against the untouched seed commit, the same
simulation is >5x slower than the cohort engine on a 2-core CPU box (seed
32.8s vs cohort 5.0s when this bench was written); the emitted speedup vs
the improved in-tree sequential path is the lower bound.

Part two sweeps cohort scale: {20, 64, 128} devices x engine
(single-width cohort, 4-tier cohort, 4-tier sharded cohort), reporting
per-round wall time and the padded-vs-real sample ratio — the tiered slot
layout recovers most of the batch-padding waste of the single-width
contract, and the sharded engine splits the slot axis over the
``"cohort"`` mesh (1 device on the CPU dev box; run under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` to see an actual
mesh).

Part three (``--churn`` in the harness / ``churn_sweep=True``) sweeps the
fault axes instead of the machine: churn x straggler tail, comparing the
async engine's synchronous-barrier mode against FedBuff-style buffering on
*simulated* round delay and loss progress. Saves
``artifacts/benchmarks/fl_round_bench_churn.json``.

Part five (``--model {vgg,transformer,ssm}`` / ``model="..."``) runs the
cohort round across the model zoo behind the ``SplitModel`` interface:
same topology, same scheduler, different architecture (and for the token
models, the Markov token data plane + flash-attention kernels). Reports
per-round steady-state time and the one-compile contract per model.
Saves ``artifacts/benchmarks/fl_round_bench_model_<name>.json``.

Part four (``--fused`` / ``fused_sweep=True``) benches the fused simulation
loop (``repro.fl.fused_sim``) on the traced data plane
(``Scenario.data_plane="traced"``: batches gathered in-scan from
device-resident shard stacks — zero per-round host transfers): steady-state
rounds/sec of the stepwise ``Simulation.rounds()`` loop vs
``fused_rounds()`` (one decide scan + one train scan) on the 20-device
topology, asserting the fused path holds a >= 3x edge and that a whole run
costs zero retraces once warm; then the sweep farm (``Simulation.sweep()``):
the seeds x V grid and the policies x seeds x V multi-policy grid
(``repro.core.policy_sweep``), asserting each is ONE compiled program
across value changes and recording the one-program grid's wall-clock
against one-program-per-policy sweeps of the same lanes.
Saves ``artifacts/benchmarks/fl_round_bench_fused.json``.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, save_json, timed
from repro.core import ddsra_jax, policy_sweep
from repro.core.network import NetworkConfig
from repro.fl import Scenario, Simulation
from repro.fl import cohort as cohort_lib

ROUNDS, DEVICES, GATEWAYS = 10, 20, 5

# (n_devices, n_gateways, n_channels) for the scaling sweep
SCALE_SWEEP = [(20, 5, 3), (64, 8, 4), (128, 16, 8)]
# (engine, tiers) variants: single-width cohort is the historical contract
SCALE_ENGINES = [("cohort", 1), ("cohort", 4), ("sharded", 4)]

# -- churn/straggler sweep (``--churn`` / ``churn_sweep=True``) -------------
# churn rates x straggler tails, each run under both aggregation modes of
# the async engine: the barrier sentinel (buffer_k=None — synchronous
# FedAvg semantics, the server waits for the slowest surviving report) and
# FedBuff-style buffering (aggregate at K landings, stragglers keep flying).
CHURN_RATES = [0.0, 0.1, 0.3]
STRAGGLER_TAILS = [(0.0, 0.0), (0.5, 1.0), (0.5, 3.0)]   # (frac, scale)
CHURN_MODES = [("sync_barrier", None), ("async_buffered", 2)]


def _simulate(engine: str):
    sc = Scenario(model="mlp", rounds=ROUNDS, seed=0, engine=engine,
                  net=NetworkConfig(n_gateways=GATEWAYS, n_devices=DEVICES,
                                    n_channels=3))
    sim = Simulation(sc)                  # init runs estimate_stats (timed)
    with timed() as t_run:
        res = sim.run("ddsra")
    return sim.stats_seconds, t_run["s"], res


def _scale_run(n_dev: int, n_gw: int, n_ch: int, engine: str, tiers: int,
               rounds: int):
    """One sweep point: short ddsra-scheduled sim at the given scale.

    Rounds are timed individually; ``round_ms`` is the mean over the
    steady-state rounds (the first round pays XLA compilation and the last
    pays the accuracy eval, so both are excluded)."""
    sc = Scenario(model="mlp", rounds=rounds, eval_every=rounds + 1, seed=0,
                  engine=engine, tiers=tiers, alpha=0.2, max_dataset=250,
                  net=NetworkConfig(n_gateways=n_gw, n_devices=n_dev,
                                    n_channels=n_ch))
    sim = Simulation(sc)
    per_round, records = [], []
    it = sim.rounds("ddsra")
    for _ in range(rounds):
        with timed() as t:
            records.append(next(it))
        per_round.append(t["s"])
    steady = per_round[1:-1] if rounds > 2 else per_round[-1:]
    real = sim.padding_stats["real_samples"]
    padded = sim.padding_stats["padded_samples"]
    return {
        "devices": n_dev, "engine": engine, "tiers": tiers,
        "rounds": rounds, "stats_s": sim.stats_seconds,
        "run_s": sum(per_round), "compile_round_s": per_round[0],
        "round_ms": sum(steady) * 1e3 / len(steady),
        "real_samples": real, "padded_samples": padded,
        "pad_ratio": padded / max(real, 1.0),
        "final_loss": float(np.mean(records[-1].losses)),
    }


TARGET_LOSS = 0.5        # rounds/delay-to-target threshold (initial ~2.3)


def _churn_run(churn: float, frac: float, scale: float, buffer_k,
               budget_s: float, stats):
    """One sweep point: a faulted async-engine run on the shared topology,
    run until ``budget_s`` of *simulated* time has elapsed (both modes get
    the same wall of simulated seconds — the only fair axis when round
    delays differ by design).

    ``stats`` (precomputed per-device statistics) is threaded into every
    run so no estimation draws are consumed and every point replays the
    identical schedule/batch/fault streams — the sweep isolates the
    aggregation mode."""
    cap = 400               # hard round cap under the time budget
    sc = Scenario(model="mlp", rounds=cap, eval_every=cap + 1, seed=0,
                  alpha=0.2, max_dataset=250, engine="async", churn=churn,
                  straggler_frac=frac, straggler_scale=scale,
                  buffer_k=buffer_k,
                  net=NetworkConfig(n_gateways=GATEWAYS, n_devices=DEVICES,
                                    n_channels=3))
    sim = Simulation(sc, _stats=stats)
    recs = []
    for rec in sim.rounds("ddsra"):
        recs.append(rec)
        if rec.cum_delay >= budget_s:
            break
    mean_loss = [float(np.mean(r.losses)) for r in recs]
    to_target = next((i for i, l in enumerate(mean_loss)
                      if l <= TARGET_LOSS), None)
    n = len(recs)
    return {
        "churn": churn, "straggler_frac": frac, "straggler_scale": scale,
        "mode": "sync_barrier" if buffer_k is None else "async_buffered",
        "buffer_k": buffer_k, "budget_s": budget_s,
        "rounds_in_budget": n,
        "mean_round_delay": recs[-1].cum_delay / n,
        "cum_delay": recs[-1].cum_delay,
        "loss_at_budget": mean_loss[-1],
        "target_loss": TARGET_LOSS,
        "rounds_to_target": None if to_target is None else to_target + 1,
        "delay_to_target": (None if to_target is None
                            else recs[to_target].cum_delay),
        "aggregations": sum(r.aggregations for r in recs),
        "dropped_devices": sum(r.dropped_devices for r in recs),
        "straggler_devices": sum(r.straggler_devices for r in recs),
        "stale_discarded": sum(r.stale_discarded for r in recs),
        "staleness_max": max(r.staleness_max for r in recs),
        "loss_curve": mean_loss,
        "cum_delay_curve": [r.cum_delay for r in recs],
    }


def churn_main(fast: bool = True) -> None:
    """Churn/straggler sweep: sync-barrier vs buffered aggregation.

    The claim under test: as the straggler tail grows, the synchronous
    barrier's mean round delay degrades (it waits for the slowest surviving
    report every round) while buffered aggregation stays near-flat (a late
    update delays itself, not the round) — so at an equal simulated-time
    budget the buffered mode completes more rounds and reaches the target
    loss sooner. Emits one line per sweep point and saves
    ``fl_round_bench_churn.json``.
    """
    budget_s = 30.0 if fast else 90.0
    # per-device stats depend only on the fault-free topology/data; compute
    # once and thread into every point (see _churn_run).
    stats = Simulation(Scenario(
        model="mlp", rounds=1, seed=0, alpha=0.2, max_dataset=250,
        net=NetworkConfig(n_gateways=GATEWAYS, n_devices=DEVICES,
                          n_channels=3))).stats

    points = []
    for churn in CHURN_RATES:
        for frac, scale in STRAGGLER_TAILS:
            for mode, buffer_k in CHURN_MODES:
                pt = _churn_run(churn, frac, scale, buffer_k, budget_s,
                                stats)
                points.append(pt)
                emit(f"fl_churn{churn}_tail{scale}_{mode}_delay_s",
                     pt["mean_round_delay"],   # simulated seconds (see name)
                     f"rounds={pt['rounds_in_budget']};"
                     f"loss_at_budget={pt['loss_at_budget']:.3f};"
                     f"delay_to_target="
                     f"{pt['delay_to_target'] or float('nan'):.1f};"
                     f"stale_max={pt['staleness_max']}")

    def _pt(mode, scale, churn):
        return next(p for p in points
                    if p["churn"] == churn and p["straggler_scale"] == scale
                    and p["mode"] == mode)

    for churn in CHURN_RATES:
        for frac, scale in STRAGGLER_TAILS:
            sync, asyn = (_pt("sync_barrier", scale, churn),
                          _pt("async_buffered", scale, churn))
            print(f"  churn={churn:.1f} tail={scale:.1f}: round delay "
                  f"sync {sync['mean_round_delay']:.2f}s vs async "
                  f"{asyn['mean_round_delay']:.2f}s | loss@{budget_s:.0f}s "
                  f"{sync['loss_at_budget']:.3f} vs "
                  f"{asyn['loss_at_budget']:.3f} | rounds "
                  f"{sync['rounds_in_budget']} vs "
                  f"{asyn['rounds_in_budget']}")

    # the headline claims, asserted so a regression fails the bench. Growth
    # is measured *additively* (seconds of extra delay per round as the
    # tail goes 0 -> 3.0x): the buffered mode's tail-free delay is near
    # zero (the backlog always holds already-landed arrivals), so a ratio
    # would explode off a tiny base even while the absolute delay stays
    # flat — which is the whole point.
    sync_growth = (_pt("sync_barrier", 3.0, 0.0)["mean_round_delay"]
                   - _pt("sync_barrier", 0.0, 0.0)["mean_round_delay"])
    async_growth = (_pt("async_buffered", 3.0, 0.0)["mean_round_delay"]
                    - _pt("async_buffered", 0.0, 0.0)["mean_round_delay"])
    print(f"  straggler tail 0 -> 3.0x: sync delay +{sync_growth:.2f}s per "
          f"round, async +{async_growth:.2f}s")
    assert sync_growth > 2.0 * async_growth, \
        "buffered aggregation no longer absorbs the straggler tail"
    for churn in CHURN_RATES:        # buffering always wins on round delay
        for _, scale in STRAGGLER_TAILS:
            assert (_pt("async_buffered", scale, churn)["mean_round_delay"]
                    < _pt("sync_barrier", scale, churn)["mean_round_delay"])
    assert (_pt("async_buffered", 3.0, 0.3)["loss_at_budget"]
            < _pt("sync_barrier", 3.0, 0.3)["loss_at_budget"]), \
        "buffered aggregation lost its loss-per-simulated-second edge"

    save_json("fl_round_bench_churn", {
        "budget_s": budget_s, "devices": DEVICES, "gateways": GATEWAYS,
        "target_loss": TARGET_LOSS,
        "sync_tail_delay_growth_s": sync_growth,
        "async_tail_delay_growth_s": async_growth,
        "sweep": points,
    })


def fused_main(fast: bool = True) -> None:
    """Fused simulation loop vs the stepwise round loop, plus the sweep farm.

    Both paths run the identical trajectory (the parity matrix in
    ``tests/test_fused_sim.py`` pins them bit-identical on queues/RNG), so
    the rounds/sec ratio isolates the loop structure: per-round dispatch +
    host repackaging vs one decide scan + one train scan. Compile counts
    are asserted in-bench via the TRACE_COUNTS deltas: a warm fused run
    retraces nothing, and the whole seeds x V sweep grid stays one
    executable across value changes.

    Workload: 20 devices (the paper topology's device count) spread over
    10 gateways contending for 2 channels — the channel-scarce regime DDSRA
    targets, and the one where the simulation loop itself (per-round decide
    dispatch, decision repackaging, per-gateway loss syncs) is the cost
    rather than raw training FLOPs. A narrow MLP + one local iteration
    keeps per-round train compute at the few-ms scale of real edge rounds;
    heavier models push both paths into compute-bound territory where the
    loop structure (correctly) stops mattering. Steady-state = best of
    ``REPS`` timed passes after a warm pass.
    """
    rounds = 30 if fast else 60
    reps = 5
    # traced data plane: both paths sample batches with the counter-based
    # jax draws (identical trajectories — the traced parity tests pin
    # them), but only the fused path gets to keep them on device: its
    # batch phase is metadata-only, while stepwise still dispatches
    # per-round programs.
    sc = Scenario(model="mlp", mlp_hidden=(32,), rounds=rounds,
                  eval_every=rounds + 1, seed=0, alpha=0.03, k_iters=1,
                  max_dataset=200, policy="ddsra_jax", data_plane="traced",
                  net=NetworkConfig(n_gateways=10, n_devices=DEVICES,
                                    n_channels=2))
    sim = Simulation(sc)

    # -- warm both paths (compiles), then interleave the timed reps: load
    # on a shared box drifts over seconds, and timing every stepwise pass
    # before every fused pass folds that drift straight into the ratio.
    # Alternating passes exposes both paths to the same conditions;
    # best-of-reps keeps the steady-state floor of each.
    recs = list(sim.rounds())
    assert all(r.trained for r in recs), "degenerate bench: idle rounds"
    sim.reset()
    sim.fused_rounds()     # warm pass traces decide + train scans
    before = {k: d[k] for d, k in [(ddsra_jax.TRACE_COUNTS, "decide"),
                                   (ddsra_jax.TRACE_COUNTS, "round"),
                                   (cohort_lib.TRACE_COUNTS, "train_scan"),
                                   (cohort_lib.TRACE_COUNTS, "round")]}
    step_s, fused_s = [], []
    for _ in range(reps):
        sim.reset()
        with timed() as t_step:
            list(sim.rounds())
        step_s.append(t_step["s"])
        sim.reset()
        with timed() as t_fused:
            sim.fused_rounds()
        fused_s.append(t_fused["s"])
    step_rps = rounds / min(step_s)
    retraces = sum(d[k] - before[k]
                   for d, k in [(ddsra_jax.TRACE_COUNTS, "decide"),
                                (ddsra_jax.TRACE_COUNTS, "round"),
                                (cohort_lib.TRACE_COUNTS, "train_scan"),
                                (cohort_lib.TRACE_COUNTS, "round")])
    fused_rps = rounds / min(fused_s)
    speedup = fused_rps / step_rps

    emit("fl_fused_rounds_per_s", fused_rps,
         f"stepwise={step_rps:.2f};speedup={speedup:.2f}x;"
         f"retraces={retraces}")
    print(f"  {rounds}-round/{DEVICES}-device run: stepwise "
          f"{step_rps:.2f} rounds/s vs fused {fused_rps:.2f} rounds/s "
          f"-> {speedup:.2f}x ({retraces} retraces on the warm run)")
    assert retraces == 0, "warm fused run retraced a scan"
    assert speedup >= 3.0, \
        f"fused loop lost its >=3x rounds/sec edge ({speedup:.2f}x)"

    # -- the sweep farm: seeds x V as ONE compiled program -----------------
    seeds, v_values = [0, 1, 2], [0.01, 1.0, 100.0]
    sweep_rounds = rounds
    sim.sweep(v_values, seeds=seeds, rounds=sweep_rounds)        # warm
    before_sweep = ddsra_jax.TRACE_COUNTS["sweep"]
    with timed() as t_sweep:
        res = sim.sweep([0.05, 5.0, 500.0], seeds=[3, 4, 5],
                        rounds=sweep_rounds)
    sweep_retraces = ddsra_jax.TRACE_COUNTS["sweep"] - before_sweep
    lanes = len(seeds) * len(v_values)
    lane_rps = lanes * sweep_rounds / t_sweep["s"]
    emit("fl_sweep_lane_rounds_per_s", lane_rps,
         f"lanes={lanes};rounds={sweep_rounds};"
         f"retraces={sweep_retraces}")
    print(f"  sweep farm: {lanes} (seed, V) lanes x {sweep_rounds} rounds "
          f"in {t_sweep['s']:.2f}s ({lane_rps:.1f} lane-rounds/s), "
          f"{sweep_retraces} retraces across value changes")
    assert sweep_retraces == 0, \
        "the seeds x V sweep stopped being one compiled program"
    assert res.taus.shape == (3, 3, sweep_rounds)

    # -- multi-policy grid: policies x seeds x V as ONE program vs one
    # program per policy (the pre-PR-10 shape of the fig456 sweep) --------
    policies = ["ddsra_jax", "round_robin", "random", "delay_driven"]
    sim.sweep(v_values, seeds=seeds, rounds=sweep_rounds,
              policies=policies)                                 # warm
    before_mp = policy_sweep.TRACE_COUNTS["sweep"]
    with timed() as t_mp:
        res_mp = sim.sweep([0.05, 5.0, 500.0], seeds=[3, 4, 5],
                           rounds=sweep_rounds, policies=policies)
    mp_retraces = policy_sweep.TRACE_COUNTS["sweep"] - before_mp
    assert mp_retraces == 0, \
        "the multi-policy sweep stopped being one compiled program"
    assert res_mp.taus.shape == (len(policies), 3, 3, sweep_rounds)
    # per-policy baseline: same lanes as P single-policy programs (warm
    # each shape first so the comparison is wall-clock, not compile time)
    for p in policies:
        sim.sweep(v_values, seeds=seeds, rounds=sweep_rounds, policies=[p])
    with timed() as t_pp:
        for p in policies:
            sim.sweep([0.05, 5.0, 500.0], seeds=[3, 4, 5],
                      rounds=sweep_rounds, policies=[p])
    mp_speedup = t_pp["s"] / t_mp["s"]
    emit("fl_multi_policy_sweep_s", t_mp["s"],
         f"policies={len(policies)};per_policy_s={t_pp['s']:.2f};"
         f"speedup={mp_speedup:.2f}x;retraces={mp_retraces}")
    print(f"  multi-policy grid: {len(policies)} policies x {lanes} lanes "
          f"x {sweep_rounds} rounds in {t_mp['s']:.2f}s as ONE program vs "
          f"{t_pp['s']:.2f}s as per-policy programs ({mp_speedup:.2f}x)")

    save_json("fl_round_bench_fused", {
        "rounds": rounds, "devices": DEVICES,
        "gateways": sc.net.n_gateways, "channels": sc.net.n_channels,
        "data_plane": sc.data_plane,
        "stepwise_rounds_per_s": step_rps,
        "fused_rounds_per_s": fused_rps,
        "fused_speedup": speedup,
        "fused_retraces_warm": retraces,
        "sweep_lanes": lanes, "sweep_rounds": sweep_rounds,
        "sweep_s": t_sweep["s"],
        "sweep_lane_rounds_per_s": lane_rps,
        "sweep_retraces_across_value_changes": sweep_retraces,
        "multi_policy_policies": policies,
        "multi_policy_sweep_s": t_mp["s"],
        "per_policy_sweeps_s": t_pp["s"],
        "multi_policy_speedup": mp_speedup,
        "multi_policy_retraces": mp_retraces,
    })


# model-zoo bench points: one Scenario tweak per SplitModel family
MODEL_SCENARIOS = {
    "vgg": {"model": "vgg", "width_mult": 0.1},
    "transformer": {"model": "transformer", "seq_len": 16},
    "ssm": {"model": "ssm", "seq_len": 16},
}


def model_main(model: str, fast: bool = True) -> None:
    """Cohort round time for one model-zoo member (``--model NAME``)."""
    if model not in MODEL_SCENARIOS:
        raise SystemExit(
            f"unknown --model {model!r}; choose from {sorted(MODEL_SCENARIOS)}")
    rounds = 4 if fast else 10
    sc = Scenario(rounds=rounds, eval_every=rounds + 1, seed=0, alpha=0.2,
                  max_dataset=250, engine="cohort",
                  net=NetworkConfig(n_gateways=4, n_devices=12, n_channels=2),
                  **MODEL_SCENARIOS[model])
    sim = Simulation(sc)
    traces_before = cohort_lib.TRACE_COUNTS["round"]
    per_round, records = [], []
    it = sim.rounds("ddsra")
    for _ in range(rounds):
        with timed() as t:
            records.append(next(it))
        per_round.append(t["s"])
    traces = cohort_lib.TRACE_COUNTS["round"] - traces_before
    steady = per_round[1:] if rounds > 1 else per_round
    round_ms = sum(steady) * 1e3 / len(steady)
    emit(f"fl_model_{model}_round_ms", round_ms,
         f"blocks={sim.plan.n_blocks};cuts={len(sim.plan.valid_cuts)};"
         f"compile_s={per_round[0]:.1f};compiles={traces}")
    assert traces <= 1, f"{model} cohort step retraced across rounds"
    final_loss = float(np.mean(records[-1].losses))
    assert np.isfinite(final_loss), f"{model} training diverged"
    save_json(f"fl_round_bench_model_{model}", {
        "model": model, "rounds": rounds,
        "devices": sc.net.n_devices, "gateways": sc.net.n_gateways,
        "n_blocks": sim.plan.n_blocks,
        "valid_cuts": len(sim.plan.valid_cuts),
        "stats_s": sim.stats_seconds, "compile_round_s": per_round[0],
        "round_ms": round_ms, "compiles": traces,
        "final_loss": final_loss,
    })


def main(fast: bool = True, churn_sweep: bool = False,
         fused_sweep: bool = False, model: str | None = None) -> None:
    import jax
    jax.numpy.zeros(1).block_until_ready()   # generic runtime warmup

    if model is not None:
        model_main(model, fast=fast)
        return
    if churn_sweep:
        churn_main(fast=fast)
        return
    if fused_sweep:
        fused_main(fast=fast)
        return

    seq_stats_s, seq_run_s, seq_res = _simulate("sequential")

    traces_before = cohort_lib.TRACE_COUNTS["round"]
    co_stats_s, co_run_s, co_res = _simulate("cohort")
    traces = cohort_lib.TRACE_COUNTS["round"] - traces_before

    speedup = (seq_stats_s + seq_run_s) / (co_stats_s + co_run_s)
    run_speedup = seq_run_s / co_run_s
    stats_speedup = seq_stats_s / co_stats_s

    emit("fl_round_ms", co_run_s * 1e3 / ROUNDS,
         f"seq_ms={seq_run_s * 1e3 / ROUNDS:.1f};speedup={run_speedup:.1f}x;"
         f"cohort_compiles={traces}")
    emit("estimate_stats_ms", co_stats_s * 1e3,
         f"seq_ms={seq_stats_s * 1e3:.1f};speedup={stats_speedup:.1f}x")
    print(f"  {ROUNDS}-round/{DEVICES}-device simulation (stats + training):"
          f" cohort {co_stats_s + co_run_s:.2f}s vs sequential"
          f" {seq_stats_s + seq_run_s:.2f}s -> {speedup:.1f}x,"
          f" {traces} cohort-step compile(s)")
    assert traces <= 1, "cohort step retraced across rounds"
    # both engines must tell the same training story (parity is pinned
    # tightly in tests/test_cohort.py; this guards the bench itself)
    assert abs(seq_res.accuracy[-1] - co_res.accuracy[-1]) < 0.05

    # -- scaling sweep: {20, 64, 128} devices x engine x slot layout -------
    n_mesh = len(jax.devices())
    sweep = []
    for n_dev, n_gw, n_ch in SCALE_SWEEP:
        rounds = (5 if n_dev <= 20 else 4) if fast else 10
        for engine, tiers in SCALE_ENGINES:
            rec = _scale_run(n_dev, n_gw, n_ch, engine, tiers, rounds)
            sweep.append(rec)
            emit(f"fl_scale_{n_dev}dev_{engine}_t{tiers}_round_ms",
                 rec["round_ms"],
                 f"pad_ratio={rec['pad_ratio']:.2f};"
                 f"compile_s={rec['compile_round_s']:.1f};"
                 f"mesh={n_mesh}")
        flat = next(r for r in sweep if r["devices"] == n_dev
                    and r["engine"] == "cohort" and r["tiers"] == 1)
        tier = next(r for r in sweep if r["devices"] == n_dev
                    and r["engine"] == "cohort" and r["tiers"] == 4)
        saved = 1.0 - tier["padded_samples"] / flat["padded_samples"]
        print(f"  {n_dev:3d} devices: tiered slots drop padded samples "
              f"{flat['padded_samples']:.0f} -> {tier['padded_samples']:.0f} "
              f"(-{saved:.0%}); pad ratio {flat['pad_ratio']:.2f} -> "
              f"{tier['pad_ratio']:.2f}")
        assert tier["padded_samples"] <= flat["padded_samples"], \
            "tiered layout must not pad more than the single-width contract"

    save_json("fl_round_bench", {
        "rounds": ROUNDS, "devices": DEVICES,
        "cohort_stats_s": co_stats_s, "cohort_run_s": co_run_s,
        "sequential_stats_s": seq_stats_s, "sequential_run_s": seq_run_s,
        "speedup": speedup, "run_speedup": run_speedup,
        "stats_speedup": stats_speedup, "cohort_compiles": traces,
        "cohort_mesh_devices": n_mesh,
        "scale_sweep": sweep,
    })


if __name__ == "__main__":
    main()
