"""Cohort engine vs the seed sequential path: 10-round, 20-device FL sim.

The seed trainer ran devices one-by-one — a jitted step per device per local
epoch, retraced for every distinct (partition point, batch shape) pair, with
sequential per-sample-grad estimation at init. The cohort engine fuses each
round (and the whole stats estimation) into one XLA program each.

Both engines run in this process back-to-back on the same scheduler trace
and dataset, so the ratio is robust to machine noise. "Simulation" = stats
estimation + the 10-round training loop (dataset synthesis is identical
common setup for both). Values are emitted in MILLISECONDS, as named.

NOTE the baseline here is conservative: the in-tree sequential engine
already benefits from this PR's shared speedups (vectorized DDSRA partition
search and Hungarian inner loop, jitted FedAvg, cached eval forward), which
the seed did not have. Measured against the untouched seed commit, the same
simulation is >5x slower than the cohort engine on a 2-core CPU box (seed
32.8s vs cohort 5.0s when this bench was written); the emitted speedup vs
the improved in-tree sequential path is the lower bound.

Part two sweeps cohort scale: {20, 64, 128} devices x engine
(single-width cohort, 4-tier cohort, 4-tier sharded cohort), reporting
per-round wall time and the padded-vs-real sample ratio — the tiered slot
layout recovers most of the batch-padding waste of the single-width
contract, and the sharded engine splits the slot axis over the
``"cohort"`` mesh (1 device on the CPU dev box; run under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` to see an actual
mesh).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, save_json, timed
from repro.core.network import NetworkConfig
from repro.fl import Scenario, Simulation
from repro.fl import cohort as cohort_lib

ROUNDS, DEVICES, GATEWAYS = 10, 20, 5

# (n_devices, n_gateways, n_channels) for the scaling sweep
SCALE_SWEEP = [(20, 5, 3), (64, 8, 4), (128, 16, 8)]
# (engine, tiers) variants: single-width cohort is the historical contract
SCALE_ENGINES = [("cohort", 1), ("cohort", 4), ("sharded", 4)]


def _simulate(engine: str):
    sc = Scenario(model="mlp", rounds=ROUNDS, seed=0, engine=engine,
                  net=NetworkConfig(n_gateways=GATEWAYS, n_devices=DEVICES,
                                    n_channels=3))
    sim = Simulation(sc)                  # init runs estimate_stats (timed)
    with timed() as t_run:
        res = sim.run("ddsra")
    return sim.stats_seconds, t_run["s"], res


def _scale_run(n_dev: int, n_gw: int, n_ch: int, engine: str, tiers: int,
               rounds: int):
    """One sweep point: short ddsra-scheduled sim at the given scale.

    Rounds are timed individually; ``round_ms`` is the mean over the
    steady-state rounds (the first round pays XLA compilation and the last
    pays the accuracy eval, so both are excluded)."""
    sc = Scenario(model="mlp", rounds=rounds, eval_every=rounds + 1, seed=0,
                  engine=engine, tiers=tiers, alpha=0.2, max_dataset=250,
                  net=NetworkConfig(n_gateways=n_gw, n_devices=n_dev,
                                    n_channels=n_ch))
    sim = Simulation(sc)
    per_round, records = [], []
    it = sim.rounds("ddsra")
    for _ in range(rounds):
        with timed() as t:
            records.append(next(it))
        per_round.append(t["s"])
    steady = per_round[1:-1] if rounds > 2 else per_round[-1:]
    real = sim.padding_stats["real_samples"]
    padded = sim.padding_stats["padded_samples"]
    return {
        "devices": n_dev, "engine": engine, "tiers": tiers,
        "rounds": rounds, "stats_s": sim.stats_seconds,
        "run_s": sum(per_round), "compile_round_s": per_round[0],
        "round_ms": sum(steady) * 1e3 / len(steady),
        "real_samples": real, "padded_samples": padded,
        "pad_ratio": padded / max(real, 1.0),
        "final_loss": float(np.mean(records[-1].losses)),
    }


def main(fast: bool = True) -> None:
    import jax
    jax.numpy.zeros(1).block_until_ready()   # generic runtime warmup

    seq_stats_s, seq_run_s, seq_res = _simulate("sequential")

    traces_before = cohort_lib.TRACE_COUNTS["round"]
    co_stats_s, co_run_s, co_res = _simulate("cohort")
    traces = cohort_lib.TRACE_COUNTS["round"] - traces_before

    speedup = (seq_stats_s + seq_run_s) / (co_stats_s + co_run_s)
    run_speedup = seq_run_s / co_run_s
    stats_speedup = seq_stats_s / co_stats_s

    emit("fl_round_ms", co_run_s * 1e3 / ROUNDS,
         f"seq_ms={seq_run_s * 1e3 / ROUNDS:.1f};speedup={run_speedup:.1f}x;"
         f"cohort_compiles={traces}")
    emit("estimate_stats_ms", co_stats_s * 1e3,
         f"seq_ms={seq_stats_s * 1e3:.1f};speedup={stats_speedup:.1f}x")
    print(f"  {ROUNDS}-round/{DEVICES}-device simulation (stats + training):"
          f" cohort {co_stats_s + co_run_s:.2f}s vs sequential"
          f" {seq_stats_s + seq_run_s:.2f}s -> {speedup:.1f}x,"
          f" {traces} cohort-step compile(s)")
    assert traces <= 1, "cohort step retraced across rounds"
    # both engines must tell the same training story (parity is pinned
    # tightly in tests/test_cohort.py; this guards the bench itself)
    assert abs(seq_res.accuracy[-1] - co_res.accuracy[-1]) < 0.05

    # -- scaling sweep: {20, 64, 128} devices x engine x slot layout -------
    n_mesh = len(jax.devices())
    sweep = []
    for n_dev, n_gw, n_ch in SCALE_SWEEP:
        rounds = (5 if n_dev <= 20 else 4) if fast else 10
        for engine, tiers in SCALE_ENGINES:
            rec = _scale_run(n_dev, n_gw, n_ch, engine, tiers, rounds)
            sweep.append(rec)
            emit(f"fl_scale_{n_dev}dev_{engine}_t{tiers}_round_ms",
                 rec["round_ms"],
                 f"pad_ratio={rec['pad_ratio']:.2f};"
                 f"compile_s={rec['compile_round_s']:.1f};"
                 f"mesh={n_mesh}")
        flat = next(r for r in sweep if r["devices"] == n_dev
                    and r["engine"] == "cohort" and r["tiers"] == 1)
        tier = next(r for r in sweep if r["devices"] == n_dev
                    and r["engine"] == "cohort" and r["tiers"] == 4)
        saved = 1.0 - tier["padded_samples"] / flat["padded_samples"]
        print(f"  {n_dev:3d} devices: tiered slots drop padded samples "
              f"{flat['padded_samples']:.0f} -> {tier['padded_samples']:.0f} "
              f"(-{saved:.0%}); pad ratio {flat['pad_ratio']:.2f} -> "
              f"{tier['pad_ratio']:.2f}")
        assert tier["padded_samples"] <= flat["padded_samples"], \
            "tiered layout must not pad more than the single-width contract"

    save_json("fl_round_bench", {
        "rounds": ROUNDS, "devices": DEVICES,
        "cohort_stats_s": co_stats_s, "cohort_run_s": co_run_s,
        "sequential_stats_s": seq_stats_s, "sequential_run_s": seq_run_s,
        "speedup": speedup, "run_speedup": run_speedup,
        "stats_speedup": stats_speedup, "cohort_compiles": traces,
        "cohort_mesh_devices": n_mesh,
        "scale_sweep": sweep,
    })


if __name__ == "__main__":
    main()
