"""Cohort engine vs the seed sequential path: 10-round, 20-device FL sim.

The seed trainer ran devices one-by-one — a jitted step per device per local
epoch, retraced for every distinct (partition point, batch shape) pair, with
sequential per-sample-grad estimation at init. The cohort engine fuses each
round (and the whole stats estimation) into one XLA program each.

Both engines run in this process back-to-back on the same scheduler trace
and dataset, so the ratio is robust to machine noise. "Simulation" = stats
estimation + the 10-round training loop (dataset synthesis is identical
common setup for both). Values are emitted in MILLISECONDS, as named.

NOTE the baseline here is conservative: the in-tree sequential engine
already benefits from this PR's shared speedups (vectorized DDSRA partition
search and Hungarian inner loop, jitted FedAvg, cached eval forward), which
the seed did not have. Measured against the untouched seed commit, the same
simulation is >5x slower than the cohort engine on a 2-core CPU box (seed
32.8s vs cohort 5.0s when this bench was written); the emitted speedup vs
the improved in-tree sequential path is the lower bound.
"""
from __future__ import annotations

from benchmarks.common import emit, save_json, timed
from repro.core.network import NetworkConfig
from repro.fl import Scenario, Simulation
from repro.fl import cohort as cohort_lib

ROUNDS, DEVICES, GATEWAYS = 10, 20, 5


def _simulate(engine: str):
    sc = Scenario(model="mlp", rounds=ROUNDS, seed=0, engine=engine,
                  net=NetworkConfig(n_gateways=GATEWAYS, n_devices=DEVICES,
                                    n_channels=3))
    sim = Simulation(sc)                  # init runs estimate_stats (timed)
    with timed() as t_run:
        res = sim.run("ddsra")
    return sim.stats_seconds, t_run["s"], res


def main(fast: bool = True) -> None:
    import jax
    jax.numpy.zeros(1).block_until_ready()   # generic runtime warmup

    seq_stats_s, seq_run_s, seq_res = _simulate("sequential")

    traces_before = cohort_lib.TRACE_COUNTS["round"]
    co_stats_s, co_run_s, co_res = _simulate("cohort")
    traces = cohort_lib.TRACE_COUNTS["round"] - traces_before

    speedup = (seq_stats_s + seq_run_s) / (co_stats_s + co_run_s)
    run_speedup = seq_run_s / co_run_s
    stats_speedup = seq_stats_s / co_stats_s

    emit("fl_round_ms", co_run_s * 1e3 / ROUNDS,
         f"seq_ms={seq_run_s * 1e3 / ROUNDS:.1f};speedup={run_speedup:.1f}x;"
         f"cohort_compiles={traces}")
    emit("estimate_stats_ms", co_stats_s * 1e3,
         f"seq_ms={seq_stats_s * 1e3:.1f};speedup={stats_speedup:.1f}x")
    print(f"  {ROUNDS}-round/{DEVICES}-device simulation (stats + training):"
          f" cohort {co_stats_s + co_run_s:.2f}s vs sequential"
          f" {seq_stats_s + seq_run_s:.2f}s -> {speedup:.1f}x,"
          f" {traces} cohort-step compile(s)")
    assert traces <= 1, "cohort step retraced across rounds"
    # both engines must tell the same training story (parity is pinned
    # tightly in tests/test_cohort.py; this guards the bench itself)
    assert abs(seq_res.accuracy[-1] - co_res.accuracy[-1]) < 0.05
    save_json("fl_round_bench", {
        "rounds": ROUNDS, "devices": DEVICES,
        "cohort_stats_s": co_stats_s, "cohort_run_s": co_run_s,
        "sequential_stats_s": seq_stats_s, "sequential_run_s": seq_run_s,
        "speedup": speedup, "run_speedup": run_speedup,
        "stats_speedup": stats_speedup, "cohort_compiles": traces,
    })


if __name__ == "__main__":
    main()
