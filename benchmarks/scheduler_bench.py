"""DDSRA decide latency: numpy oracle vs the jitted control plane.

Sweeps the network scale (M gateways x J channels, N devices) and times a
full scheduling decision — the per-(m, j) BCD solves, the lambda-cap
Hungarian sweep and the queue update — for both implementations on
identical host-drawn ChannelStates:

* ``numpy``  — ``repro.core.ddsra.ddsra_round`` (Algorithm 1 as written:
  Python loops over (m, j), scalar bisections, Python Kuhn-Munkres);
* ``jitted`` — ``repro.core.ddsra_jax.DDSRAPlan.round`` (vmap over (m, j),
  fixed-trip lax.scan bisections, vmapped Hungarian cap sweep, x64).

The jitted path must compile **exactly once per network shape** across all
timed rounds — the artifact records the jit cache delta per size and the
bench fails loudly if any round retraced.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, save_json, timed
from repro.core import costmodel as cm
from repro.core import ddsra_jax
from repro.core.ddsra import Workload, ddsra_round
from repro.core.ddsra_jax import DDSRAPlan
from repro.core.network import Network, NetworkConfig
from repro.core.participation import participation_rates
from repro.models.vgg import mlp_layer_costs

# (M gateways, J channels, N devices); the last entry is the 128-device
# M x J sweep scale from the PR 3 cohort benchmarks
SIZES = [(6, 3, 12), (16, 8, 32), (32, 12, 64), (64, 16, 128)]


def _workload(n_devices: int, seed: int) -> Workload:
    layers = mlp_layer_costs((3072, 512, 512, 10))
    o, g = cm.flops_vector(layers), cm.mem_vector(layers, batch=50)
    rng = np.random.default_rng(seed)
    d_tilde = np.maximum(
        (rng.uniform(0, 2000, n_devices) * 0.05).astype(int), 4)
    return Workload(o, g, cm.model_size_bytes(layers), 5,
                    d_tilde.astype(float))


def _decide_rounds(fn, n_gateways, states):
    """Time fn(st, queues) over the drawn states, carrying the queues."""
    q = np.zeros(n_gateways)
    t0 = time.perf_counter()
    for st in states:
        dec = fn(st, q)
        q = dec.queues
    return (time.perf_counter() - t0) / len(states), q


def run(sizes=SIZES, rounds: int = 5, numpy_rounds: int = 2, seed: int = 0,
        v: float = 10.0):
    out = {"rounds": rounds, "sweep": []}
    for m_gw, j_ch, n_dev in sizes:
        net = Network(NetworkConfig(n_gateways=m_gw, n_channels=j_ch,
                                    n_devices=n_dev),
                      np.random.default_rng(seed))
        w = _workload(n_dev, seed)
        gamma = participation_rates(
            np.random.default_rng(seed + 1).uniform(0.5, 2, m_gw), j_ch)
        states = [net.draw() for _ in range(rounds)]

        plan = DDSRAPlan.build(w, net)
        plan.round(states[0], np.zeros(m_gw), gamma, v)   # compile
        compiles0 = ddsra_jax._round_jit._cache_size()
        jit_s, _ = _decide_rounds(
            lambda st, q: plan.round(st, q, gamma, v), m_gw, states)
        compiles = ddsra_jax._round_jit._cache_size() - compiles0
        if compiles != 0:
            raise RuntimeError(
                f"jitted scheduler retraced {compiles}x at "
                f"M={m_gw} J={j_ch} (expected 1 compile across rounds)")

        np_s, q_np = _decide_rounds(
            lambda st, q: ddsra_round(w, net, st, q, gamma, v),
            m_gw, states[:numpy_rounds])

        # the two paths must agree on the queues they stepped through
        parity = bool(np.allclose(
            q_np, _decide_rounds(
                lambda st, q: plan.round(st, q, gamma, v),
                m_gw, states[:numpy_rounds])[1], atol=1e-9))

        entry = {"m": m_gw, "j": j_ch, "n": n_dev,
                 "numpy_ms": np_s * 1e3, "jitted_ms": jit_s * 1e3,
                 "speedup": np_s / jit_s, "compiles_across_rounds": 1,
                 "queue_parity": parity}
        out["sweep"].append(entry)
        print(f"  M={m_gw:3d} J={j_ch:2d} N={n_dev:3d}  "
              f"numpy {entry['numpy_ms']:9.1f}ms  "
              f"jitted {entry['jitted_ms']:7.1f}ms  "
              f"speedup {entry['speedup']:6.1f}x  parity={parity}")
    return out


def main(fast: bool = True):
    sizes = SIZES[:2] if fast else SIZES
    with timed() as t:
        res = run(sizes=sizes)
    save_json("scheduler_bench", res)
    top = res["sweep"][-1]
    emit("ddsra_decide_latency", t["s"] * 1e6,
         f"M={top['m']}xJ={top['j']};speedup={top['speedup']:.1f}x;"
         f"compiles=1")


if __name__ == "__main__":
    main(fast=False)
