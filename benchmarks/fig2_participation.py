"""Paper Fig. 2: derived vs experimental device-specific participation rate.

Derived:      Gamma_m from the Theorem-1 divergence bound (Eq. 13).
Experimental: Gamma_m recomputed from the OBSERVED divergence
              ||w_hat_m^t - v^{K,t}|| between each shop floor's aggregate and
              a centralized-GD twin trained from the same per-round init.
The claim validated: the two track each other (same ranking, similar values).
"""
from __future__ import annotations

import numpy as np
import jax

from benchmarks.common import emit, save_json, timed
from repro.core.participation import participation_rates
from repro.fl import FLConfig, FLTrainer
from repro.fl.data import sample_batch
from repro.fl.roles import fedavg
from repro.fl import split as split_lib
from repro.models import vgg


def run(rounds: int = 8, model: str = "mlp", seed: int = 0):
    cfg = FLConfig(model=model, rounds=rounds, seed=seed)
    tr = FLTrainer(cfg)
    plan = tr.plan
    params = tr.bs.params
    n_ch = tr.net.cfg.n_channels
    m_gw = tr.net.cfg.n_gateways
    rng = np.random.default_rng(seed + 7)

    obs_div = np.zeros(m_gw)
    for _ in range(rounds):
        # pooled batch for the centralized twin
        xs, ys = [], []
        gw_models, gw_weights = [], []
        for m in range(m_gw):
            local_models, local_w = [], []
            for dev in tr.gateways[m].devices:
                x, y = sample_batch(rng, tr.ds, dev.idx, dev.d_tilde)
                xs.append(x); ys.append(y)
                w_n, _ = split_lib.local_train(plan, params, x, y,
                                               len(plan) // 2, cfg.k_iters, cfg.lr)
                local_models.append(w_n); local_w.append(dev.d_tilde)
            gw_models.append(fedavg(local_models, np.asarray(local_w, float)))
            gw_weights.append(sum(local_w))
        # centralized GD twin from the same init
        xc, yc = np.concatenate(xs), np.concatenate(ys)
        v = params
        for _ in range(cfg.k_iters):
            v, _ = split_lib.split_sgd_step(plan, v, (xc, yc), len(plan) // 2,
                                            np.float32(cfg.lr))
        v_flat = np.asarray(split_lib.flat_params(v))
        for m in range(m_gw):
            w_flat = np.asarray(split_lib.flat_params(gw_models[m]))
            obs_div[m] += np.linalg.norm(w_flat - v_flat) / rounds
        params = fedavg(gw_models, np.asarray(gw_weights, float))

    gamma_exp = participation_rates(obs_div, n_ch)
    res = {
        "derived": tr.gamma.tolist(),
        "experimental": gamma_exp.tolist(),
        "phi_derived": tr.phi.tolist(),
        "phi_observed": obs_div.tolist(),
        "rank_corr": float(np.corrcoef(
            np.argsort(np.argsort(tr.gamma)),
            np.argsort(np.argsort(gamma_exp)))[0, 1]),
        "top1_match": bool(int(np.argmax(tr.gamma)) == int(np.argmax(gamma_exp))),
    }
    save_json("fig2_participation", res)
    return res


def main(fast: bool = True):
    with timed() as t:
        res = run(rounds=8 if fast else 16)
    emit("fig2_participation_rate", t["s"] * 1e6,
         f"rank_corr={res['rank_corr']:.2f};top1_match={res['top1_match']}")
    print("  derived     ", np.round(res["derived"], 2))
    print("  experimental", np.round(res["experimental"], 2))


if __name__ == "__main__":
    main()
