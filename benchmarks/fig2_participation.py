"""Paper Fig. 2: derived vs experimental device-specific participation rate.

Derived:      Gamma_m from the Theorem-1 divergence bound (Eq. 13).
Experimental: Gamma_m recomputed from the OBSERVED divergence
              ||w_hat_m^t - v^{K,t}|| between each shop floor's aggregate and
              a centralized-GD twin trained from the same per-round init.
The claim validated: the two track each other (same ranking, similar values).

The per-device round loop runs through the cohort engine's fused
``shop_floor_round`` (one XLA program per round, per-gateway models surfaced
from the same program), replacing the hand-rolled device-by-device loop; the
batch stream and numerics match the sequential loop (parity pinned in
tests/test_cohort.py / tests/test_sim.py).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, save_json, timed
from repro.core.participation import participation_rates
from repro.fl import Scenario, Simulation
from repro.fl import split as split_lib


def run(rounds: int = 8, model: str = "mlp", seed: int = 0):
    sim = Simulation(Scenario(model=model, rounds=rounds, seed=seed))
    plan = sim.plan
    params = sim.params
    n_ch = sim.net.cfg.n_channels
    m_gw = sim.net.cfg.n_gateways
    rng = np.random.default_rng(seed + 7)

    # all devices train every round at the mid cut, in shop-floor order
    # (gateway 0's devices first — the order the sequential loop sampled in)
    device_ids = [dev.idx for gw in sim.gateways for dev in gw.devices]
    l_n = np.full(sim.net.cfg.n_devices, plan.n_blocks // 2, dtype=int)

    obs_div = np.zeros(m_gw)
    for _ in range(rounds):
        new_global, gw_models, _, batch = sim.engine.shop_floor_round(
            sim, device_ids, l_n, params=params, rng=rng)
        # centralized GD twin from the same init, on the pooled device batches
        valid = batch.mask[device_ids].astype(bool)
        xc = np.concatenate([batch.x[n][valid[i]]
                             for i, n in enumerate(device_ids)])
        yc = np.concatenate([batch.y[n][valid[i]]
                             for i, n in enumerate(device_ids)])
        v = params
        for _ in range(sim.scenario.k_iters):
            v, _ = split_lib.split_sgd_step(plan, v, (xc, yc), plan.n_blocks // 2,
                                            np.float32(sim.scenario.lr))
        v_flat = np.asarray(split_lib.flat_params(v))
        for m in range(m_gw):
            w_flat = np.asarray(split_lib.flat_params(
                [{k: a[m] for k, a in layer.items()} for layer in gw_models]))
            obs_div[m] += np.linalg.norm(w_flat - v_flat) / rounds
        params = new_global

    gamma_exp = participation_rates(obs_div, n_ch)
    res = {
        "derived": sim.gamma.tolist(),
        "experimental": gamma_exp.tolist(),
        "phi_derived": sim.phi.tolist(),
        "phi_observed": obs_div.tolist(),
        "rank_corr": float(np.corrcoef(
            np.argsort(np.argsort(sim.gamma)),
            np.argsort(np.argsort(gamma_exp)))[0, 1]),
        "top1_match": bool(int(np.argmax(sim.gamma)) == int(np.argmax(gamma_exp))),
    }
    save_json("fig2_participation", res)
    return res


def main(fast: bool = True):
    with timed() as t:
        res = run(rounds=8 if fast else 16)
    emit("fig2_participation_rate", t["s"] * 1e6,
         f"rank_corr={res['rank_corr']:.2f};top1_match={res['top1_match']}")
    print("  derived     ", np.round(res["derived"], 2))
    print("  experimental", np.round(res["experimental"], 2))


if __name__ == "__main__":
    main()
