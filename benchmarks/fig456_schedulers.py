"""Paper Figs. 4/5/6: DDSRA vs the four baselines — test accuracy vs rounds,
cumulative training delay, and per-gateway participation rates.

Claims validated (relative orderings, synthetic data):
  * DDSRA >= baselines on final accuracy (Fig. 4)
  * DDSRA cumulative delay << Loss-Driven; slightly above Delay-Driven (Fig. 5)
  * DDSRA participation tracks the derived Gamma_m; baselines starve
    slow/low-loss gateways (Fig. 6)
  * smaller V -> better accuracy, higher delay (Theorem 2 direction, Fig. 4/5)

Every policy runs from ``Simulation.reset()`` — identical model init, batch
draws AND channel-state sequence (the pre-sim.reset() version of this sweep
reset params/batch RNG but not the Network RNG, so schedulers were compared
on different channel realizations).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, save_json, timed
from repro.fl import Scenario, Simulation

# ddsra_jax is the jitted control plane (repro.core.ddsra_jax); it must
# land on the same curves as ddsra — the sweep doubles as a parity check
SCHEDS = ["ddsra", "ddsra_jax", "random", "round_robin", "loss_driven",
          "delay_driven"]


def run(rounds: int = 30, model: str = "mlp", v: float = 0.01, seed: int = 0,
        schedulers=None, width_mult: float = 0.25):
    sim = Simulation(Scenario(model=model, width_mult=width_mult,
                              rounds=rounds, v=v, seed=seed,
                              eval_every=max(rounds // 6, 1)))
    results = {}
    for name in (schedulers or SCHEDS):
        sim.reset()                     # same init, data and channel draws
        res = sim.run(name)
        results[name] = {
            "accuracy": res.accuracy,
            "acc_rounds": res.acc_rounds,
            "cum_delay": res.cum_delay[-1],
            "delay_curve": res.cum_delay[:: max(rounds // 10, 1)],
            "participation": res.participation.mean(axis=0).tolist(),
            "failures": res.failures,
        }
    results["gamma_targets"] = sim.gamma.tolist()
    return results


def main(fast: bool = True):
    rounds = 20 if fast else 60
    with timed() as t:
        res = run(rounds=rounds)
    save_json("fig456_schedulers", res)
    accs = {k: v["accuracy"][-1] for k, v in res.items() if k != "gamma_targets"}
    delays = {k: v["cum_delay"] for k, v in res.items() if k != "gamma_targets"}
    best = max(accs, key=accs.get)
    emit("fig4_accuracy_vs_schedulers", t["s"] * 1e6,
         f"best={best};ddsra_acc={accs['ddsra']:.3f}")
    for k in accs:
        print(f"  {k:13s} acc {accs[k]:.3f}  cum_delay {delays[k]:9.1f}s "
              f"fail {res[k]['failures']:2d}  part {np.round(res[k]['participation'], 2)}")
    print(f"  gamma targets {np.round(res['gamma_targets'], 2)}")
    emit("fig5_delay_ddsra_vs_lossdriven", t["s"] * 1e6,
         f"ratio={delays['ddsra'] / max(delays.get('loss_driven', 1), 1e-9):.2f}")


if __name__ == "__main__":
    main()
