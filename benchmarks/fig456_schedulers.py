"""Paper Figs. 4/5/6: DDSRA vs the four baselines — test accuracy vs rounds,
cumulative training delay, and per-gateway participation rates.

Claims validated (relative orderings, synthetic data):
  * DDSRA >= baselines on final accuracy (Fig. 4)
  * DDSRA cumulative delay << Loss-Driven; slightly above Delay-Driven (Fig. 5)
  * DDSRA participation tracks the derived Gamma_m; baselines starve
    slow/low-loss gateways (Fig. 6)
  * smaller V -> better accuracy, higher delay (Theorem 2 direction, Fig. 4/5)

Every policy runs from ``Simulation.reset()`` — identical model init, batch
draws AND channel-state sequence (the pre-sim.reset() version of this sweep
reset params/batch RNG but not the Network RNG, so schedulers were compared
on different channel realizations).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, save_json, timed
from repro.fl import Scenario, Simulation

# ddsra_jax is the jitted control plane (repro.core.ddsra_jax); it must
# land on the same curves as ddsra — the sweep doubles as a parity check
SCHEDS = ["ddsra", "ddsra_jax", "random", "round_robin", "loss_driven",
          "delay_driven"]


def run(rounds: int = 30, model: str = "mlp", v: float = 0.01, seed: int = 0,
        schedulers=None, width_mult: float = 0.25):
    sim = Simulation(Scenario(model=model, width_mult=width_mult,
                              rounds=rounds, v=v, seed=seed,
                              eval_every=max(rounds // 6, 1)))
    results = {}
    for name in (schedulers or SCHEDS):
        sim.reset()                     # same init, data and channel draws
        res = sim.run(name)
        results[name] = {
            "accuracy": res.accuracy,
            "acc_rounds": res.acc_rounds,
            "cum_delay": res.cum_delay[-1],
            "delay_curve": res.cum_delay[:: max(rounds // 10, 1)],
            "participation": res.participation.mean(axis=0).tolist(),
            "failures": res.failures,
        }
    results["gamma_targets"] = sim.gamma.tolist()
    return results


# the traced-decide subset of SCHEDS, i.e. every policy that can ride the
# one-program sweep grid (repro.core.policy_sweep.POLICY_KINDS); the ddsra
# host oracle and loss_driven (needs realized losses) stay stepwise-only
GRID_SCHEDS = ["ddsra_jax", "round_robin", "random", "delay_driven"]


def grid(rounds: int = 30, seeds=(0, 1, 2), v: float = 0.01,
         width_mult: float = 0.25):
    """The Fig. 5/6 scheduling claims (cumulative delay + per-gateway
    participation) over the whole policies x seeds grid as ONE compiled
    program (``Simulation.sweep(policies=...)``), timed against the
    pre-PR-10 shape of this sweep — one compiled program per policy.

    Accuracy (Fig. 4) needs actual training and the ``ddsra`` host oracle,
    so it keeps the stepwise runs in :func:`run`; the grid covers the
    decide-plane figures, where multi-seed error bars are cheap."""
    sim = Simulation(Scenario(model="mlp", width_mult=width_mult,
                              rounds=rounds, v=v, seed=seeds[0],
                              eval_every=rounds + 1))
    seeds = list(seeds)
    sim.sweep([v], seeds=seeds, rounds=rounds, policies=GRID_SCHEDS)  # warm
    with timed() as t_one:
        res = sim.sweep([v], seeds=seeds, rounds=rounds,
                        policies=GRID_SCHEDS)
    for p in GRID_SCHEDS:                                             # warm
        sim.sweep([v], seeds=seeds, rounds=rounds, policies=[p])
    with timed() as t_pp:
        for p in GRID_SCHEDS:
            sim.sweep([v], seeds=seeds, rounds=rounds, policies=[p])

    cum = res.taus.sum(axis=-1)[..., 0]            # (P, S): V axis is size 1
    part = res.selected.mean(axis=3)[:, :, 0, :]   # (P, S, M)
    out = {"policies": GRID_SCHEDS, "seeds": seeds, "rounds": rounds,
           "one_program_s": t_one["s"], "per_policy_s": t_pp["s"],
           "cum_delay_mean": cum.mean(axis=1).tolist(),
           "cum_delay_std": cum.std(axis=1).tolist(),
           "participation_mean": part.mean(axis=1).tolist()}
    # Fig. 5's headline direction, now with seeds in evidence: at
    # delay-dominant V the DDSRA solve lower-bounds every fixed-resource
    # baseline's mean cumulative delay (the per-device greedy
    # delay_driven rule piles devices onto the same fast gateways —
    # see its participation row — and realizes a *worse* round max)
    dj = GRID_SCHEDS.index("ddsra_jax")
    assert all(out["cum_delay_mean"][dj] <= m + 1e-9
               for m in out["cum_delay_mean"])
    return out


def main(fast: bool = True):
    rounds = 20 if fast else 60
    with timed() as t:
        res = run(rounds=rounds)
    g = grid(rounds=rounds)
    res["scheduling_grid"] = g
    save_json("fig456_schedulers", res)
    grid_speedup = g["per_policy_s"] / max(g["one_program_s"], 1e-9)
    emit("fig56_grid_one_program_s", g["one_program_s"],
         f"policies={len(g['policies'])};seeds={len(g['seeds'])};"
         f"per_policy_s={g['per_policy_s']:.3f};"
         f"speedup={grid_speedup:.2f}x")
    print(f"  scheduling grid: {len(g['policies'])} policies x "
          f"{len(g['seeds'])} seeds x {rounds} rounds as ONE program "
          f"{g['one_program_s']:.3f}s vs per-policy {g['per_policy_s']:.3f}s"
          f" ({grid_speedup:.2f}x)")
    accs = {k: v["accuracy"][-1] for k, v in res.items() if k in SCHEDS}
    delays = {k: v["cum_delay"] for k, v in res.items() if k in SCHEDS}
    best = max(accs, key=accs.get)
    emit("fig4_accuracy_vs_schedulers", t["s"] * 1e6,
         f"best={best};ddsra_acc={accs['ddsra']:.3f}")
    for k in accs:
        print(f"  {k:13s} acc {accs[k]:.3f}  cum_delay {delays[k]:9.1f}s "
              f"fail {res[k]['failures']:2d}  part {np.round(res[k]['participation'], 2)}")
    print(f"  gamma targets {np.round(res['gamma_targets'], 2)}")
    emit("fig5_delay_ddsra_vs_lossdriven", t["s"] * 1e6,
         f"ratio={delays['ddsra'] / max(delays.get('loss_driven', 1), 1e-9):.2f}")


if __name__ == "__main__":
    main()
