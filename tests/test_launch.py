"""Launch-layer tests: input specs cover all 40 combos; pipeline partition;
GPipe parity (subprocess, 2 host devices); one real dry-run case
(subprocess, 512 host devices)."""
import json
import os
import pathlib
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro import configs as cfg_lib

SRC = str(pathlib.Path(__file__).resolve().parent.parent / "src")


def test_input_specs_all_40_combos():
    from repro.launch.specs import input_specs
    for arch in cfg_lib.ARCHS:
        for shape in cfg_lib.SHAPES:
            specs = input_specs(arch, shape)
            assert "tokens" in specs
            sc = cfg_lib.get_shape(shape)
            b = sc.global_batch
            s = 1 if sc.mode == "decode" else sc.seq_len
            assert specs["tokens"].shape == (b, s)
            if cfg_lib.get_config(arch).enc_layers:
                assert "enc_frames" in specs


def test_choose_cut_balances_uniform_layers():
    from repro.launch.pipeline import choose_cut
    costs = np.ones(16)
    mem = np.ones(16)
    cut = choose_cut(costs, mem, hbm_per_pod=100.0)
    assert cut.cut == 8


def test_choose_cut_respects_memory():
    from repro.launch.pipeline import choose_cut
    costs = np.ones(10)
    mem = np.concatenate([np.full(5, 10.0), np.full(5, 1.0)])  # heavy bottom
    cut = choose_cut(costs, mem, hbm_per_pod=30.0)
    g = np.concatenate([[0], np.cumsum(mem)])
    assert g[cut.cut] <= 30.0 and g[-1] - g[cut.cut] <= 30.0


def _run_sub(code: str, devices: int, timeout: int = 600) -> str:
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=SRC)
    return subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          env=env, capture_output=True, text=True,
                          timeout=timeout, check=True).stdout


def test_gpipe_parity_subprocess():
    out = _run_sub("""
        import jax, numpy as np
        from repro.launch.pipeline import build_demo, reference_forward
        mesh = jax.make_mesh((2,), ("pod",))
        params, x, y = build_demo(mesh, n_layers=4, width=64, batch=8, n_micro=2)
        ref = reference_forward(params, x)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-4)
        print("PIPELINE_OK")
    """, devices=2)
    assert "PIPELINE_OK" in out


@pytest.mark.slow
def test_dryrun_one_case_subprocess(tmp_path):
    """End-to-end dry-run on the production 16x16 mesh for one fast case."""
    out = _run_sub(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
        from repro.launch.dryrun import run_case
        r = run_case("granite-moe-1b-a400m", "decode_32k", multi_pod=False,
                     out_dir=r"{tmp_path}")
        assert r["ok"]
        assert r["memory"]["peak_bytes"] > 0
        assert r["roofline"]["t_compute_s"] > 0
        print("DRYRUN_OK", r["roofline"]["bottleneck"])
    """, devices=512, timeout=900)
    assert "DRYRUN_OK" in out
    files = list(pathlib.Path(tmp_path).glob("*.json"))
    assert files
    payload = json.loads(files[0].read_text())
    assert payload["arch"] == "granite-moe-1b-a400m"
