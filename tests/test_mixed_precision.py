"""Mixed-precision data plane + quantized-upload cost axis.

Covers the two Scenario knobs PR 6 added: ``dtype="bf16"`` (bf16 storage /
f32-accumulation training through the cohort engines, f32 master params)
and ``upload_bits`` (bits-per-parameter compression priced into the DDSRA
upload-delay and energy terms through ``Workload.gamma``).
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import costmodel as cm
from repro.core.network import Network, NetworkConfig
from repro.fl import cohort as cohort_lib
from repro.fl.sim import Scenario, Simulation

NET = NetworkConfig(n_devices=6, n_gateways=2, n_channels=2)


def _scenario(**kw):
    kw.setdefault("model", "mlp")
    kw.setdefault("rounds", 2)
    kw.setdefault("net", NET)
    return Scenario(**kw)


# ---------------------------------------------------------------------------
# costmodel: the bits-per-parameter axis
# ---------------------------------------------------------------------------


def test_upload_bytes_scales_linearly_with_bits():
    layers = cm.vgg11_layers(width_mult=0.25)
    native = cm.model_size_bytes(layers)
    # None = native precision = the historical gamma, exactly
    assert cm.upload_bytes(layers, None) == native
    # vgg layers are sf=4 (32-bit): pricing at 32 bits reproduces native
    assert cm.upload_bytes(layers, 32) == pytest.approx(native)
    # and the axis is linear in bits
    assert cm.upload_bytes(layers, 16) == pytest.approx(native / 2)
    assert cm.upload_bytes(layers, 8) == pytest.approx(native / 4)
    assert cm.param_count(layers) == pytest.approx(native / 4)  # 4 B/param
    with pytest.raises(ValueError):
        cm.upload_bytes(layers, 0)


def test_upload_delay_and_energy_scale_with_bits():
    """Regression: the DDSRA uplink/downlink delay and transmit-energy
    terms scale linearly with the bits-per-parameter knob (they are linear
    in gamma)."""
    layers = cm.vgg11_layers(width_mult=0.25)
    net = Network(NET, np.random.default_rng(0))
    st = net.draw()
    p = NET.p_max / 2
    g32 = cm.upload_bytes(layers, 32)
    g8 = cm.upload_bytes(layers, 8)
    for fn in (lambda g: net.uplink_time(0, 0, p, g, st),
               lambda g: net.downlink_time(0, 0, g, st),
               lambda g: net.uplink_energy(0, 0, p, g, st)):
        assert fn(g8) == pytest.approx(fn(g32) / 4)
        assert fn(g8) > 0


def test_simulation_prices_upload_bits_into_workload():
    base = Simulation(_scenario())
    g_native = cm.model_size_bytes(base.layers)
    assert base.workload.gamma == g_native                    # seed parity
    assert Simulation(_scenario(upload_bits=8)).workload.gamma == \
        pytest.approx(g_native / 4)
    # dtype="bf16" implies 16-bit uploads unless overridden
    assert Simulation(_scenario(dtype="bf16")).workload.gamma == \
        pytest.approx(g_native / 2)
    assert Simulation(_scenario(dtype="bf16", upload_bits=8)).workload.gamma \
        == pytest.approx(g_native / 4)


# ---------------------------------------------------------------------------
# Scenario knobs
# ---------------------------------------------------------------------------


def test_scenario_round_trips_new_fields():
    sc = _scenario(dtype="bf16", upload_bits=8.0)
    assert Scenario.from_json(json.loads(json.dumps(sc.to_json()))) == sc
    # old checkpoints (no dtype/upload_bits keys) load with defaults
    d = _scenario().to_json()
    del d["dtype"], d["upload_bits"]
    old = Scenario.from_json(d)
    assert old.dtype == "f32" and old.upload_bits is None
    assert old.effective_upload_bits is None


def test_bad_dtype_and_unsupported_engine_raise():
    with pytest.raises(ValueError, match="dtype"):
        Simulation(_scenario(dtype="fp8"))
    with pytest.raises(ValueError, match="sequential"):
        Simulation(_scenario(dtype="bf16", engine="sequential"))


# ---------------------------------------------------------------------------
# bf16 training path
# ---------------------------------------------------------------------------


def test_bf16_round_keeps_f32_masters_and_trains():
    """A bf16 cohort round runs end to end: master params stay f32, the
    loss moves, and the result tracks the f32 round within bf16 noise."""
    sim32 = Simulation(_scenario(seed=3))
    sim16 = Simulation(_scenario(seed=3, dtype="bf16"))
    r32 = next(sim32.rounds())
    r16 = next(sim16.rounds())
    assert all(l.dtype == jnp.float32
               for l in jax.tree.leaves(sim16.params))
    # same devices trained on the same draws; losses agree to bf16 tolerance
    assert r16.trained == r32.trained
    np.testing.assert_allclose(r16.losses, r32.losses, rtol=5e-2, atol=5e-2)
    # and the bf16 params track the f32 params at bf16 resolution
    for a, b in zip(jax.tree.leaves(sim16.params),
                    jax.tree.leaves(sim32.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=3e-2, rtol=3e-2)


def test_local_train_bf16_gemms_run_in_bf16():
    """The bf16 data plane really computes in bf16: the traced jaxpr of a
    bf16 local-train step contains bf16 dot/conv operands (storage + HBM
    traffic), while the f32 plan contains none."""
    key = jax.random.PRNGKey(0)
    from repro.models import split_model as sm
    plan = sm.MLPSplitModel(sizes=(16, 8, 4))
    params = plan.init(key)
    xs = (jax.random.normal(key, (2, 4, 16)),)
    ys = (jnp.zeros((2, 4), jnp.int32),)
    masks = (jnp.ones((2, 4)),)

    def trace(dtype):
        return str(jax.make_jaxpr(
            lambda p: cohort_lib._local_train(plan, p, xs, ys, masks, 1,
                                              0.01, compute_dtype=dtype))(
            params))

    assert "bf16" in trace("bf16")
    assert "bf16" not in trace("f32")
