"""Jitted DDSRA control plane vs the numpy oracle.

Parity contract (pinned here, required by the control-plane refactor):
identical channel assignments and selected-gateway sets, Lambda and tau
within atol 1e-6 (x64), across random networks/rounds and through an
end-to-end Simulation run; the jittable Hungarian is the numpy algorithm
step for step (identical assignments, not merely equal cost); the round
function compiles exactly once per network shape.
"""
import itertools

import numpy as np
import pytest

import jax
from jax.experimental import enable_x64

from repro.core import costmodel as cm
from repro.core import ddsra_jax
from repro.core.ddsra import Workload, ddsra_round
from repro.core.ddsra_jax import DDSRAPlan
from repro.core.hungarian import (assign_channels, assign_channels_jax,
                                  hungarian_min, hungarian_min_jax)
from repro.core.network import Network, NetworkConfig
from repro.core.participation import participation_rates


def _mlp_workload(n_devices: int, seed: int) -> Workload:
    from repro.models.vgg import mlp_layer_costs
    layers = mlp_layer_costs((3072, 512, 512, 10))
    o, g = cm.flops_vector(layers), cm.mem_vector(layers, batch=50)
    rng = np.random.default_rng(seed)
    d_tilde = np.maximum(
        (rng.uniform(0, 2000, n_devices) * 0.05).astype(int), 4)
    return Workload(o, g, cm.model_size_bytes(layers), 5,
                    d_tilde.astype(float))


# three shapes: the paper default, M == J, and a ragged shop-floor layout
# (26 devices over 8 gateways -> unequal per-gateway device counts)
_CONFIGS = [
    NetworkConfig(),
    NetworkConfig(n_gateways=5, n_channels=5, n_devices=15),
    NetworkConfig(n_gateways=8, n_channels=4, n_devices=26),
]


def test_round_parity_random_networks():
    """>= 50 random (network, round) pairs: identical assignment/selected,
    Lambda & tau atol 1e-6, identical per-device cuts on selected pairs."""
    compared = 0
    for ci, cfg in enumerate(_CONFIGS):
        net = Network(cfg, np.random.default_rng(100 + ci))
        w = _mlp_workload(cfg.n_devices, seed=ci)
        gamma = participation_rates(
            np.random.default_rng(ci).uniform(0.5, 2, cfg.n_gateways),
            cfg.n_channels)
        plan = DDSRAPlan.build(w, net)
        q = qj = np.zeros(cfg.n_gateways)
        for t in range(18):
            st = net.draw()
            v = [0.01, 10.0, 1000.0][t % 3]
            dec = ddsra_round(w, net, st, q, gamma, v)
            decj = plan.round(st, qj, gamma, v)
            assert np.array_equal(dec.assignment, decj.assignment), (ci, t)
            assert np.array_equal(dec.selected, decj.selected), (ci, t)
            finite = np.isfinite(dec.lam)
            assert np.array_equal(finite, np.isfinite(decj.lam)), (ci, t)
            np.testing.assert_allclose(decj.lam[finite], dec.lam[finite],
                                       atol=1e-6, rtol=1e-9)
            assert abs(dec.delay - decj.delay) <= 1e-6, (ci, t)
            np.testing.assert_allclose(decj.queues, dec.queues, atol=1e-9)
            for key, sol in dec.solutions.items():
                solj = decj.solutions.get(key)
                if solj is None:          # jitted dict keeps assigned pairs
                    assert dec.assignment[key] == 0
                    continue
                assert np.array_equal(sol.l_split, solj.l_split), (ci, t)
                np.testing.assert_allclose(solj.f_gw, sol.f_gw, rtol=1e-6)
                assert abs(sol.p_tx - solj.p_tx) <= 1e-6 * max(sol.p_tx, 1)
            q, qj = dec.queues, decj.queues
            compared += 1
    assert compared >= 50


def test_round_compiles_once_across_rounds(compile_count):
    """Round-to-round reuse: one trace per network shape, zero after."""
    cfg = _CONFIGS[0]
    net = Network(cfg, np.random.default_rng(0))
    w = _mlp_workload(cfg.n_devices, seed=0)
    gamma = participation_rates(np.ones(cfg.n_gateways), cfg.n_channels)
    plan = DDSRAPlan.build(w, net)
    q = np.zeros(cfg.n_gateways)
    plan.round(net.draw(), q, gamma, 10.0)            # warm (or cached)
    with compile_count(ddsra_jax._round_jit) as c:
        for _ in range(5):
            q = plan.round(net.draw(), q, gamma, 10.0).queues
    assert c.count == 0


def test_scheduler_runs_in_x64_regardless_of_global_flag():
    """Precision contract: the control plane is x64 even when the data
    plane (and the global jax flag) stay f32."""
    cfg = _CONFIGS[0]
    net = Network(cfg, np.random.default_rng(0))
    w = _mlp_workload(cfg.n_devices, seed=0)
    plan = DDSRAPlan.build(w, net)
    out = plan.round_arrays(net.draw(), np.zeros(cfg.n_gateways),
                            np.ones(cfg.n_gateways), 10.0)
    assert out.lam.dtype == np.float64
    assert out.queues.dtype == np.float64
    assert plan.statics.cumf.dtype == np.float64


def test_e2e_simulation_policy_parity():
    """A full Simulation under policy="ddsra_jax" reproduces the oracle's
    round telemetry (selected/trained/cuts exactly, delay to 1e-6)."""
    from repro.fl import Scenario, Simulation
    sim = Simulation(Scenario(model="mlp", rounds=4, eval_every=2, seed=0))
    sim.reset()
    oracle = list(sim.rounds("ddsra"))
    sim.reset()
    jitted = list(sim.rounds("ddsra_jax"))
    assert len(oracle) == len(jitted) == 4
    for a, b in zip(oracle, jitted):
        assert np.array_equal(a.selected, b.selected)
        assert a.trained == b.trained
        assert np.array_equal(a.l_n, b.l_n)
        assert abs(a.delay - b.delay) <= 1e-6
        np.testing.assert_allclose(b.queues, a.queues, atol=1e-9)
        np.testing.assert_allclose(b.losses, a.losses, atol=1e-9)
        if a.accuracy is not None:
            assert b.accuracy == pytest.approx(a.accuracy, abs=1e-9)


def test_v_sweep_is_one_fused_program():
    """vmap-over-V device-resident sweep: right shapes, finite queues, and
    the Theorem-2 direction (small V honours participation targets)."""
    cfg = _CONFIGS[0]
    net = Network(cfg, np.random.default_rng(0))
    w = _mlp_workload(cfg.n_devices, seed=0)
    gamma = participation_rates(
        np.random.default_rng(2).uniform(0.5, 2, cfg.n_gateways),
        cfg.n_channels)
    plan = DDSRAPlan.build(w, net)
    taus, sel = plan.simulate_v_sweep(jax.random.PRNGKey(0), gamma,
                                      [0.01, 100.0], rounds=40)
    assert taus.shape == (2, 40)
    assert sel.shape == (2, 40, cfg.n_gateways)
    rates = sel[0].mean(axis=0)           # small V: constraint dominates
    assert (rates >= gamma - 0.2).all(), (rates, gamma)


# ---------------------------------------------------------------------------
# assignment solver: jitted Hungarian == numpy == brute force
# (the hypothesis property version lives in test_hungarian_jax_properties.py
#  so a container without hypothesis still runs everything above)
# ---------------------------------------------------------------------------

_PSI = 1e18
_jit_hungarian = jax.jit(hungarian_min_jax)


def _brute_force_min(cost: np.ndarray) -> float:
    r, c = cost.shape
    return min(sum(cost[i, p[i]] for i in range(r))
               for p in itertools.permutations(range(c), r))


def test_hungarian_jax_matches_numpy_and_bruteforce():
    """Identical assignment to the numpy oracle (same algorithm, same
    tie-breaks) and brute-force-optimal cost, on random R <= C <= 6
    matrices including ties and _PSI-masked infeasible cells."""
    rng = np.random.default_rng(0)
    with enable_x64():
        for trial in range(60):
            r = int(rng.integers(1, 7))
            c = int(rng.integers(r, 7))
            cost = rng.uniform(0, 10, (r, c))
            if trial % 3 == 1:
                cost = np.round(cost)            # many equal-cost optima
            elif trial % 3 == 2:
                cost[rng.uniform(size=cost.shape) < 0.3] = _PSI
            cols_np, total_np = hungarian_min(cost)
            cols_jx, total_jx = _jit_hungarian(cost)
            assert np.array_equal(cols_np, np.asarray(cols_jx)), trial
            assert float(total_jx) == pytest.approx(total_np, abs=1e-9)
            assert total_np == pytest.approx(_brute_force_min(cost),
                                             rel=1e-12, abs=1e-9)


def test_assign_channels_jax_parity():
    """assign_channels_jax emits the oracle's exact 0/1 incidence matrix,
    including rounds where whole gateways are _PSI-banned."""
    rng = np.random.default_rng(1)
    with enable_x64():
        for trial in range(40):
            m = int(rng.integers(2, 7))
            j = int(rng.integers(1, m + 1))
            theta = rng.normal(size=(m, j))
            if trial % 2:
                theta[rng.uniform(size=theta.shape) < 0.25] = _PSI
                theta[rng.integers(m), :] = _PSI   # fully-banned gateway
            eye_np = assign_channels(theta)
            eye_jx = np.asarray(assign_channels_jax(theta))
            assert np.array_equal(eye_np, eye_jx), trial
            assert (eye_jx.sum(axis=0) == 1).all()       # C3
            assert (eye_jx.sum(axis=1) <= 1).all()       # C2
