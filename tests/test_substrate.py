"""Substrate tests: checkpointing, data pipeline, optimizers, FL data."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, load_pytree, save_pytree
from repro.data import markov_stream
from repro.fl.data import make_fl_dataset, sample_batch
from repro.optim import adamw, clip_by_global_norm, cosine_schedule, sgd
from repro.optim.optimizers import apply_updates


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16) * 1.5,
                  "d": jnp.array(3, jnp.int32)},
            "e": [jnp.zeros((2,)), jnp.ones((3,), jnp.float64)]}
    f = save_pytree(tmp_path, tree, step=7)
    restored = load_pytree(f, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    assert latest_step(tmp_path) == 7


def test_checkpoint_slash_keys_do_not_collide(tmp_path):
    """Regression: ``{"a/b": ...}`` and ``{"a": {"b": ...}}`` used to flatten
    to the same ``a/b`` npz key, silently clobbering one leaf."""
    tree = {"a/b": jnp.full((2,), 1.0),
            "a": {"b": jnp.full((2,), 2.0)}}
    f = save_pytree(tmp_path, tree, step=0)
    restored = load_pytree(f, tree)
    np.testing.assert_array_equal(restored["a/b"], tree["a/b"])
    np.testing.assert_array_equal(restored["a"]["b"], tree["a"]["b"])


def test_checkpoint_keep_last_rotation(tmp_path):
    from repro.checkpoint import all_steps
    tree = {"w": jnp.arange(4.0)}
    for step in range(5):
        save_pytree(tmp_path, tree, step=step, keep_last=2)
    assert all_steps(tmp_path) == [3, 4]
    assert not (tmp_path / "step_00000000.npz").exists()
    assert not (tmp_path / "step_00000000.json").exists()
    assert latest_step(tmp_path) == 4
    # the surviving newest checkpoint still restores
    restored = load_pytree(tmp_path / "step_00000004.npz", tree)
    np.testing.assert_array_equal(restored["w"], tree["w"])
    # invalid keep_last is rejected before anything is written
    with pytest.raises(ValueError):
        save_pytree(tmp_path, tree, step=9, keep_last=0)
    assert not (tmp_path / "step_00000009.npz").exists()


def test_checkpoint_rotation_never_deletes_current_step(tmp_path):
    """Regression: a restarted run saving low step numbers into a directory
    holding stale higher-numbered steps must not GC its own fresh write."""
    tree = {"w": jnp.arange(4.0)}
    save_pytree(tmp_path, tree, step=5)
    save_pytree(tmp_path, tree, step=6)
    f = save_pytree(tmp_path, tree, step=1, keep_last=2)
    assert f.exists()
    from repro.checkpoint import all_steps
    assert 1 in all_steps(tmp_path)


def test_lm_stream_deterministic_and_learnable():
    s1 = markov_stream(256, 32, 4, seed=3)
    s2 = markov_stream(256, 32, 4, seed=3)
    b1, b2 = s1.next_batch(), s2.next_batch()
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # labels are next tokens
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])
    # successor structure: every label is a valid successor of its token
    succ = s1.succ
    ok = np.isin(b1["labels"], succ[b1["tokens"]].reshape(*b1["tokens"].shape, -1))
    # elementwise check
    for i in range(4):
        for t in range(32):
            assert b1["labels"][i, t] in succ[b1["tokens"][i, t]]
    assert 0 < s1.entropy_floor() < np.log(256)


def test_adamw_reduces_quadratic_loss():
    opt = adamw(0.1)
    params = {"w": jnp.array([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        g = {"w": 2 * params["w"]}
        upd, state = opt.update(g, state, params)
        params = apply_updates(params, upd)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_sgd_momentum_matches_manual():
    opt = sgd(0.1, momentum=0.9)
    params = {"w": jnp.array(1.0)}
    state = opt.init(params)
    g = {"w": jnp.array(1.0)}
    upd, state = opt.update(g, state, params)
    assert float(upd["w"]) == pytest.approx(-0.1)
    upd, state = opt.update(g, state, params)
    assert float(upd["w"]) == pytest.approx(-0.1 * 1.9)


def test_cosine_schedule_endpoints():
    lr = cosine_schedule(1.0, warmup=10, total=110)
    assert float(lr(0)) == pytest.approx(0.0)
    assert float(lr(10)) == pytest.approx(1.0)
    assert float(lr(110)) == pytest.approx(0.0, abs=1e-6)


def test_clip_by_global_norm():
    tree = {"a": jnp.array([3.0, 4.0])}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert float(norm) == pytest.approx(5.0)
    assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0, rel=1e-5)


def test_fl_dataset_noniid_partition():
    sizes = np.full(6, 100)
    q = np.array([10, 1, 2, 3, 1, 2])
    ds = make_fl_dataset(6, sizes, q, chi=1.0, seed=0)
    for n in range(6):
        classes = np.unique(ds.y_dev[n])
        assert len(classes) <= q[n]
        assert len(ds.y_dev[n]) == 100
    # chi < 1 spills other classes in
    ds2 = make_fl_dataset(6, sizes, q, chi=0.5, seed=0)
    assert len(np.unique(ds2.y_dev[1])) > 1
    # test set balanced
    _, counts = np.unique(ds.y_test, return_counts=True)
    assert (counts == counts[0]).all()
    x, y = sample_batch(np.random.default_rng(0), ds, 0, 32)
    assert x.shape == (32, 32, 32, 3) and y.shape == (32,)
