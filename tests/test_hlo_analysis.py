"""Unit tests for the HLO collective parser + roofline arithmetic."""
import numpy as np
import pytest

from repro.launch.hlo_analysis import (HBM_BW, ICI_BW, PEAK_FLOPS, Roofline,
                                       collective_bytes)

SAMPLE_HLO = """
HloModule jit_step, entry_computation_layout={...}

%fused_computation (param_0: bf16[8,128]) -> bf16[8,128] {
  ROOT %add = bf16[8,128]{1,0} add(%param_0, %param_0)
}

ENTRY %main {
  %p0 = bf16[16,256]{1,0} parameter(0)
  %ag = bf16[256,256]{1,0} all-gather(%p0), replica_groups={{0,1}}, dimensions={0}
  %ar = f32[16,256]{1,0} all-reduce(%conv), replica_groups={}, to_apply=%sum
  %rs = bf16[8,256]{1,0} reduce-scatter(%ag), dimensions={0}, to_apply=%sum
  %a2a = bf16[16,256]{1,0} all-to-all(%p0), dimensions={0}
  %cp = bf16[16,256]{1,0} collective-permute(%p0), source_target_pairs={{0,1}}
  %ags = (bf16[1,4], bf16[2,4]) all-gather-start(%p0), dimensions={0}
  %agd = bf16[2,4]{1,0} all-gather-done(%ags)
  %not_a_collective = bf16[99,99]{1,0} add(%p0, %p0)
}
"""


def test_collective_parser_counts_each_kind():
    out = collective_bytes(SAMPLE_HLO)
    assert out["all-gather"] == 256 * 256 * 2 + (1 * 4 + 2 * 4) * 2  # + start op
    assert out["all-reduce"] == 16 * 256 * 4
    assert out["reduce-scatter"] == 8 * 256 * 2
    assert out["all-to-all"] == 16 * 256 * 2
    assert out["collective-permute"] == 16 * 256 * 2
    assert out["count"] == 6            # -done not double counted
    assert out["total"] == sum(out[k] for k in
                               ("all-gather", "all-reduce", "reduce-scatter",
                                "all-to-all", "collective-permute"))


def test_collective_parser_ignores_plain_ops():
    out = collective_bytes("%x = bf16[4,4] add(%a, %b)\n")
    assert out["total"] == 0 and out["count"] == 0


def test_roofline_terms_and_bottleneck():
    r = Roofline(flops=PEAK_FLOPS * 256, hbm_bytes=0.0, coll_bytes=0.0, chips=256)
    assert r.t_compute == pytest.approx(1.0)
    assert r.bottleneck == "compute"
    r2 = Roofline(flops=0.0, hbm_bytes=HBM_BW * 256 * 2, coll_bytes=0.0, chips=256)
    assert r2.t_memory == pytest.approx(2.0)
    assert r2.bottleneck == "memory"
    r3 = Roofline(flops=0.0, hbm_bytes=0.0, coll_bytes=ICI_BW * 256 * 3, chips=256)
    assert r3.t_collective == pytest.approx(3.0)
    assert r3.bottleneck == "collective"
    d = r3.as_dict()
    assert d["bottleneck"] == "collective" and d["chips"] == 256


def test_network_rates_monotone_in_power_and_distance():
    from repro.core.network import Network, NetworkConfig
    net = Network(NetworkConfig(), np.random.default_rng(0))
    st = net.draw()
    r1 = net.uplink_rate(0, 0, 0.05, st)
    r2 = net.uplink_rate(0, 0, 0.2, st)
    assert r2 > r1 > 0
    # energy is increasing in power for fixed payload
    e1 = net.uplink_energy(0, 0, 0.05, 1e6, st)
    e2 = net.uplink_energy(0, 0, 0.2, 1e6, st)
    assert e2 > e1
    # time decreasing in power
    t1 = net.uplink_time(0, 0, 0.05, 1e6, st)
    t2 = net.uplink_time(0, 0, 0.2, 1e6, st)
    assert t2 < t1
