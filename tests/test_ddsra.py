"""DDSRA solver unit tests: feasibility of every inner solve + round
constraints C1-C11 hold on the emitted decisions."""
import numpy as np
import pytest

from repro.core import costmodel as cm
from repro.core.ddsra import (Workload, _cum, ddsra_round, solve_frequency,
                              solve_gateway, solve_partition, solve_power)
from repro.core.network import Network, NetworkConfig
from repro.core.participation import participation_rates
from repro.core.schedulers import SCHEDULERS, RoundContext


@pytest.fixture(scope="module")
def env():
    net = Network(NetworkConfig(), np.random.default_rng(0))
    layers = cm.vgg11_layers(width_mult=0.25)
    o, g = cm.flops_vector(layers), cm.mem_vector(layers, batch=50)
    rng = np.random.default_rng(0)
    d_tilde = np.maximum((rng.uniform(0, 2000, net.cfg.n_devices) * 0.05).astype(int), 4)
    w = Workload(o, g, cm.model_size_bytes(layers), 5, d_tilde.astype(float))
    return net, w


def test_solve_partition_respects_constraints(env):
    net, w = env
    st = net.draw()
    devs = net.devices_of(0)
    f_gw = np.full(len(devs), net.cfg.f_gw_max / len(devs))
    l = solve_partition(w, net, 0, devs, f_gw, st, e_gw_budget=st.e_gw[0])
    if l is None:
        pytest.skip("infeasible draw")
    cumf, cumg = _cum(w.flops), _cum(w.mem)
    # C7': device memory; C10': device energy
    assert (cumg[l] <= net.cfg.g_dev_max).all()
    e_dev = (w.k_iters * w.d_tilde[devs] * net.cfg.v_dev / net.cfg.phi_dev
             * cumf[l] * net.f_dev[devs] ** 2)
    assert (e_dev <= st.e_dev[devs] + 1e-9).all()
    # C8': gateway memory
    assert np.sum(cumg[-1] - cumg[l]) <= net.cfg.g_gw_max + 1e-9


def test_solve_frequency_respects_c6_c9(env):
    net, w = env
    st = net.draw()
    devs = net.devices_of(1)
    l = np.full(len(devs), 8)
    budget = st.e_gw[1]
    f = solve_frequency(w, net, devs, l, st, budget)
    if f is None:
        pytest.skip("infeasible draw")
    assert f.sum() <= net.cfg.f_gw_max + 1e-6
    cumf = _cum(w.flops)
    e = np.sum(w.k_iters * w.d_tilde[devs] * net.cfg.v_gw / net.cfg.phi_gw
               * (cumf[-1] - cumf[l]) * f ** 2)
    assert e <= budget + 1e-9


def test_solve_power_energy_budget(env):
    net, w = env
    st = net.draw()
    for budget in (0.0, 0.5, 5.0, 1e9):
        p = solve_power(net, 0, 0, st, w.gamma, budget)
        assert 0.0 <= p <= net.cfg.p_max
        if p > 0:
            assert net.uplink_energy(0, 0, p, w.gamma, st) <= budget * (1 + 1e-6)
    # monotone in budget
    ps = [solve_power(net, 0, 0, st, w.gamma, b) for b in (0.1, 1.0, 10.0)]
    assert ps == sorted(ps)


def test_solve_gateway_lambda_decomposition(env):
    net, w = env
    st = net.draw()
    sol = solve_gateway(w, net, 0, 0, st)
    if not sol.feasible:
        pytest.skip("infeasible draw")
    t_up = net.uplink_time(0, 0, sol.p_tx, w.gamma, st)
    t_down = net.downlink_time(0, 0, w.gamma, st)
    assert sol.delay >= t_up + t_down
    assert sol.e_gw <= st.e_gw[0] + 1e-9


def test_ddsra_round_constraints(env):
    net, w = env
    gamma = participation_rates(np.random.default_rng(1).uniform(0.5, 2, 6), 3)
    q = np.zeros(net.cfg.n_gateways)
    for t in range(10):
        st = net.draw()
        dec = ddsra_round(w, net, st, q, gamma, v=10.0)
        eye = dec.assignment
        assert set(np.unique(eye)) <= {0.0, 1.0}          # C1
        assert (eye.sum(axis=1) <= 1).all()               # C2
        assert (eye.sum(axis=0) <= 1).all()               # <= J channels used
        np.testing.assert_allclose(
            dec.queues, np.maximum(q - dec.selected + gamma, 0))  # Eq. 14
        q = dec.queues


def test_ddsra_long_run_satisfies_participation(env):
    """C11: time-average participation approaches Gamma_m (small V)."""
    net, w = env
    gamma = participation_rates(np.random.default_rng(2).uniform(0.5, 2, 6), 3)
    q = np.zeros(net.cfg.n_gateways)
    hist = []
    for t in range(120):
        dec = ddsra_round(w, net, net.draw(), q, gamma, v=0.01)
        q = dec.queues
        hist.append(dec.selected)
    rates = np.mean(hist, axis=0)
    assert (rates >= gamma - 0.12).all(), (rates, gamma)


@pytest.mark.parametrize("name", list(SCHEDULERS))
def test_all_schedulers_emit_valid_decisions(env, name):
    net, w = env
    gamma = participation_rates(np.random.default_rng(3).uniform(0.5, 2, 6), 3)
    sched = SCHEDULERS[name]() if name != "random" else SCHEDULERS[name](0)
    q = np.zeros(net.cfg.n_gateways)
    losses = np.ones(net.cfg.n_gateways)
    for t in range(4):
        ctx = RoundContext(t, w, net, net.draw(), q, gamma, 10.0, losses)
        dec = sched.schedule(ctx)
        assert dec.assignment.shape == (6, 3)
        assert (dec.assignment.sum(axis=1) <= 1).all()
        assert dec.selected.sum() <= net.cfg.n_channels
        q = dec.queues


def test_round_robin_cycles(env):
    net, w = env
    gamma = np.full(6, 0.5)
    sched = SCHEDULERS["round_robin"]()
    seen = set()
    q = np.zeros(6)
    for t in range(2):
        ctx = RoundContext(t, w, net, net.draw(), q, gamma, 10.0, np.ones(6))
        dec = sched.schedule(ctx)
        seen |= set(np.where(dec.selected)[0].tolist())
    assert seen == set(range(6))
