"""Per-arch smoke tests: reduced config, one forward + train step + decode step.

Required by the assignment: instantiate a REDUCED variant of each family
(<=2 layers, d_model<=512, <=4 experts) and run one forward/train step on CPU
asserting output shapes + no NaNs.
"""
import jax
import jax.numpy as jnp
import pytest

from repro import configs as cfg_lib
from repro.models import get_bundle, demo_batch
from repro.models import params as params_lib
from repro.optim import sgd
from repro.optim.optimizers import apply_updates

ARCHS = list(cfg_lib.ARCHS)
B, S = 2, 64


@pytest.fixture(scope="module", params=ARCHS)
def bundle(request):
    return get_bundle(request.param, smoke=True)


def test_reduced_config_limits(bundle):
    cfg = bundle.cfg
    assert cfg.n_layers <= 2 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.n_experts <= 4


def test_forward_shapes_no_nans(bundle):
    cfg = bundle.cfg
    params = bundle.init(jax.random.PRNGKey(0))
    batch = demo_batch(cfg, B, S)
    logits = jax.jit(bundle.forward)(params, batch)
    assert logits.shape == (B, S, cfg.vocab)
    assert jnp.isfinite(logits).all()


def test_train_step_updates_and_finite(bundle):
    cfg = bundle.cfg
    params = bundle.init(jax.random.PRNGKey(1))
    batch = demo_batch(cfg, B, S)
    opt = sgd(1e-2, momentum=0.9)
    state = opt.init(params)

    @jax.jit
    def step(params, state, batch):
        loss, grads = jax.value_and_grad(bundle.loss_fn)(params, batch)
        upd, state = opt.update(grads, state, params)
        return apply_updates(params, upd), state, loss

    p2, state, loss = step(params, state, batch)
    assert jnp.isfinite(loss)
    # at least one parameter must have moved
    moved = jax.tree.reduce(
        lambda a, kv: a or bool(jnp.any(kv)),
        jax.tree.map(lambda a, b: jnp.any(a != b), params, p2), False)
    assert moved


def test_decode_step(bundle):
    cfg = bundle.cfg
    params = bundle.init(jax.random.PRNGKey(2))
    cache_t = bundle.cache_template(B, 32, enc_len=16)
    cache = params_lib.init_params(jax.random.PRNGKey(3), cache_t)
    if cfg.enc_layers:
        from repro.models import model as model_lib
        enc = jnp.asarray(
            jax.random.normal(jax.random.PRNGKey(4), (B, 16, cfg.d_model)))
        enc_out = model_lib.encode_for_decode(params, enc, cfg)
        cache = model_lib.fill_cross_cache(params, cache, enc_out, cfg)
    tok = jnp.zeros((B, 1), jnp.int32)
    logits, cache2 = jax.jit(bundle.serve_step)(params, cache, tok, jnp.int32(5))
    assert logits.shape == (B, 1, cfg.vocab)
    assert jnp.isfinite(logits).all()
