"""Fault-model semantics and the Scenario fault axes.

The load-bearing contracts here are the RNG ones (see the module docstring
of ``repro.fl.faults``): an inactive model must consume zero network-stream
draws — that is what pins the async==cohort degenerate parity — and an
active model must consume a fixed number of draws per round regardless of
its rates, so sweeps over fault rates still face identical channel states.
"""
import dataclasses
import warnings

import numpy as np
import pytest

from repro.core.network import NetworkConfig
from repro.fl import FaultModel, Scenario, Simulation, draw_round_faults
from repro.fl.faults import RoundFaults


def _net():
    return NetworkConfig(n_gateways=4, n_devices=8, n_channels=2)


def _scenario(**kw):
    base = dict(model="mlp", rounds=3, eval_every=10, seed=0,
                max_dataset=120, net=_net())
    base.update(kw)
    return Scenario(**base)


# ---------------------------------------------------------------------------
# FaultModel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("field,value", [
    ("churn", -0.1), ("churn", 1.0), ("dropout", 1.5),
    ("straggler_frac", -1e-9), ("straggler_scale", -0.5)])
def test_fault_model_validates_ranges(field, value):
    with pytest.raises(ValueError, match=field):
        FaultModel(**{field: value})


def test_active_property():
    assert not FaultModel().active
    # straggler_frac without a scale (or vice versa) can never fire
    assert not FaultModel(straggler_frac=0.5).active
    assert not FaultModel(straggler_scale=2.0).active
    assert FaultModel(churn=0.1).active
    assert FaultModel(dropout=0.1).active
    assert FaultModel(straggler_frac=0.5, straggler_scale=2.0).active


def test_from_scenario_reads_the_fault_axes():
    sc = _scenario(churn=0.2, dropout=0.1, straggler_frac=0.3,
                   straggler_scale=1.5)
    fm = FaultModel.from_scenario(sc)
    assert fm == FaultModel(0.2, 0.1, 0.3, 1.5)


# ---------------------------------------------------------------------------
# the RNG contract
# ---------------------------------------------------------------------------


def test_inactive_model_consumes_zero_draws():
    rng = np.random.default_rng(7)
    before = rng.bit_generator.state
    faults = draw_round_faults(rng, FaultModel(), 16)
    assert rng.bit_generator.state == before
    assert not faults.dropped.any() and not faults.lost.any()
    assert (faults.straggle == 0).all()


def test_active_model_draw_count_is_rate_invariant():
    """Runs differing only in fault *rates* must advance the stream
    identically: the next draw after the fault block is the same number."""
    probes = []
    for model in (FaultModel(churn=0.01), FaultModel(churn=0.9, dropout=0.9),
                  FaultModel(straggler_frac=0.5, straggler_scale=3.0)):
        rng = np.random.default_rng(123)
        draw_round_faults(rng, model, 16)
        probes.append(rng.uniform())
    assert probes[0] == probes[1] == probes[2]


def test_draws_are_deterministic_and_disjoint():
    rng = np.random.default_rng(11)
    model = FaultModel(churn=0.4, dropout=0.4, straggler_frac=0.5,
                       straggler_scale=2.0)
    a = draw_round_faults(rng, model, 64)
    b = draw_round_faults(np.random.default_rng(11), model, 64)
    for f in dataclasses.fields(RoundFaults):
        np.testing.assert_array_equal(getattr(a, f.name), getattr(b, f.name))
    # churned devices never also count as lost, and never straggle
    assert not (a.dropped & a.lost).any()
    assert (a.straggle[a.dropped] == 0).all()
    assert (a.straggle >= 0).all()


# ---------------------------------------------------------------------------
# Scenario axes: round-trip, forward-compat, engine gating
# ---------------------------------------------------------------------------


def test_scenario_fault_axes_round_trip():
    sc = _scenario(engine="async", churn=0.2, dropout=0.1,
                   straggler_frac=0.3, straggler_scale=1.5, buffer_k=2,
                   staleness_alpha=0.25, max_staleness=4)
    assert Scenario.from_json(sc.to_json()) == sc


def test_from_json_pre_fault_era_checkpoint_defaults():
    """A scenario dict written before the fault axes existed (PR 6 era)
    loads with every new axis at its fault-free default."""
    sc = _scenario()
    d = sc.to_json()
    for k in ("churn", "dropout", "straggler_frac", "straggler_scale",
              "buffer_k", "staleness_alpha", "max_staleness"):
        d.pop(k)
    with warnings.catch_warnings():
        warnings.simplefilter("error")          # no spurious warnings either
        back = Scenario.from_json(d)
    assert back == sc
    assert back.buffer_k is None and back.churn == 0.0


def test_from_json_unknown_fields_warn_and_are_ignored():
    """A checkpoint from a *newer* version loads: unknown fields (top-level
    and nested net) are dropped with a warning instead of crashing."""
    d = _scenario().to_json()
    d["flux_capacitor"] = 1.21
    d["net"]["warp_factor"] = 9
    with pytest.warns(UserWarning, match="flux_capacitor"):
        sc = Scenario.from_json(d)
    assert sc == _scenario()
    with pytest.warns(UserWarning, match="warp_factor"):
        Scenario.from_json(d)


@pytest.mark.parametrize("engine", ["cohort", "sequential", "sharded"])
def test_sync_engines_reject_active_faults(engine):
    with pytest.raises(ValueError, match="synchronous"):
        Simulation(_scenario(engine=engine, churn=0.1))
    with pytest.raises(ValueError, match="synchronous"):
        Simulation(_scenario(engine=engine, buffer_k=2))


def test_buffer_k_validated():
    with pytest.raises(ValueError, match="buffer_k"):
        Simulation(_scenario(engine="async", buffer_k=0))
