"""Prefill/decode parity: feeding tokens one-by-one through serve_step must
reproduce the sequence-mode forward logits (same math, two code paths).
Covers the KV-cache, ring-buffer, SSM-state and cross-attention paths."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import get_bundle
from repro.models import model as model_lib
from repro.models import params as params_lib

PARITY_ARCHS = ["deepseek-7b", "qwen3-14b", "mamba2-2.7b", "jamba-v0.1-52b",
                "granite-moe-1b-a400m", "seamless-m4t-medium"]
B, S = 2, 16


@pytest.mark.parametrize("arch", PARITY_ARCHS)
def test_decode_matches_forward(arch):
    bundle = get_bundle(arch, smoke=True)
    cfg = bundle.cfg
    if cfg.moe is not None:
        # exact parity requires drop-free routing: the capacity cut-off sees
        # T=B*S tokens in sequence mode but T=B in decode mode
        import dataclasses
        from repro.models.registry import bundle_for
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
        bundle = bundle_for(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)

    batch = {"tokens": tokens}
    enc = None
    if cfg.enc_layers:
        enc = jax.random.normal(jax.random.PRNGKey(2), (B, 8, cfg.d_model))
        batch["enc_frames"] = enc
    ref_logits = bundle.forward(params, batch)           # (B, S, V)

    cache_t = bundle.cache_template(B, S, enc_len=8)
    cache = params_lib.init_params(jax.random.PRNGKey(3), cache_t)
    if cfg.enc_layers:
        enc_out = model_lib.encode_for_decode(params, enc, cfg)
        cache = model_lib.fill_cross_cache(params, cache, enc_out, cfg)

    step = jax.jit(lambda p, c, t, pos: model_lib.serve_step(p, c, t, pos, cfg))
    outs = []
    for t in range(S):
        logits, cache = step(params, cache, tokens[:, t:t + 1], jnp.int32(t))
        outs.append(logits[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec_logits), np.asarray(ref_logits),
                               atol=2e-3, rtol=2e-3)


def test_ring_buffer_matches_window_attention():
    """Sliding-window decode with a ring cache == full cache with window mask."""
    arch = "deepseek-7b"
    bundle = get_bundle(arch, smoke=True)
    cfg = bundle.cfg
    import dataclasses
    cfg_w = dataclasses.replace(cfg, window=8)
    params = bundle.init(jax.random.PRNGKey(0))
    S_total, W = 24, 8
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S_total), 0, cfg.vocab)

    # ring decode
    cache_t = model_lib.cache_template(cfg_w, B, W)
    cache = params_lib.init_params(jax.random.PRNGKey(2), cache_t)
    step = jax.jit(lambda p, c, t, pos: model_lib.serve_step(
        p, c, t, pos, cfg_w, ring=True))
    ring_logits = None
    for t in range(S_total):
        ring_logits, cache = step(params, cache, tokens[:, t:t + 1], jnp.int32(t))

    # oracle: full-cache decode, window-masked attention, same final position
    from repro.models.layers import causal_attention
    from repro.models import model as m

    def windowed_forward(params, tokens):
        x = jnp.take(params["embed"], tokens, axis=0)
        pat = m.pattern_of(cfg_w)

        def unit(xc, up):
            for j, kind in enumerate(pat):
                sub = up[f"s{j}"]
                h = m.rms_norm(xc, sub["ln1"], cfg_w.norm_eps)
                q, k, v = m._proj_qkv(h, sub["attn"], cfg_w,
                                      jnp.arange(S_total)[None, :])
                o = causal_attention(q, k, v, window=W, block_q=S_total)
                xc = xc + o.reshape(*xc.shape[:2], -1) @ sub["attn"]["wo"]
                h = m.rms_norm(xc, sub["ln2"], cfg_w.norm_eps)
                xc = xc + m._ffn_apply(h, sub["ffn"], cfg_w)
            return xc

        y, _ = jax.lax.scan(lambda c, p: (unit(c, p), None), x, params["blocks"])
        y = m.rms_norm(y, params["final_norm"], cfg_w.norm_eps)
        return y @ params["unembed"]

    ref = windowed_forward(params, tokens)[:, -1]
    np.testing.assert_allclose(np.asarray(ring_logits[:, 0]), np.asarray(ref),
                               atol=2e-3, rtol=2e-3)
