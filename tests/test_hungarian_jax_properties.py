"""Hypothesis property tests for the jittable assignment solver.

Pinned triangle on random R <= C <= 8 cost matrices (ties and
_PSI-masked infeasible cells included):

    hungarian_min_jax == hungarian_min == brute-force enumeration

— *identical assignments* for the jax/numpy pair (same algorithm, same
first-minimum tie-breaks), equal total cost against brute force.

Kept separate from tests/test_ddsra_jax.py so a container without
hypothesis still runs the full control-plane parity suite.
"""
import itertools

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # container may lack hypothesis
from hypothesis import given, settings, strategies as st

import jax
from jax.experimental import enable_x64

from repro.core.hungarian import (assign_channels, assign_channels_jax,
                                  hungarian_min, hungarian_min_jax)

_PSI = 1e18
_jit_hungarian = jax.jit(hungarian_min_jax)


def _brute_force_min(cost: np.ndarray) -> float:
    r, c = cost.shape
    return min(sum(cost[i, p[i]] for i in range(r))
               for p in itertools.permutations(range(c), r))


@settings(max_examples=50, deadline=None)
@given(st.integers(1, 8), st.integers(0, 8), st.integers(0, 2 ** 31 - 1),
       st.sampled_from(["float", "ties", "psi"]))
def test_hungarian_jax_triangle(r, extra, seed, kind):
    c = min(r + extra, 8)
    rng = np.random.default_rng(seed)
    cost = rng.uniform(0, 10, (r, c))
    if kind == "ties":
        cost = np.round(cost)                    # many equal-cost optima
    elif kind == "psi":
        cost[rng.uniform(size=cost.shape) < 0.3] = _PSI
    cols_np, total_np = hungarian_min(cost)
    with enable_x64():
        cols_jx, total_jx = _jit_hungarian(cost)
    assert np.array_equal(cols_np, np.asarray(cols_jx))
    assert float(total_jx) == pytest.approx(total_np, abs=1e-9)
    assert total_np == pytest.approx(_brute_force_min(cost),
                                     rel=1e-12, abs=1e-9)


@settings(max_examples=30, deadline=None)
@given(st.integers(2, 7), st.integers(1, 5), st.integers(0, 2 ** 31 - 1),
       st.booleans())
def test_assign_channels_jax_property(m, j, seed, with_psi):
    """Exact incidence-matrix parity + constraints C2/C3, with and without
    _PSI-banned cells (including a fully-banned gateway row)."""
    j = min(j, m)
    rng = np.random.default_rng(seed)
    theta = rng.normal(size=(m, j))
    if with_psi:
        theta[rng.uniform(size=theta.shape) < 0.25] = _PSI
        theta[rng.integers(m), :] = _PSI
    eye_np = assign_channels(theta)
    with enable_x64():
        eye_jx = np.asarray(assign_channels_jax(theta))
    assert np.array_equal(eye_np, eye_jx)
    assert (eye_jx.sum(axis=0) == 1).all()
    assert (eye_jx.sum(axis=1) <= 1).all()
