"""Pallas-backend integration: forward pass with kernels (interpret mode)
matches the jnp path at model level."""
import jax
import jax.numpy as jnp
import numpy as np
import dataclasses

from repro.models import backend, demo_batch
from repro.models.registry import bundle_for
from repro import configs as cfg_lib


def _cfg_kernel_friendly(arch):
    cfg = cfg_lib.get_smoke_config(arch)
    # kernel tiling wants head_dim in {64,80,128,256}
    if cfg.family in ("dense", "vlm", "moe", "hybrid", "audio"):
        cfg = dataclasses.replace(cfg, head_dim=64)
    return cfg


def test_dense_forward_pallas_matches_jnp():
    cfg = _cfg_kernel_friendly("deepseek-7b")
    bundle = bundle_for(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    batch = demo_batch(cfg, 2, 128)
    ref = bundle.forward(params, batch)
    with backend.use_pallas(interpret=True, block_q=64, block_k=64):
        got = bundle.forward(params, batch)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=2e-3, rtol=2e-3)


def test_mamba_forward_pallas_matches_jnp():
    cfg = cfg_lib.get_smoke_config("mamba2-2.7b")
    bundle = bundle_for(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    batch = demo_batch(cfg, 2, 64)
    ref = bundle.forward(params, batch)
    with backend.use_pallas(interpret=True, ssd_block_h=4):
        got = bundle.forward(params, batch)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=2e-3, rtol=2e-3)
