"""The fused simulation loop vs the stepwise round loop.

Parity contract (pinned here, required by ``repro.fl.fused_sim``): across
{cohort, sharded} x {ddsra_jax, round_robin, delay_driven} x {f32, bf16}
x {host, traced} data planes, the fused path
reproduces the stepwise loop's RoundRecord stream and end state with
bit-identical queues and RNG streams (both the channel and the batch
stream) and params within atol 1e-5 — including when a checkpoint is saved
mid-run and resumed into either path. The seeds x V sweep matches per-seed
stepwise loops row-for-row, deterministically across processes; the fused
run is one decide compile + one train compile, with zero retraces when
only values change; and the RoundTelemetry pytree round-trips exactly.
"""
import dataclasses
import hashlib
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax

from repro.core import ddsra_jax, policy_sweep
from repro.core.network import NetworkConfig
from repro.fl import cohort as cohort_lib
from repro.fl import fused_sim
from repro.fl.fused_sim import RoundTelemetry
from repro.fl.sim import RoundRecord, Scenario, Simulation

_BASE = dict(model="mlp", alpha=0.2, max_dataset=120, rounds=5, k_iters=2,
             eval_every=100, net=NetworkConfig(3, 9, 2))


def _scenario(**over):
    return Scenario(**{**_BASE, **over})


def _run_stepwise(sc, n=None):
    sim = Simulation(sc)
    gen = sim.rounds()
    recs = [next(gen) for _ in range(sc.rounds if n is None else n)]
    return sim, recs


def _assert_record_parity(recs_a, recs_b, *, loss_atol=1e-5):
    assert len(recs_a) == len(recs_b)
    for a, b in zip(recs_a, recs_b):
        assert a.t == b.t
        assert np.array_equal(a.selected, b.selected), a.t
        assert a.trained == b.trained, a.t
        assert np.array_equal(a.l_n, b.l_n), a.t
        assert a.delay == pytest.approx(b.delay, rel=1e-12), a.t
        assert a.cum_delay == pytest.approx(b.cum_delay, rel=1e-12), a.t
        assert np.array_equal(a.queues, b.queues), a.t      # bit-identical
        np.testing.assert_allclose(b.losses, a.losses, atol=loss_atol)
        assert a.failures == b.failures, a.t
        assert a.aggregations == b.aggregations, a.t


def _assert_end_state_parity(sim_a, sim_b, *, atol=1e-5):
    # bit-identical queues and BOTH RNG streams; params to atol
    assert np.array_equal(sim_a.queues, sim_b.queues)
    assert sim_a.rng.bit_generator.state == sim_b.rng.bit_generator.state
    assert sim_a.net.rng.bit_generator.state == \
        sim_b.net.rng.bit_generator.state
    assert sim_a.t == sim_b.t
    assert sim_a.delay_sum == pytest.approx(sim_b.delay_sum, rel=1e-12)
    for a, b in zip(jax.tree.leaves(sim_a.params),
                    jax.tree.leaves(sim_b.params)):
        np.testing.assert_allclose(np.asarray(a, np.float64),
                                   np.asarray(b, np.float64), atol=atol)


# ---------------------------------------------------------------------------
# the parity matrix
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("engine", ["cohort", "sharded"])
@pytest.mark.parametrize("policy", ["ddsra_jax", "round_robin",
                                    "delay_driven"])
@pytest.mark.parametrize("dtype", ["f32", "bf16"])
def test_fused_matches_stepwise(engine, policy, dtype):
    sc = _scenario(engine=engine, policy=policy, dtype=dtype)
    sim_a, recs_a = _run_stepwise(sc)
    sim_b = Simulation(sc)
    recs_b = sim_b.fused_rounds()
    _assert_record_parity(recs_a, recs_b)
    _assert_end_state_parity(sim_a, sim_b)


def _assert_accuracy_parity(recs_a, recs_b):
    for a, b in zip(recs_a, recs_b):
        assert (a.accuracy is None) == (b.accuracy is None), a.t
        if a.accuracy is not None:
            assert b.accuracy == pytest.approx(a.accuracy, abs=1e-6), a.t


@pytest.mark.parametrize("engine", ["cohort", "sharded"])
def test_fused_in_scan_eval_matches_stepwise(engine):
    """``eval_every`` accuracy snapshots run lax.cond-gated inside the
    train scan and equal the stepwise loop's post-round evals round for
    round — mid-run rounds included, not just the final one."""
    sc = _scenario(policy="ddsra_jax", engine=engine, eval_every=2)
    _, recs_a = _run_stepwise(sc)
    recs_b = Simulation(sc).fused_rounds()
    # the stepwise schedule: rounds where (t+1) % eval_every == 0, plus
    # the final round
    assert [r.t for r in recs_b if r.accuracy is not None] == [1, 3, 4]
    _assert_accuracy_parity(recs_a, recs_b)


@pytest.mark.parametrize("engine", ["cohort", "sharded"])
@pytest.mark.parametrize("policy", ["ddsra_jax", "delay_driven"])
def test_fused_matches_stepwise_traced_data_plane(engine, policy):
    """The traced data plane: counter-based jax batch draws gathered from
    device-resident stacks *inside* the train scan reproduce the stepwise
    loop (whose host oracle, ``sample_cohort_batch_traced``, derives the
    identical indices eagerly) — bit-identical queues/RNG, params at 1e-5,
    and identical in-scan accuracy snapshots."""
    sc = _scenario(engine=engine, policy=policy, data_plane="traced",
                   eval_every=2)
    sim_a, recs_a = _run_stepwise(sc)
    sim_b = Simulation(sc)
    recs_b = sim_b.fused_rounds()
    _assert_record_parity(recs_a, recs_b)
    _assert_accuracy_parity(recs_a, recs_b)
    _assert_end_state_parity(sim_a, sim_b)


def test_traced_plane_refused_off_cohort_engines():
    with pytest.raises(ValueError, match="data_plane"):
        Simulation(_scenario(engine="sequential", data_plane="traced"))


def test_traced_draws_byte_identical_to_resident_stack_gather():
    """The host oracle (``sample_cohort_batch_traced``) and the fused
    scan's in-program gather read the SAME bytes: every occupied slot's
    valid rows equal a direct gather of ``traced_batch_indices`` into the
    device-resident stacks, and a wider slot's draw extends a narrower
    one's (the prefix property the tiered widths rely on)."""
    from repro.fl.data import (device_resident_stacks,
                               sample_cohort_batch_traced,
                               traced_batch_indices)
    sim = Simulation(_scenario(data_plane="traced", tiers=2))
    layout = sim.engine._layout(sim, sim.cohort_capacity)
    x_all, y_all, pool = device_resident_stacks(sim.ds)
    l_max = x_all.shape[1]
    key = sim.data_key
    device_ids = list(range(min(sim.cohort_capacity,
                                sim.net.cfg.n_devices)))
    for t in (0, 3):
        batch = sample_cohort_batch_traced(key, t, sim.ds, device_ids,
                                           sim.d_tilde, layout)
        for di, n in enumerate(device_ids):
            k, row = layout.locate(int(batch.slot_of[di]))
            width = layout.tier_widths[k]
            b = int(min(sim.d_tilde[n], pool[n]))
            idx = np.asarray(traced_batch_indices(
                key, t, n, int(pool[n]), width, l_max))
            # prefix property: the width-draw's first b indices ARE the
            # b-draw (so any tier width reads the same b valid rows)
            idx_b = np.asarray(traced_batch_indices(
                key, t, n, int(pool[n]), b, l_max))
            assert np.array_equal(idx[:b], idx_b)
            assert batch.tiers[k].x[row, :b].tobytes() == \
                x_all[n][idx[:b]].tobytes()
            assert batch.tiers[k].y[row, :b].tobytes() == \
                y_all[n][idx[:b]].tobytes()
            assert batch.tiers[k].mask[row, :b].all()
            assert not batch.tiers[k].mask[row, b:].any()


def test_fused_and_stepwise_blocks_interleave():
    """End-state parity is strong enough to mix the two paths mid-run."""
    sc = _scenario(rounds=6)
    sim_a, recs_a = _run_stepwise(sc)
    sim_b = Simulation(sc)
    recs_b = sim_b.fused_rounds(rounds=3)          # fused block ...
    gen = sim_b.rounds()
    recs_b += [next(gen) for _ in range(2)]        # ... stepwise block ...
    recs_b += sim_b.fused_rounds(rounds=1)         # ... fused again
    _assert_record_parity(recs_a, recs_b)
    _assert_end_state_parity(sim_a, sim_b)


def test_fused_resume_from_checkpoint_mid_sweep(tmp_path):
    """A checkpoint saved after a fused block resumes bit-identically into
    both the fused and the stepwise path."""
    sc = _scenario(rounds=6, policy="ddsra_jax")
    sim = Simulation(sc)
    recs = sim.fused_rounds(rounds=3)
    sim.save(tmp_path, block=True)
    recs_a = recs + sim.fused_rounds()             # finish fused, in-place

    sim_f = Simulation.resume(tmp_path)            # resume -> fused
    recs_f = recs[:3] + sim_f.fused_rounds()
    _assert_record_parity(recs_a, recs_f)
    _assert_end_state_parity(sim, sim_f, atol=0.0)  # same path: exact

    sim_s = Simulation.resume(tmp_path)            # resume -> stepwise
    gen = sim_s.rounds()
    recs_s = recs[:3] + [next(gen) for _ in range(3)]
    _assert_record_parity(recs_a, recs_s)
    _assert_end_state_parity(sim, sim_s)


# ---------------------------------------------------------------------------
# refusals
# ---------------------------------------------------------------------------


def test_fused_refuses_loss_driven_policy():
    sim = Simulation(_scenario(policy="loss_driven"))
    with pytest.raises(ValueError, match="reads_losses"):
        sim.fused_rounds()
    # the refusal happened before any stream was consumed
    assert sim.net.rng.bit_generator.state == sim._net_rng_state0


def test_fused_refuses_async_engine():
    sim = Simulation(_scenario(engine="async"))
    with pytest.raises(NotImplementedError, match="async"):
        sim.fused_rounds()
    assert sim.net.rng.bit_generator.state == sim._net_rng_state0


def test_sweep_requires_traced_decide_policy():
    sim = Simulation(_scenario(policy="loss_driven"))
    with pytest.raises(ValueError, match="traced-decide"):
        sim.sweep([0.01, 1.0])


def test_sweep_refuses_fixed_resource_baselines():
    # round_robin decides traced now, but a V sweep over it is meaningless:
    # fixed-resource baselines never read V
    sim = Simulation(_scenario(policy="round_robin"))
    with pytest.raises(ValueError, match="V-sweep"):
        sim.sweep([0.01, 1.0])


# ---------------------------------------------------------------------------
# compile-count / retrace regression
# ---------------------------------------------------------------------------


def test_fused_run_is_two_compiles_and_never_retraces(compile_count):
    """One decide-scan trace + one train-scan trace for an N-round fused
    run; a second run over the same shapes (different seed, so different
    values everywhere) retraces nothing."""
    sc = _scenario(policy="ddsra_jax")
    Simulation(sc).fused_rounds()                  # warm (or cached)
    with compile_count((ddsra_jax.TRACE_COUNTS, "decide"),
                       (ddsra_jax.TRACE_COUNTS, "round"),
                       (cohort_lib.TRACE_COUNTS, "train_scan"),
                       (cohort_lib.TRACE_COUNTS, "round")) as c:
        sim = Simulation(sc)
        sim.reset(seed=123)
        sim.fused_rounds()
    assert c.count == 0


def test_sweep_is_one_compile_across_value_changes(compile_count):
    """The seeds x V sweep compiles once; changing the seeds and V values
    (same counts) re-runs the same executable."""
    sim = Simulation(_scenario(policy="ddsra_jax"))
    sim.sweep([0.01, 1.0], seeds=[0, 1], rounds=4)           # warm
    with compile_count((ddsra_jax.TRACE_COUNTS, "sweep")) as c:
        res = sim.sweep([0.5, 50.0], seeds=[3, 9], rounds=4)
    assert c.count == 0
    assert res.taus.shape == (2, 2, 4)


_POLICIES = ["ddsra_jax", "round_robin", "random", "delay_driven"]


def test_multi_policy_sweep_is_one_program(compile_count):
    """The whole policies x seeds x V grid is ONE compiled program — not
    one per policy — and changing values (seeds, V) never retraces."""
    sim = Simulation(_scenario(policy="ddsra_jax"))
    sim.sweep([0.01, 1.0], seeds=[0, 1], rounds=4, policies=_POLICIES)
    with compile_count((policy_sweep.TRACE_COUNTS, "sweep")) as c:
        res = sim.sweep([0.5, 50.0], seeds=[3, 9], rounds=4,
                        policies=_POLICIES)
    assert c.count == 0
    assert res.taus.shape == (4, 2, 2, 4)
    assert res.policies == _POLICIES


def test_multi_policy_sweep_refuses_host_policies():
    sim = Simulation(_scenario(policy="ddsra_jax"))
    with pytest.raises(ValueError, match="loss_driven"):
        sim.sweep([0.01], rounds=2, policies=["ddsra_jax", "loss_driven"])


# ---------------------------------------------------------------------------
# seeds x V sweep determinism
# ---------------------------------------------------------------------------


def _sweep_digest(res) -> str:
    h = hashlib.sha256()
    for a in (res.taus, res.selected, res.queues):
        h.update(np.ascontiguousarray(a).tobytes())
    return h.hexdigest()


def test_sweep_matches_stepwise_rows():
    """Every (seed, v) sweep lane equals the stepwise reset(seed) run at
    that V, row for row: realized delays, participation, queues."""
    sc = _scenario(policy="ddsra_jax")
    sim = Simulation(sc)
    res = sim.sweep([0.01, 10.0], seeds=[0, 7], rounds=4)
    for si, seed in enumerate(res.seeds):
        for vi, v in enumerate(res.v_values):
            ref = Simulation(dataclasses.replace(sc, v=v, rounds=4))
            ref.reset(seed)
            recs = list(ref.rounds())
            np.testing.assert_allclose(
                res.taus[si, vi], [r.delay for r in recs], rtol=1e-9)
            assert np.array_equal(
                res.selected[si, vi],
                np.asarray([r.selected for r in recs]))
            np.testing.assert_allclose(
                res.queues[si, vi],
                np.asarray([r.queues for r in recs]), atol=1e-12)


def test_multi_policy_sweep_matches_stepwise_rows():
    """Every (policy, seed, v) lane of the one-program grid equals the
    stepwise ``reset(seed)`` run of that policy at that V, row for row:
    realized delays, participation, and bit-exact queue recursions —
    including the delay_driven lane, whose greedy pick is computed
    in-scan from the round's channel draws."""
    sc = _scenario(policy="ddsra_jax")
    sim = Simulation(sc)
    res = sim.sweep([0.01, 10.0], seeds=[0, 7], rounds=4,
                    policies=_POLICIES)
    for pi, pol in enumerate(_POLICIES):
        for si, seed in enumerate(res.seeds):
            for vi, v in enumerate(res.v_values):
                ref = Simulation(dataclasses.replace(
                    sc, v=v, rounds=4, policy=pol))
                ref.reset(seed)
                recs = list(ref.rounds())
                np.testing.assert_allclose(
                    res.taus[pi, si, vi], [r.delay for r in recs],
                    rtol=1e-9, err_msg=f"{pol} seed={seed} v={v}")
                assert np.array_equal(
                    res.selected[pi, si, vi],
                    np.asarray([r.selected for r in recs])), (pol, seed, v)
                np.testing.assert_allclose(
                    res.queues[pi, si, vi],
                    np.asarray([r.queues for r in recs]), atol=1e-12,
                    err_msg=f"{pol} seed={seed} v={v}")
    # fixed-resource lanes never read V: identical rows across the V axis
    for pi, pol in enumerate(_POLICIES):
        if pol != "ddsra_jax":
            assert np.array_equal(res.taus[pi, :, 0], res.taus[pi, :, 1])


_SWEEP_SCRIPT = textwrap.dedent("""
    import hashlib, numpy as np
    from repro.core.network import NetworkConfig
    from repro.fl.sim import Scenario, Simulation
    sc = Scenario(model="mlp", alpha=0.2, max_dataset=120, rounds=5,
                  k_iters=2, eval_every=100, policy="ddsra_jax",
                  net=NetworkConfig(3, 9, 2))
    sim = Simulation(sc)
    for pols in (None, ["ddsra_jax", "round_robin", "random",
                        "delay_driven"]):
        res = sim.sweep([0.01, 10.0], seeds=[0, 7], rounds=4,
                        policies=pols)
        h = hashlib.sha256()
        for a in (res.taus, res.selected, res.queues):
            h.update(np.ascontiguousarray(a).tobytes())
        print(h.hexdigest())
""")


def test_sweep_deterministic_across_processes():
    """The same sweeps — the classic seeds x V grid and the multi-policy
    grid — in a fresh interpreter produce byte-identical trajectories
    (no hash seeds, no device-order dependence)."""
    sim = Simulation(_scenario(policy="ddsra_jax"))
    local = _sweep_digest(sim.sweep([0.01, 10.0], seeds=[0, 7], rounds=4))
    local_mp = _sweep_digest(sim.sweep([0.01, 10.0], seeds=[0, 7], rounds=4,
                                       policies=_POLICIES))
    out = subprocess.run([sys.executable, "-c", _SWEEP_SCRIPT],
                         capture_output=True, text=True, check=True)
    assert out.stdout.strip().splitlines() == [local, local_mp]


# ---------------------------------------------------------------------------
# RoundTelemetry pytree properties
# ---------------------------------------------------------------------------


def _random_telemetry(rng, t, m, n) -> RoundTelemetry:
    trained = rng.random((t, m)) < 0.5
    aggs = trained.any(axis=1).astype(int)
    delay = np.where(aggs > 0, rng.random(t), 0.0)
    return RoundTelemetry(
        t=np.arange(t), selected=rng.random((t, m)) < 0.7, trained=trained,
        l_n=rng.integers(0, 4, (t, n)), delay=delay,
        cum_delay=np.cumsum(delay), queues=rng.random((t, m)),
        losses=rng.random((t, m)), failures=rng.integers(0, 2, t),
        aggregations=aggs,
        staleness_mean=np.where(aggs > 0, rng.random(t), 0.0),
        staleness_max=np.zeros(t, int), stale_discarded=np.zeros(t, int),
        dropped_devices=np.zeros(t, int), lost_devices=np.zeros(t, int),
        straggler_devices=np.zeros(t, int), buffer_fill=np.zeros(t, int),
        inflight=np.zeros(t, int))


def _check_telemetry_invariants(tel: RoundTelemetry):
    # flatten -> unflatten is the identity (a well-formed pytree)
    leaves, treedef = jax.tree.flatten(tel)
    tel2 = jax.tree.unflatten(treedef, leaves)
    for a, b in zip(tel, tel2):
        assert a is b
    # a lax.scan round-trip re-emits every leaf unchanged (the stacked
    # telemetry really is scan-shaped: leading round axis everywhere).
    # x64 on: the control-plane leaves are float64 and must survive.
    from jax.experimental import enable_x64
    with enable_x64():
        carried = jax.lax.scan(lambda c, x: (c, x), 0, tel)[1]
    for a, b in zip(tel, carried):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # records round-trip exactly, with no tracers leaking to the host
    recs = tel.to_records()
    assert all(isinstance(r.delay, float) and isinstance(r.failures, int)
               for r in recs)
    assert all(isinstance(r.queues, np.ndarray) and
               not isinstance(r.queues, jax.Array) for r in recs)
    back = RoundTelemetry.from_records(recs)
    for name, a, b in zip(RoundTelemetry._fields, tel, back):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b), name)
    # non-aggregating rounds carry exact zeros, never NaN
    quiet = np.asarray(tel.aggregations) == 0
    assert np.isfinite(np.asarray(tel.staleness_mean)).all()
    assert (np.asarray(tel.delay)[quiet] == 0.0).all()
    assert (np.asarray(tel.staleness_mean)[quiet] == 0.0).all()


def test_telemetry_pytree_roundtrip_fixed_seeds():
    """Deterministic version of the property test (runs without
    hypothesis)."""
    for seed in range(5):
        rng = np.random.default_rng(seed)
        _check_telemetry_invariants(
            _random_telemetry(rng, t=int(rng.integers(1, 8)),
                              m=int(rng.integers(1, 5)),
                              n=int(rng.integers(1, 9))))


def test_telemetry_pytree_properties_hypothesis():
    pytest.importorskip("hypothesis")  # container may lack hypothesis
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), t=st.integers(1, 10),
           m=st.integers(1, 6), n=st.integers(1, 12))
    def prop(seed, t, m, n):
        rng = np.random.default_rng(seed)
        _check_telemetry_invariants(_random_telemetry(rng, t, m, n))

    prop()


def test_telemetry_from_real_records():
    """from_records over a real stepwise stream rebuilds the fused stream's
    mask form and back."""
    _, recs = _run_stepwise(_scenario(policy="ddsra_jax"))
    tel = RoundTelemetry.from_records(recs)
    back = tel.to_records()
    for a, b in zip(recs, back):
        assert a.t == b.t and a.trained == b.trained
        assert np.array_equal(a.queues, b.queues)
        assert a.delay == pytest.approx(b.delay)
