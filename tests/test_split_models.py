"""The model zoo behind the SplitModel interface: split-vs-unsplit parity at
every valid cut for the transformer/MoE/SSM families, hand-computed FLOP pins
for the cost profiles DDSRA consumes, registry ergonomics, the flash-attention
backward pass, and token-model end-to-end runs through the FL engines."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.fl import Scenario, Simulation
from repro.fl import split as split_lib
from repro.fl.data import make_token_fl_dataset, sample_cohort_batch
from repro.kernels.flash_attention import ops as flash_ops
from repro.kernels.flash_attention import ref as flash_ref
from repro.models import registry as model_registry
from repro.models import split_model as sm

FAMILIES = {
    "transformer": sm.FL_TRANSFORMER,
    "moe": sm.FL_MOE,
    "ssm": sm.FL_SSM,
}


def _token_batch(model, batch=4, seed=0):
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.randint(kx, (batch, model.seq_len), 0, model.classes,
                           jnp.int32)
    y = jax.random.randint(ky, (batch, model.seq_len), 0, model.classes,
                           jnp.int32)
    return x, y


def _direct_sgd(model, params, x, y, lr):
    g = jax.grad(lambda p: model.loss(model.forward(p, x), y))(params)
    return jax.tree.map(lambda w, gw: w - lr * gw, params, g)


# ---------------------------------------------------------------------------
# split-vs-unsplit parity at EVERY valid cut, per family
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("family", sorted(FAMILIES))
def test_seq_split_parity_all_cuts(family):
    model = sm.SeqSplitModel(FAMILIES[family], seq_len=8)
    params = model.init(jax.random.PRNGKey(3))
    x, y = _token_batch(model)
    direct = _direct_sgd(model, params, x, y, 0.05)
    assert model.valid_cuts == tuple(range(1, model.n_blocks + 1))
    for l in model.valid_cuts:
        split_new, loss = split_lib.split_sgd_step(model, params, (x, y), l,
                                                   jnp.float32(0.05))
        assert jnp.isfinite(loss), (family, l)
        for a, b in zip(jax.tree.leaves(split_new), jax.tree.leaves(direct)):
            np.testing.assert_allclose(a, b, atol=1e-5, rtol=1e-5,
                                       err_msg=f"{family} cut {l}")


def test_seq_masked_loss_ignores_padding():
    model = sm.SeqSplitModel(sm.FL_TRANSFORMER, seq_len=8)
    params = model.init(jax.random.PRNGKey(0))
    x, y = _token_batch(model, batch=3)
    logits = model.forward(params, x)
    full = model.masked_loss(logits, y, jnp.ones(3, jnp.float32))
    np.testing.assert_allclose(full, model.loss(logits, y), rtol=1e-6)
    # a masked-out row with garbage labels must not move the loss
    y_bad = y.at[2].set(0)
    mask = jnp.array([1.0, 1.0, 0.0])
    np.testing.assert_allclose(
        model.masked_loss(logits, y_bad, mask),
        model.loss(logits[:2], y[:2]), rtol=1e-6)


# ---------------------------------------------------------------------------
# cost profiles: the numbers DDSRA partitions on, pinned by hand
# ---------------------------------------------------------------------------


def test_layer_costs_align_with_blocks():
    for family, cfg in FAMILIES.items():
        model = sm.SeqSplitModel(cfg, seq_len=16)
        costs = model.layer_costs()
        assert len(costs) == model.n_blocks, family
        kind_map = {"embed": "embed", "attn": "attention", "ffn": None,
                    "ssm": "ssm", "head": "fc"}
        for bk, lc in zip(model.block_kinds, costs):
            if bk == "ffn":
                assert lc.kind in ("ffn", "moe_ffn"), family
            else:
                assert lc.kind == kind_map[bk], (family, bk, lc.kind)


def test_transformer_flops_pinned():
    """Hand-computed from FL_TRANSFORMER (d=64, 2 heads of 32, 2 KV heads,
    d_ff=128) at seq_len=16 — per-token FLOPs x seq_len."""
    model = sm.SeqSplitModel(sm.FL_TRANSFORMER, seq_len=16)
    costs = {lc.name: lc for lc in model.layer_costs()}
    # qkv+out projections: q 2*64*64, k+v 2*(2*64*64), out 2*64*64 = 32768
    # scores QK^T + AV: 2*2*32*16 + 2*2*16*32 = 4096
    attn = costs["l0.attn"]
    assert attn.flops_fwd == (32768 + 4096) * 16 == 589824
    assert attn.flops_bwd == 2 * attn.flops_fwd
    # gated FFN: 3 matmuls of 2*64*128 = 49152 per token
    ffn = costs["l0.ffn"]
    assert ffn.flops_fwd == 3 * 2 * 64 * 128 * 16 == 786432
    assert ffn.flops_bwd == 2 * ffn.flops_fwd
    # unembed: 2*64*128 per token fwd, 2x bwd
    head = costs["unembed"]
    assert head.flops_fwd == 2 * 64 * 128 * 16
    assert head.flops_bwd == 2 * head.flops_fwd


def test_moe_ffn_prices_all_experts_resident():
    model = sm.SeqSplitModel(sm.FL_MOE, seq_len=16)
    ffn = next(lc for lc in model.layer_costs() if lc.kind == "moe_ffn")
    # router 2*d*E + top-k expert matmuls: (2*64*4 + 2*3*2*64*64) * 16
    assert ffn.flops_fwd == (2 * 64 * 4 + 2 * 3 * 2 * 64 * 64) * 16
    # weights hold ALL experts (weights + grad buffers, sf=4)
    assert ffn.mem_weights == 2 * 4 * (64 * 4 + 4 * 3 * 64 * 64)


# ---------------------------------------------------------------------------
# registry ergonomics
# ---------------------------------------------------------------------------


def test_registry_has_model_zoo():
    assert {"vgg", "mlp", "transformer", "moe", "ssm"} <= set(
        model_registry.FL_MODELS)


def test_registry_duplicate_raises():
    with pytest.raises(ValueError, match="already registered"):
        model_registry.register_fl_model("vgg")(lambda key, spec: None)


def test_registry_unknown_lists_known():
    with pytest.raises(KeyError, match="ssm"):
        model_registry.build_fl_model("no-such-model",
                                      jax.random.PRNGKey(0), None)


def test_registry_builds_split_model_contract():
    spec = Scenario(model="transformer", seq_len=8)
    model, params, layers = model_registry.build_fl_model(
        "transformer", jax.random.PRNGKey(0), spec)
    assert model.input_kind == "tokens"
    assert len(layers) == model.n_blocks
    x, _ = _token_batch(model)
    assert model.forward(params, x).shape == (4, 8, model.classes)


# ---------------------------------------------------------------------------
# flash attention: custom backward parity + the jaxpr pin
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("impl", ["ref", "interpret"])
@pytest.mark.parametrize("causal,window", [(True, None), (False, None),
                                           (True, 4)])
def test_flash_backward_matches_autodiff_reference(impl, causal, window):
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q, k, v = (jax.random.normal(kk, (1, 2, 16, 8), jnp.float32)
               for kk in ks)

    def via_flash(q, k, v):
        return jnp.sum(flash_ops.attention(q, k, v, causal=causal,
                                           window=window, impl=impl) ** 2)

    def via_ref(q, k, v):
        return jnp.sum(flash_ref.attention_ref(q, k, v, causal=causal,
                                               window=window) ** 2)

    got = jax.grad(via_flash, argnums=(0, 1, 2))(q, k, v)
    want = jax.grad(via_ref, argnums=(0, 1, 2))(q, k, v)
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, atol=2e-5, rtol=2e-5)


def _primitive_names(jaxpr):
    names = set()
    for eqn in jaxpr.eqns:
        names.add(eqn.primitive.name)
        for v in eqn.params.values():
            if hasattr(v, "eqns"):
                names |= _primitive_names(v)
            elif hasattr(v, "jaxpr"):
                names |= _primitive_names(v.jaxpr)
    return names


def test_training_jaxpr_routes_through_flash_attention():
    """The transformer's training gradient must route attention through the
    flash_attention custom-vjp (not silently fall back to the naive composed
    softmax path, whose jaxpr has no custom_vjp_call)."""
    model = sm.SeqSplitModel(sm.FL_TRANSFORMER, seq_len=8)
    params = model.init(jax.random.PRNGKey(0))
    x, y = _token_batch(model)
    fwd_jaxpr = jax.make_jaxpr(lambda p: model.forward(p, x))(params)
    # the flash_attention custom-vjp primitive is in the primal trace — so
    # grad MUST use its custom backward rule (custom_vjp semantics)
    assert any(n.startswith("custom_vjp_call")
               for n in _primitive_names(fwd_jaxpr.jaxpr))
    # and the training gradient keeps attention inside the named wrapper
    grad_jaxpr = jax.make_jaxpr(
        jax.grad(lambda p: model.loss(model.forward(p, x), y)))(params)
    assert "gqa_attention" in grad_jaxpr.pretty_print(use_color=False)


def test_training_jaxpr_routes_through_ssd_scan():
    """Under the Pallas backend the SSM family's SSD recurrence must route
    through the ssd_scan custom-vjp op layer (not the jnp chunked fallback)
    — and stay differentiable: the backward recomputes via the sequential
    oracle, so jax.grad works where the bare pallas_call would raise."""
    from repro.models import backend

    model = sm.SeqSplitModel(sm.FL_SSM, seq_len=8)
    params = model.init(jax.random.PRNGKey(0))
    x, y = _token_batch(model)
    with backend.use_pallas(interpret=True):
        fwd_jaxpr = jax.make_jaxpr(lambda p: model.forward(p, x))(params)
        assert any(n.startswith("custom_vjp_call")
                   for n in _primitive_names(fwd_jaxpr.jaxpr))
        grad_jaxpr = jax.make_jaxpr(
            jax.grad(lambda p: model.loss(model.forward(p, x), y)))(params)
        grad_txt = grad_jaxpr.pretty_print(use_color=False)
        # the training gradient keeps the recurrence inside the named op
        # wrapper, and its forward is the Pallas kernel (not the jnp ref)
        assert "name=ssd" in grad_txt
        assert "pallas_call" in grad_txt
        # the routed grad is the chunked fallback's grad (kernel parity)
        g_kernel = jax.grad(
            lambda p: model.loss(model.forward(p, x), y))(params)
    g_ref = jax.grad(lambda p: model.loss(model.forward(p, x), y))(params)
    for a, b in zip(jax.tree.leaves(g_kernel), jax.tree.leaves(g_ref)):
        np.testing.assert_allclose(a, b, atol=2e-4, rtol=2e-4)


# ---------------------------------------------------------------------------
# token data plane: the Markov dataset + cohort packing
# ---------------------------------------------------------------------------


def test_make_token_fl_dataset_shapes():
    sizes = np.array([40, 30, 20, 10])
    ds = make_token_fl_dataset(4, sizes, vocab=64, seq_len=12, chi=0.7,
                               seed=3)
    assert len(ds.x_dev) == 4
    for n, sz in enumerate(sizes):
        assert ds.x_dev[n].shape == (sz, 12)
        assert ds.x_dev[n].dtype == np.int32
        assert ds.y_dev[n].shape == (sz, 12)
        assert (ds.x_dev[n] < 64).all() and (ds.x_dev[n] >= 0).all()
    assert ds.x_test.shape[1] == 12
    # labels are the next-token shift of a single walk
    seq0 = np.concatenate([ds.x_dev[0][0], ds.y_dev[0][0][-1:]])
    np.testing.assert_array_equal(ds.y_dev[0][0], seq0[1:])


def test_token_dataset_determinism_and_chi():
    sizes = np.array([16, 16])
    a = make_token_fl_dataset(2, sizes, vocab=32, seq_len=8, chi=1.0, seed=5)
    b = make_token_fl_dataset(2, sizes, vocab=32, seq_len=8, chi=1.0, seed=5)
    np.testing.assert_array_equal(a.x_dev[0], b.x_dev[0])
    np.testing.assert_array_equal(a.x_test, b.x_test)


def test_cohort_packing_preserves_token_layout():
    sizes = np.array([20, 16, 12])
    ds = make_token_fl_dataset(3, sizes, vocab=32, seq_len=8, seed=0)
    rng = np.random.default_rng(0)
    batch = sample_cohort_batch(rng, ds, [0, 2], np.array([4, 4, 4]),
                                pad_to=6, capacity=2)
    assert batch.x.shape == (2, 6, 8) and batch.x.dtype == np.int32
    assert batch.y.shape == (2, 6, 8) and batch.y.dtype == np.int32
    assert batch.mask.dtype == np.float32
    np.testing.assert_array_equal(batch.mask.sum(axis=1), [4.0, 4.0])
    # padded slots are exact zeros so the masked loss ignores them
    assert (batch.x[0, 4:] == 0).all()


# ---------------------------------------------------------------------------
# end-to-end: the transformer trains through the real FL engines
# ---------------------------------------------------------------------------


def _tiny_token_scenario(**kw):
    base = dict(model="transformer", seq_len=8, rounds=2, k_iters=1,
                eval_every=1, alpha=0.2, max_dataset=400, seed=0,
                policy="ddsra")
    base.update(kw)
    return Scenario(**base)


@pytest.mark.parametrize("engine", ["cohort", "sharded"])
def test_transformer_end_to_end(engine):
    sim = Simulation(_tiny_token_scenario(engine=engine))
    assert sim.plan.input_kind == "tokens"
    res = sim.run()
    assert len(res.cum_delay) == 2
    assert np.isfinite(res.accuracy).all()
    assert np.isfinite(np.asarray(res.losses)).all()
    # DDSRA partitions over exactly the model's block axis
    assert sim.workload.n_layers == sim.plan.n_blocks


def test_ssm_end_to_end_cohort():
    sim = Simulation(_tiny_token_scenario(model="ssm", rounds=1))
    res = sim.run()
    assert np.isfinite(res.accuracy).all()
