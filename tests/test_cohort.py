"""Cohort engine correctness: numerical parity with the seed sequential
path, single-compile behaviour across varying device subsets, and gradient
parity of the fused_linear custom VJP against the jnp reference."""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hungarian import hungarian_min
from repro.fl import cohort as cohort_lib
from repro.fl import FLConfig, FLTrainer, Scenario, Simulation
from repro.fl.data import make_fl_dataset, sample_cohort_batch
from repro.fl.roles import Device, Gateway, fedavg
from repro.kernels.fused_linear import ops as fused_ops
from repro.kernels.fused_linear.ref import fused_linear_ref
from repro.models import split_model as sm

K_ITERS, LR = 3, 0.05


@pytest.fixture(scope="module")
def cohort_setup():
    n_dev, classes = 6, 10
    sizes = np.array([40, 52, 37, 64, 45, 58])
    d_tilde = np.array([8, 12, 7, 16, 9, 11])
    ds = make_fl_dataset(n_dev, sizes, np.full(n_dev, 3), classes=classes,
                         seed=3)
    plan = sm.MLPSplitModel(sizes=(3072, 64, 32, classes))
    params = plan.init(jax.random.PRNGKey(0))
    gws = [Gateway(0, [Device(0, 0, 40, 8), Device(1, 0, 52, 12),
                       Device(2, 0, 37, 7)]),
           Gateway(1, [Device(3, 1, 64, 16), Device(4, 1, 45, 9),
                       Device(5, 1, 58, 11)])]
    gw_onehot = np.zeros((n_dev, 2))
    gw_onehot[:3, 0] = gw_onehot[3:, 1] = 1.0
    return plan, params, ds, d_tilde, gws, gw_onehot


def _run_sequential(plan, params, ds, gws, trained, l_n, rng):
    models, weights, gw_losses = [], [], {}
    for m in trained:
        gw = gws[m]
        l_splits = np.asarray([l_n[d.idx] for d in gw.devices])
        combined, gw_loss, w_m = gw.shop_floor_round(
            plan, params, ds, l_splits, K_ITERS, LR, rng)
        models.append(combined)
        weights.append(w_m)
        gw_losses[m] = gw_loss
    return fedavg(models, np.asarray(weights, float)), gw_losses


def _run_cohort(plan, params, ds, d_tilde, gws, gw_onehot, trained, l_n, rng):
    device_ids, weights = [], np.zeros(len(d_tilde), np.float32)
    for m in trained:
        for dev in gws[m].devices:
            device_ids.append(dev.idx)
            weights[dev.idx] = dev.d_tilde
    batch = sample_cohort_batch(rng, ds, device_ids, d_tilde,
                                int(d_tilde.max()))
    return cohort_lib.cohort_round(plan, params, batch, l_n, weights,
                                   gw_onehot, K_ITERS, LR)


def test_cohort_round_matches_sequential(cohort_setup):
    """Same seeds, same l_n vector -> same global params and losses."""
    plan, params, ds, d_tilde, gws, gw_onehot = cohort_setup
    l_n = np.array([0, 1, 2, 3, 1, 2])
    trained = [0, 1]
    seq_params, seq_losses = _run_sequential(
        plan, params, ds, gws, trained, l_n, np.random.default_rng(42))
    new_params, gw_loss, gw_count, _, boundary = _run_cohort(
        plan, params, ds, d_tilde, gws, gw_onehot, trained, l_n,
        np.random.default_rng(42))
    for a, b in zip(jax.tree.leaves(new_params), jax.tree.leaves(seq_params)):
        np.testing.assert_allclose(a, b, atol=1e-5)
    for m in trained:
        assert float(gw_loss[m]) == pytest.approx(seq_losses[m], abs=1e-4)
    assert list(np.asarray(gw_count)) == [3.0, 3.0]
    assert np.asarray(boundary).shape == (6,)
    assert (np.asarray(boundary) > 0).all()      # all devices participated


def test_cohort_partial_participation_matches_sequential(cohort_setup):
    """Non-participating devices are zero-masked, not dropped: shapes stay
    fixed and the FedAvg only mixes participants."""
    plan, params, ds, d_tilde, gws, gw_onehot = cohort_setup
    l_n = np.array([2, 2, 2, 0, 0, 0])
    trained = [0]                                 # only gateway 0 trains
    seq_params, seq_losses = _run_sequential(
        plan, params, ds, gws, trained, l_n, np.random.default_rng(7))
    new_params, gw_loss, gw_count, _, _ = _run_cohort(
        plan, params, ds, d_tilde, gws, gw_onehot, trained, l_n,
        np.random.default_rng(7))
    for a, b in zip(jax.tree.leaves(new_params), jax.tree.leaves(seq_params)):
        np.testing.assert_allclose(a, b, atol=1e-5)
    assert float(gw_loss[0]) == pytest.approx(seq_losses[0], abs=1e-4)
    assert float(gw_count[1]) == 0.0


def test_cohort_compiles_once_across_varying_subsets(cohort_setup,
                                                     compile_count):
    """3 rounds with different device subsets and l_n vectors reuse one
    compiled executable (fixed-shape batching contract)."""
    plan, params, ds, d_tilde, gws, gw_onehot = cohort_setup
    rng = np.random.default_rng(0)
    with compile_count((cohort_lib.TRACE_COUNTS, "round")) as c:
        for trained, l_n in [([0], [1, 2, 3, 0, 0, 0]),
                             ([1], [0, 0, 0, 1, 2, 3]),
                             ([0, 1], [3, 2, 1, 0, 1, 2])]:
            _run_cohort(plan, params, ds, d_tilde, gws, gw_onehot, trained,
                        np.asarray(l_n), rng)
    assert c.count <= 1


def test_cohort_round_matches_sequential_vgg():
    """Conv plans (no reshape-hoist fast path) agree too."""
    classes = 10
    sizes = np.array([40, 44])
    d_tilde = np.array([5, 7])
    ds = make_fl_dataset(2, sizes, np.full(2, 3), classes=classes, seed=5)
    plan = sm.VGGSplitModel(width_mult=0.06)
    params = plan.init(jax.random.PRNGKey(1))
    gws = [Gateway(0, [Device(0, 0, 40, 5), Device(1, 0, 44, 7)])]
    gw_onehot = np.ones((2, 1))
    l_n = np.array([4, 13])
    seq_params, seq_losses = _run_sequential(
        plan, params, ds, gws, [0], l_n, np.random.default_rng(11))
    new_params, gw_loss, _, _, boundary = _run_cohort(
        plan, params, ds, d_tilde, gws, gw_onehot, [0], l_n,
        np.random.default_rng(11))
    for a, b in zip(jax.tree.leaves(new_params), jax.tree.leaves(seq_params)):
        np.testing.assert_allclose(a, b, atol=2e-5)
    assert float(gw_loss[0]) == pytest.approx(seq_losses[0], abs=1e-4)
    assert (np.asarray(boundary) > 0).all()


def test_trainer_cohort_engine_matches_sequential_run():
    """Full FL loop: both engines produce the same trajectory."""
    cohort = FLTrainer(FLConfig(model="mlp", rounds=3, eval_every=3, seed=0,
                                engine="cohort")).run("ddsra")
    seq = FLTrainer(FLConfig(model="mlp", rounds=3, eval_every=3, seed=0,
                             engine="sequential")).run("ddsra")
    np.testing.assert_allclose(cohort.losses, seq.losses, atol=1e-3)
    assert abs(cohort.accuracy[-1] - seq.accuracy[-1]) < 0.02
    np.testing.assert_array_equal(cohort.participation, seq.participation)


def test_estimate_stats_cohort_matches_sequential():
    tr = FLTrainer(FLConfig(model="mlp", rounds=1, seed=1, engine="cohort"))
    params = tr.bs.params
    # re-seed the rng so both estimators sample identical batches
    tr.rng = np.random.default_rng(123)
    b = tr.estimate_stats(params, engine="cohort")
    tr.rng = np.random.default_rng(123)
    c = tr.estimate_stats(params, engine="sequential")
    np.testing.assert_allclose(b.sigma, c.sigma, rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(b.delta, c.delta, rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(b.lipschitz, c.lipschitz, rtol=1e-3, atol=1e-4)


# ---------------------------------------------------------------------------
# fused_linear custom VJP
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("act", ["none", "relu", "silu", "gelu"])
@pytest.mark.parametrize("impl", ["ref", "interpret"])
def test_fused_linear_custom_vjp_matches_ref_grads(act, impl):
    keys = jax.random.split(jax.random.PRNGKey(5), 4)
    x = jax.random.normal(keys[0], (8, 16))
    w = jax.random.normal(keys[1], (16, 8)) / 4.0
    b = jax.random.normal(keys[2], (8,))
    dy_seed = jax.random.normal(keys[3], (8, 8))

    def f_new(x, w, b):
        return jnp.sum(fused_ops.linear(x, w, b, activation=act, impl=impl)
                       * dy_seed)

    def f_ref(x, w, b):
        return jnp.sum(fused_linear_ref(x, w, b, act) * dy_seed)

    out_new = fused_ops.linear(x, w, b, activation=act, impl=impl)
    np.testing.assert_allclose(out_new, fused_linear_ref(x, w, b, act),
                               atol=1e-5, rtol=1e-5)
    g_new = jax.grad(f_new, argnums=(0, 1, 2))(x, w, b)
    g_ref = jax.grad(f_ref, argnums=(0, 1, 2))(x, w, b)
    for a, r in zip(g_new, g_ref):
        np.testing.assert_allclose(a, r, atol=1e-5, rtol=1e-5)


def test_fused_linear_custom_vjp_under_vmap():
    """The cohort engine vmaps the fc layers over devices."""
    keys = jax.random.split(jax.random.PRNGKey(9), 3)
    x = jax.random.normal(keys[0], (4, 8, 16))           # (devices, B, K)
    w = jax.random.normal(keys[1], (4, 16, 8)) / 4.0
    b = jax.random.normal(keys[2], (4, 8))

    def per_dev(x, w, b):
        return jnp.sum(fused_ops.linear(x, w, b, activation="relu",
                                        impl="ref"))

    g = jax.grad(lambda ws: jnp.sum(jax.vmap(per_dev, in_axes=(0, 0, 0))(
        x, ws, b)))(w)
    g_ref = jax.grad(lambda ws: jnp.sum(jax.vmap(
        lambda xx, ww, bb: jnp.sum(fused_linear_ref(xx, ww, bb, "relu")))(
            x, ws, b)))(w)
    np.testing.assert_allclose(g, g_ref, atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# hungarian: vectorized column scan vs brute force (no hypothesis needed)
# ---------------------------------------------------------------------------


def test_tokens_bf16_round_parity():
    """Mixed precision on the ``input_kind="tokens"`` data plane: int32
    token batches must pass through ``_cast_floats`` untouched (only
    float leaves — params, activations — drop to bf16), so a bf16
    transformer round agrees with its f32 twin at bf16-storage
    tolerance, and the control plane (selection, delays, queues) is
    bit-identical — compute dtype never leaks into scheduling. Upload
    bits are pinned (dtype="bf16" alone would price uploads at 16 bits
    and legitimately change the delays) so the only varying input IS the
    compute dtype."""
    def run(dtype):
        sc = Scenario(model="transformer", seq_len=8, rounds=2, k_iters=1,
                      eval_every=1, alpha=0.2, max_dataset=400, seed=0,
                      policy="ddsra_jax", engine="cohort", dtype=dtype,
                      upload_bits=32)
        sim = Simulation(sc)
        assert sim.plan.input_kind == "tokens"
        assert all(x.dtype == np.int32 for x in sim.ds.x_dev)
        recs = list(sim.rounds())
        return sim, recs

    sim32, recs32 = run("f32")
    sim16, recs16 = run("bf16")
    for a, b in zip(recs32, recs16):
        np.testing.assert_array_equal(b.selected, a.selected)
        assert list(b.trained) == list(a.trained)
        assert b.delay == pytest.approx(a.delay, rel=1e-12)
        np.testing.assert_allclose(b.queues, a.queues, atol=1e-12)
        # losses re-converge within bf16 resolution (~8 mantissa bits)
        np.testing.assert_allclose(
            np.asarray(b.losses), np.asarray(a.losses), rtol=0.05, atol=0.05)
        assert b.accuracy == pytest.approx(a.accuracy, abs=0.1)
    # master params stay f32 in both runs and drift only by bf16 rounding
    for l32, l16 in zip(jax.tree.leaves(sim32.params),
                        jax.tree.leaves(sim16.params)):
        assert l16.dtype == l32.dtype == jnp.float32
        np.testing.assert_allclose(np.asarray(l16), np.asarray(l32),
                                   rtol=0.1, atol=0.02)


def test_hungarian_vectorized_matches_bruteforce():
    rng = np.random.default_rng(0)
    for _ in range(60):
        r = int(rng.integers(1, 7))
        c = int(rng.integers(r, 7))
        cost = rng.uniform(0, 10, (r, c))
        col, total = hungarian_min(cost)
        assert len(set(col.tolist())) == r and (col >= 0).all()
        best = min(sum(cost[i, p[i]] for i in range(r))
                   for p in itertools.permutations(range(c), r))
        assert total == pytest.approx(best, abs=1e-9)
