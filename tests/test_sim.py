"""Composable simulation API: Scenario/Policy/Engine registries, fair-sweep
reset semantics, streaming round telemetry, checkpoint-resume bit-identity
and the FLTrainer deprecation shim."""
import dataclasses
import json

import jax
import numpy as np
import pytest

from repro.core.network import NetworkConfig
from repro.core.schedulers import POLICIES, make_policy, register_policy
from repro.fl import (FLConfig, FLTrainer, Scenario, Simulation, make_engine,
                      register_engine)
from repro.models import registry as model_registry


def _scenario(**kw):
    base = dict(model="mlp", rounds=4, eval_every=2, seed=0)
    base.update(kw)
    return Scenario(**base)


# ---------------------------------------------------------------------------
# registries
# ---------------------------------------------------------------------------


def test_duplicate_policy_registration_raises():
    with pytest.raises(ValueError, match="already registered"):
        register_policy("ddsra")(object)
    assert POLICIES["ddsra"].cls is not object   # registry untouched


def test_duplicate_fl_model_registration_raises():
    with pytest.raises(ValueError, match="already registered"):
        model_registry.register_fl_model("vgg")(lambda key, spec: None)


def test_duplicate_engine_registration_raises():
    with pytest.raises(ValueError, match="already registered"):
        register_engine("cohort")(object)


def test_unknown_names_raise():
    with pytest.raises(KeyError):
        make_policy("nope")
    with pytest.raises(ValueError):
        make_engine("nope")
    with pytest.raises(KeyError):
        model_registry.build_fl_model("nope", jax.random.PRNGKey(0), None)


def test_registry_seed_threading_is_declarative():
    """Stochastic policies get their seed via registry kwargs, not by
    name-matching at the call site — same seed, same schedule."""
    assert "seed" in POLICIES["random"].kwargs
    a = make_policy("random", seed=123)
    b = make_policy("random", seed=123)
    draws_a = [a.rng.integers(0, 100) for _ in range(5)]
    draws_b = [b.rng.integers(0, 100) for _ in range(5)]
    assert draws_a == draws_b
    # deterministic policies simply ignore the offered context
    make_policy("round_robin", seed=123)


def test_fl_model_registry_resolves_plan_and_costs():
    sc = _scenario()
    plan, params, layers = model_registry.build_fl_model(
        "mlp", jax.random.PRNGKey(0), sc)
    assert plan.n_blocks == len(params) == len(layers) == 3
    plan_v, params_v, layers_v = model_registry.build_fl_model(
        "vgg", jax.random.PRNGKey(0), sc)
    assert plan_v.n_blocks == len(params_v) == len(layers_v)


# ---------------------------------------------------------------------------
# scenario serialization
# ---------------------------------------------------------------------------


def test_scenario_json_roundtrip():
    sc = _scenario(model="vgg", width_mult=0.125, mlp_hidden=(32, 16),
                   tiers=3, mesh_shape=(8,),
                   net=NetworkConfig(n_gateways=4, n_devices=8))
    rt = Scenario.from_json(json.loads(json.dumps(sc.to_json())))
    assert rt == sc
    assert isinstance(rt.net.dist_range, tuple)
    assert isinstance(rt.mesh_shape, tuple)
    assert dataclasses.asdict(rt) == dataclasses.asdict(sc)


def test_scenario_from_json_accepts_pre_mesh_checkpoints():
    """Manifests written before mesh_shape/tiers existed load with the
    defaults (checkpoint forward-compatibility)."""
    d = _scenario().to_json()
    del d["mesh_shape"], d["tiers"]
    sc = Scenario.from_json(d)
    assert sc.mesh_shape is None and sc.tiers == 1


def test_scenario_auto_tiers_roundtrip_and_runs():
    """tiers="auto" survives the JSON round-trip and drives a cohort run
    (the layout is derived from the d_tilde histogram at build time)."""
    sc = _scenario(tiers="auto")
    assert Scenario.from_json(json.loads(json.dumps(sc.to_json()))) == sc
    sim = Simulation(sc)
    recs = list(sim.rounds("round_robin"))
    assert len(recs) == sc.rounds
    assert sim.padding_stats["padded_samples"] > 0
    # the derived layout is at least as tight as the manual baselines
    from repro.fl.data import CohortLayout
    for manual in (1, 4):
        base = CohortLayout.build(sim.d_tilde, sim.cohort_capacity, manual)
        auto = CohortLayout.build(sim.d_tilde, sim.cohort_capacity, "auto")
        assert auto.padded_samples <= base.padded_samples


# ---------------------------------------------------------------------------
# fair-sweep reset
# ---------------------------------------------------------------------------


def test_reset_replays_identical_channel_draws():
    """Regression for the unfair-sweep bug: resetting params/batch RNG but
    not the Network RNG compared policies on different channel sequences."""
    sim = Simulation(_scenario())
    sim.run("ddsra")                       # advance all three streams
    sim.reset()
    draws1 = [sim.net.draw() for _ in range(3)]
    sim.reset()
    draws2 = [sim.net.draw() for _ in range(3)]
    for a, b in zip(draws1, draws2):
        for f in dataclasses.fields(a):
            np.testing.assert_array_equal(getattr(a, f.name),
                                          getattr(b, f.name))


def test_reset_makes_runs_bit_identical():
    sim = Simulation(_scenario())
    first = sim.run("random")
    sim.reset()
    again = sim.run("random")
    assert first.losses == again.losses
    assert first.cum_delay == again.cum_delay
    assert first.accuracy == again.accuracy
    np.testing.assert_array_equal(first.participation, again.participation)


def test_reset_seed_threads_into_stochastic_policies():
    """Replicate sweeps: reset(seed=s) must decorrelate the random baseline
    across seeds (the policy seed follows the run seed, not scenario.seed)."""
    sim = Simulation(_scenario(rounds=6))
    schedules = []
    for s in (0, 1, 2):
        sim.reset(seed=s)
        res = sim.run("random")
        schedules.append(res.participation)
    assert not np.array_equal(schedules[0], schedules[1]) or \
        not np.array_equal(schedules[0], schedules[2])
    # and the same replicate seed replays the same schedule
    sim.reset(seed=1)
    again = sim.run("random")
    np.testing.assert_array_equal(schedules[1], again.participation)
    # plain reset() returns to the scenario seed
    sim.reset()
    assert sim.run_seed == sim.scenario.seed


def test_fresh_simulation_equals_reset_run():
    sc = _scenario()
    fresh = Simulation(sc).run("ddsra")
    sim = Simulation(sc)
    sim.run("random")
    sim.reset()
    rerun = sim.run("ddsra")
    assert fresh.losses == rerun.losses
    assert fresh.cum_delay == rerun.cum_delay


# ---------------------------------------------------------------------------
# streaming rounds / telemetry
# ---------------------------------------------------------------------------


def test_rounds_streams_records_with_telemetry():
    sim = Simulation(_scenario())
    recs = list(sim.rounds("ddsra", boundary=True))
    assert [r.t for r in recs] == [0, 1, 2, 3]
    m = sim.net.cfg.n_gateways
    for r in recs:
        assert r.selected.shape == (m,) and r.queues.shape == (m,)
        assert r.losses.shape == (m,) and r.delay >= 0
        if r.trained:
            rms = r.boundary_rms
            assert rms is not None and rms.shape == (sim.net.cfg.n_devices,)
            trained_devs = [d.idx for mm in r.trained
                            for d in sim.gateways[mm].devices]
            assert (rms[trained_devs] > 0).all()
    assert recs[1].accuracy is not None and recs[3].accuracy is not None
    assert recs[0].accuracy is None
    # run() is a thin consumer of the same stream
    res = sim.result_of(recs)
    assert res.cum_delay == [r.cum_delay for r in recs]
    assert res.accuracy == [recs[1].accuracy, recs[3].accuracy]


# ---------------------------------------------------------------------------
# checkpoint-resume
# ---------------------------------------------------------------------------


def _records_equal(a, b):
    assert a.t == b.t and a.delay == b.delay and a.failures == b.failures
    assert a.cum_delay == b.cum_delay and a.accuracy == b.accuracy
    np.testing.assert_array_equal(a.selected, b.selected)
    np.testing.assert_array_equal(a.queues, b.queues)
    np.testing.assert_array_equal(a.losses, b.losses)
    np.testing.assert_array_equal(a.l_n, b.l_n)


@pytest.mark.parametrize("engine,policy", [("cohort", "random"),
                                           ("sequential", "ddsra"),
                                           ("sharded", "ddsra")])
def test_checkpoint_resume_bit_identical(engine, policy, tmp_path):
    """A run checkpointed at round t and resumed matches an uninterrupted
    run record-for-record, including the final parameters."""
    kw = {"tiers": 2} if engine == "sharded" else {}
    sc = _scenario(rounds=6, eval_every=3, engine=engine, **kw)
    uninterrupted = Simulation(sc)
    full = list(uninterrupted.rounds(policy))

    sim = Simulation(sc)
    it = sim.rounds(policy)
    head = [next(it) for _ in range(3)]
    sim.save(tmp_path)
    sim.flush()          # save() is non-blocking by default
    resumed = Simulation.resume(tmp_path)
    assert resumed.t == 3
    tail = list(resumed.rounds())        # keeps the restored policy
    assert len(head) + len(tail) == len(full)
    for a, b in zip(full, head + tail):
        _records_equal(a, b)
    for x, y in zip(jax.tree.leaves(uninterrupted.params),
                    jax.tree.leaves(resumed.params)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_resume_without_checkpoint_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        Simulation.resume(tmp_path)


def test_save_keep_last_rotates_and_resumes(tmp_path):
    """Per-round saving with ``keep_last`` keeps disk bounded (both the
    ``step_*`` param files and the ``sim_*`` manifests) and the run still
    resumes bit-identically from the newest surviving checkpoint."""
    sc = _scenario(rounds=5, keep_last=2)      # threaded via Scenario
    assert Scenario.from_json(sc.to_json()).keep_last == 2

    uninterrupted = Simulation(sc)
    full = list(uninterrupted.rounds("round_robin"))

    sim = Simulation(sc)
    it = sim.rounds("round_robin")
    for _ in range(3):
        next(it)
        sim.save(tmp_path)                     # keep_last from the Scenario
    sim.flush()
    npz = sorted(f.name for f in tmp_path.glob("step_*.npz"))
    manifests = sorted(f.name for f in tmp_path.glob("sim_*.json"))
    assert npz == ["step_00000002.npz", "step_00000003.npz"]
    assert manifests == ["sim_00000002.json", "sim_00000003.json"]

    resumed = Simulation.resume(tmp_path)      # round-1 files are GC'd
    assert resumed.t == 3
    tail = list(resumed.rounds())
    for a, b in zip(full[3:], tail):
        _records_equal(a, b)
    for x, y in zip(jax.tree.leaves(uninterrupted.params),
                    jax.tree.leaves(resumed.params)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_resume_skips_stats_estimation_and_matches(tmp_path):
    sim = Simulation(_scenario())
    next(sim.rounds("ddsra"))
    sim.save(tmp_path)
    sim.flush()
    resumed = Simulation.resume(tmp_path)
    assert resumed.stats_seconds < sim.stats_seconds / 10
    for f in dataclasses.fields(sim.stats):
        got, want = getattr(resumed.stats, f.name), getattr(sim.stats, f.name)
        assert got.dtype == want.dtype
        np.testing.assert_array_equal(got, want)
    np.testing.assert_array_equal(resumed.gamma, sim.gamma)
    np.testing.assert_array_equal(resumed.phi, sim.phi)


def test_resume_with_custom_policy_refuses_silent_swap(tmp_path):
    """A checkpoint taken under an unregistered policy instance must not
    silently continue with the scenario default."""
    class Greedy:
        def schedule(self, ctx):
            return make_policy("round_robin").schedule(ctx)

    sim = Simulation(_scenario())
    it = sim.rounds(Greedy())
    next(it)
    sim.save(tmp_path)
    sim.flush()
    resumed = Simulation.resume(tmp_path)
    with pytest.raises(ValueError, match="custom policy"):
        next(resumed.rounds())
    # passing the policy explicitly continues fine
    recs = list(resumed.rounds(Greedy()))
    assert [r.t for r in recs] == [1, 2, 3]


# ---------------------------------------------------------------------------
# FLTrainer shim
# ---------------------------------------------------------------------------


def test_trainer_shim_matches_simulation():
    cfg = FLConfig(model="mlp", rounds=4, eval_every=2, seed=0)
    res_sim = Simulation(cfg.to_scenario()).run()
    res_shim = FLTrainer(cfg).run()
    assert res_shim.accuracy == res_sim.accuracy
    assert res_shim.losses == res_sim.losses
    assert res_shim.cum_delay == res_sim.cum_delay
    np.testing.assert_array_equal(res_shim.participation,
                                  res_sim.participation)


def test_trainer_shim_internals_stay_mutable():
    """Legacy sweep idiom: poking tr.bs.params / tr.rng must still reach the
    underlying simulation (the shim shares state, not copies)."""
    tr = FLTrainer(FLConfig(model="mlp", rounds=2, eval_every=2, seed=0))
    fresh = np.random.default_rng(1)
    tr.rng = fresh
    assert tr.sim.rng is fresh
    tr.bs.params = tr.sim._init_params
    assert tr.sim.params is tr.sim._init_params
    assert tr.gamma is tr.sim.gamma


def test_trainer_shim_boundary_telemetry():
    tr = FLTrainer(FLConfig(model="mlp", rounds=2, eval_every=2, seed=0,
                            boundary_telemetry=True))
    tr.run("ddsra")
    assert tr.last_boundary_rms is not None
    assert tr.last_boundary_rms.shape == (tr.net.cfg.n_devices,)


# ---------------------------------------------------------------------------
# fig2 path: fused shop-floor round surfaces per-gateway models
# ---------------------------------------------------------------------------


def test_shop_floor_round_matches_sequential_gateways():
    sim = Simulation(_scenario(rounds=1))
    device_ids = [dev.idx for gw in sim.gateways for dev in gw.devices]
    l_n = np.full(sim.net.cfg.n_devices, sim.plan.n_blocks // 2, dtype=int)

    _, gw_models, gw_loss, _ = sim.engine.shop_floor_round(
        sim, device_ids, l_n, params=sim.params,
        rng=np.random.default_rng(17))

    rng = np.random.default_rng(17)
    for m, gw in enumerate(sim.gateways):
        l_splits = np.asarray([l_n[d.idx] for d in gw.devices])
        combined, loss, _ = gw.shop_floor_round(
            sim.plan, sim.params, sim.ds, l_splits,
            sim.scenario.k_iters, sim.scenario.lr, rng)
        got = [{k: np.asarray(a[m]) for k, a in layer.items()}
               for layer in gw_models]
        for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(combined)):
            np.testing.assert_allclose(a, np.asarray(b), atol=1e-5)
        assert float(gw_loss[m]) == pytest.approx(loss, abs=1e-4)
