"""Property-based tests (hypothesis) for the paper-core invariants."""
import itertools

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # container may lack hypothesis
from hypothesis import given, settings, strategies as st

from repro.core.hungarian import assign_channels, hungarian_min
from repro.core.lyapunov import update_queues
from repro.core.participation import participation_rates
from repro.core.partition import (Tier, best_partition, brute_force_partition,
                                  feasible_interval)
from repro.core import costmodel as cm


# ---------------------------------------------------------------------------
# Hungarian method == brute force
# ---------------------------------------------------------------------------


@settings(max_examples=60, deadline=None)
@given(st.integers(2, 5), st.integers(1, 5), st.integers(0, 2**31 - 1))
def test_hungarian_matches_bruteforce(m, r, seed):
    r = min(r, m)
    cost = np.random.default_rng(seed).uniform(0, 10, size=(r, m))
    _, total = hungarian_min(cost)
    best = min(sum(cost[i, p[i]] for i in range(r))
               for p in itertools.permutations(range(m), r))
    assert total == pytest.approx(best, abs=1e-9)


@settings(max_examples=30, deadline=None)
@given(st.integers(3, 6), st.integers(1, 3), st.integers(0, 2**31 - 1))
def test_assign_channels_constraints(m, j, seed):
    j = min(j, m)
    theta = np.random.default_rng(seed).normal(size=(m, j))
    eye = assign_channels(theta)
    # C3: each channel exactly one gateway; C2: each gateway <= 1 channel
    assert (eye.sum(axis=0) == 1).all()
    assert (eye.sum(axis=1) <= 1).all()


# ---------------------------------------------------------------------------
# partition-point bisection == exact argmin
# ---------------------------------------------------------------------------


@settings(max_examples=80, deadline=None)
@given(st.integers(2, 24), st.integers(0, 2**31 - 1), st.booleans())
def test_partition_bisection_matches_bruteforce(n_layers, seed, tight_mem):
    rng = np.random.default_rng(seed)
    costs = rng.uniform(0.1, 10, n_layers)
    mem = rng.uniform(0.1, 5, n_layers)
    cap = mem.sum() * (0.6 if tight_mem else 2.0)
    bottom = Tier(throughput=rng.uniform(0.5, 2), mem_capacity=cap)
    top = Tier(throughput=rng.uniform(0.5, 2), mem_capacity=cap)
    got = best_partition(costs, mem, bottom, top)
    want = brute_force_partition(costs, mem, bottom, top)
    if want is None:
        assert got is None
    else:
        assert got is not None
        # equal objective value (tie-breaks may differ only at equal cost)
        from repro.core.partition import split_time
        bb = np.zeros(n_layers + 1)
        assert split_time(costs, got, bottom, top, bb, np.inf) == pytest.approx(
            split_time(costs, want, bottom, top, bb, np.inf), rel=1e-6)


def test_partition_infeasible_memory():
    costs = np.ones(4)
    mem = np.ones(4) * 10
    small = Tier(throughput=1.0, mem_capacity=1.0)
    assert best_partition(costs, mem, small, small) is None
    assert feasible_interval(mem, small, small) == (1, 0)


# ---------------------------------------------------------------------------
# participation rates (Eq. 13)
# ---------------------------------------------------------------------------


@settings(max_examples=60, deadline=None)
@given(st.lists(st.floats(0.01, 100), min_size=2, max_size=12),
       st.integers(1, 6))
def test_participation_rates_properties(phi, j):
    phi = np.asarray(phi)
    j = min(j, len(phi))
    g = participation_rates(phi, j)
    assert (g <= 1.0 + 1e-12).all() and (g >= 0).all()
    # monotonicity: smaller divergence bound -> >= participation rate
    order = np.argsort(phi)
    gs = g[order]
    assert all(gs[i] >= gs[i + 1] - 1e-9 for i in range(len(gs) - 1))
    # scale invariance
    g2 = participation_rates(phi * 7.3, j)
    np.testing.assert_allclose(g, g2, rtol=1e-9)


# ---------------------------------------------------------------------------
# Lyapunov queues (Eq. 14)
# ---------------------------------------------------------------------------


@settings(max_examples=60, deadline=None)
@given(st.lists(st.floats(0, 50), min_size=1, max_size=8),
       st.integers(0, 2**31 - 1))
def test_queue_update_form(qs, seed):
    q = np.asarray(qs)
    rng = np.random.default_rng(seed)
    sel = rng.integers(0, 2, size=len(q)).astype(bool)
    gamma = rng.uniform(0, 1, size=len(q))
    q2 = update_queues(q, sel, gamma)
    assert (q2 >= 0).all()
    np.testing.assert_allclose(q2, np.maximum(q - sel + gamma, 0))


def test_queues_bounded_when_selected_every_round():
    """If a gateway is selected every round, its queue stays bounded."""
    q = np.zeros(3)
    gamma = np.array([0.9, 0.5, 0.2])
    for _ in range(1000):
        q = update_queues(q, np.ones(3, bool), gamma)
    assert (q <= 1.0).all()


# ---------------------------------------------------------------------------
# Table II cost model
# ---------------------------------------------------------------------------


def test_costmodel_positive_and_monotone():
    layers = cm.vgg11_layers()
    assert len(layers) == 16        # 8 conv + 5 pool + 3 fc
    o = cm.flops_vector(layers)
    assert (o > 0).all()
    g1 = cm.mem_vector(layers, batch=8)
    g2 = cm.mem_vector(layers, batch=64)
    assert (g2 >= g1).all()         # memory grows with batch size
    assert cm.model_size_bytes(layers) > 0


def test_costmodel_energy_quadratic_in_frequency():
    layers = cm.vgg11_layers(0.25)
    o = cm.flops_vector(layers)
    e1 = cm.train_energy_device(o, 8, 5, 32, 1e-27, 16, 1e9)
    e2 = cm.train_energy_device(o, 8, 5, 32, 1e-27, 16, 2e9)
    assert e2 == pytest.approx(4 * e1)


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 16))
def test_split_time_conservation(l):
    """device flops + gateway flops == total, for every cut."""
    layers = cm.vgg11_layers(0.5)
    o = cm.flops_vector(layers)
    t = cm.train_time_split(o, l, 1, 1, 1.0, 1.0, 1.0, 1.0)
    assert t == pytest.approx(o.sum(), rel=1e-9)


def test_arch_layer_costs_cover_all_archs():
    from repro import configs as cfg_lib
    for a in cfg_lib.ARCHS:
        cfg = cfg_lib.get_config(a)
        layers = cm.arch_layers(cfg, seq=4096)
        assert len(layers) >= cfg.n_layers
        assert all(l.flops() > 0 for l in layers)
