"""Sharded cohort engine + tiered packing: mesh fallback, numerical parity
with the single-host cohort engine (round, shop-floor/gateway-model and
stats paths), the tiered slot-packing contract, and the public-API
docstring guarantee. An 8-way forced-host-device CPU mesh is exercised in a
subprocess so the parity contract holds in every environment (the CI matrix
additionally runs the whole suite under that flag)."""
import dataclasses
import inspect
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

import repro.fl as fl
from repro.core.network import NetworkConfig
from repro.fl import (CohortLayout, Scenario, Simulation, TieredCohortBatch,
                      make_engine)
from repro.fl import cohort as cohort_lib
from repro.fl import shard as shard_lib
from repro.fl.data import make_fl_dataset, sample_batch, sample_cohort_batch
from repro.fl.shard import ShardedCohortEngine
from repro.sharding import COHORT_AXIS, cohort_mesh


def _scenario(**kw):
    base = dict(model="mlp", rounds=3, eval_every=3, seed=0)
    base.update(kw)
    return Scenario(**base)


# ---------------------------------------------------------------------------
# mesh
# ---------------------------------------------------------------------------


def test_cohort_mesh_clamps_to_available_devices():
    """Asking for a bigger mesh than the host has must degrade gracefully
    (the CPU dev box runs the sharded engine on a 1-device mesh)."""
    mesh = cohort_mesh((4096,))
    assert mesh.axis_names == (COHORT_AXIS,)
    assert mesh.shape[COHORT_AXIS] == len(jax.devices())
    assert cohort_mesh(None).shape[COHORT_AXIS] == len(jax.devices())
    assert cohort_mesh((1,)).shape[COHORT_AXIS] == 1


def test_sharded_engine_registered():
    eng = make_engine("sharded")
    assert isinstance(eng, ShardedCohortEngine)
    assert Scenario(engine="sharded").engine == "sharded"


# ---------------------------------------------------------------------------
# tiered slot layout / packing contract
# ---------------------------------------------------------------------------


def test_layout_tiers_partition_capacity_and_respect_shard_count():
    d_tilde = np.array([17, 3, 9, 5, 8, 2, 13, 11])
    for tiers in (1, 2, 3, 8, 20):
        for shards in (1, 2, 3):
            lay = CohortLayout.build(d_tilde, capacity=6, tiers=tiers,
                                     shard_count=shards)
            assert all(s % shards == 0 for s in lay.tier_slots)
            assert lay.n_slots >= 6
            widths = lay.slot_widths
            assert (np.diff(widths) <= 0).all()          # non-increasing
            assert widths[0] == 17                       # global max first
            assert lay.padded_samples == widths.sum()
    # tiers=1, shard_count=1 reproduces the single-width contract exactly
    lay = CohortLayout.build(d_tilde, capacity=6)
    assert lay.tier_widths == (17,) and lay.tier_slots == (6,)


def test_tiered_layout_cuts_padded_samples():
    rng = np.random.default_rng(0)
    d_tilde = rng.integers(4, 60, size=64)
    flat = CohortLayout.build(d_tilde, capacity=32, tiers=1)
    tiered = CohortLayout.build(d_tilde, capacity=32, tiers=4)
    assert tiered.padded_samples < flat.padded_samples


def test_auto_tiers_never_pads_more_than_manual_baselines():
    """tiers="auto" on the bench layouts ({20, 64, 128} devices, the
    fl_round_bench d_tilde distribution) must never pad more samples than
    the manual 1- and 4-tier baselines, for unsharded and mesh-8 layouts."""
    for n in (20, 64, 128):
        rng = np.random.default_rng(1)            # Simulation's seed + 1
        d_sizes = np.maximum(rng.uniform(0, 2000, n).astype(int), 40)
        d_tilde = np.maximum((0.05 * d_sizes).astype(int), 4)
        for shards in (1, 8):
            auto = CohortLayout.build(d_tilde, tiers="auto",
                                      shard_count=shards)
            for manual in (1, 4):
                base = CohortLayout.build(d_tilde, tiers=manual,
                                          shard_count=shards)
                assert auto.padded_samples <= base.padded_samples, \
                    (n, shards, manual)
            assert 1 <= len(auto.tier_widths) <= CohortLayout.AUTO_MAX_TIERS


def test_auto_tiers_property():
    """Random d_tilde/capacity/shard_count: auto is the best candidate
    count (<= every manual choice up to AUTO_MAX_TIERS) and a valid int."""
    rng = np.random.default_rng(5)
    for _ in range(25):
        n = int(rng.integers(4, 40))
        d_tilde = rng.integers(4, 120, size=n)
        capacity = int(rng.integers(1, n + 1))
        shards = int(rng.integers(1, 4))
        t_auto = CohortLayout.auto_tiers(d_tilde, capacity, shards)
        auto = CohortLayout.build(d_tilde, capacity, "auto", shards)
        assert auto == CohortLayout.build(d_tilde, capacity, t_auto, shards)
        top = min(capacity, CohortLayout.AUTO_MAX_TIERS)
        for manual in range(1, top + 1):
            base = CohortLayout.build(d_tilde, capacity, manual, shards)
            assert auto.padded_samples <= base.padded_samples


def test_tiered_packing_property():
    """Every participating device's real samples land in exactly one slot;
    mask totals equal the true drawn batch sizes; empty slots stay empty."""
    n_dev = 9
    sizes = np.array([40, 22, 37, 64, 45, 18, 52, 33, 26])
    d_tilde = np.array([12, 5, 9, 16, 11, 4, 14, 8, 6])
    ds = make_fl_dataset(n_dev, sizes, np.full(n_dev, 3), seed=2)
    rng0 = np.random.default_rng(0)
    for trial in range(6):
        tiers = int(rng0.integers(1, 5))
        shards = int(rng0.integers(1, 4))
        k = int(rng0.integers(1, 8))
        ids = rng0.choice(n_dev, size=k, replace=False).tolist()
        layout = CohortLayout.build(d_tilde, capacity=7, tiers=tiers,
                                    shard_count=shards)
        batch = sample_cohort_batch(np.random.default_rng(trial), ds, ids,
                                    d_tilde, layout=layout)
        assert isinstance(batch, TieredCohortBatch)
        # slot assignment is injective and in-range
        assert len(set(batch.slot_of.tolist())) == len(ids)
        assert (batch.slot_of >= 0).all()
        assert (batch.slot_of < layout.n_slots).all()
        mask_by_slot = np.concatenate(
            [t.mask.sum(axis=1) for t in batch.tiers])
        widths = layout.slot_widths
        for di, n in enumerate(ids):
            drawn = min(int(d_tilde[n]), int(sizes[n]))
            s = int(batch.slot_of[di])
            assert mask_by_slot[s] == drawn          # all samples, one slot
            assert drawn <= widths[s]                # slot is wide enough
        # unassigned slots hold nothing; totals match the true batch sizes
        unused = np.setdiff1d(np.arange(layout.n_slots), batch.slot_of)
        assert (mask_by_slot[unused] == 0).all()
        assert mask_by_slot.sum() == sum(
            min(int(d_tilde[n]), int(sizes[n])) for n in ids)


def test_tiered_packing_draws_match_sequential_order():
    """rng parity: the tiered path must consume the generator exactly as
    the sequential per-device loop does, in device_ids order."""
    n_dev = 6
    sizes = np.array([40, 52, 37, 64, 45, 58])
    d_tilde = np.array([8, 12, 7, 16, 9, 11])
    ds = make_fl_dataset(n_dev, sizes, np.full(n_dev, 3), seed=3)
    ids = [4, 1, 5, 2]
    layout = CohortLayout.build(d_tilde, capacity=5, tiers=3)
    batch = sample_cohort_batch(np.random.default_rng(7), ds, ids, d_tilde,
                                layout=layout)
    rng = np.random.default_rng(7)
    for di, n in enumerate(ids):
        xb, yb = sample_batch(rng, ds, n, int(d_tilde[n]))
        k, row = layout.locate(int(batch.slot_of[di]))
        t = batch.tiers[k]
        np.testing.assert_array_equal(t.x[row, :len(yb)], xb)
        np.testing.assert_array_equal(t.y[row, :len(yb)], yb)
        assert t.mask[row].sum() == len(yb)


def test_tiered_cohort_round_matches_single_width():
    """The fused round over a tiered batch equals the single-width batch
    round (same devices, same draws) at atol 1e-5."""
    n_dev = 6
    sizes = np.array([40, 52, 37, 64, 45, 58])
    d_tilde = np.array([8, 12, 7, 16, 9, 11])
    ds = make_fl_dataset(n_dev, sizes, np.full(n_dev, 3), seed=3)
    from repro.models import split_model as sm
    plan = sm.MLPSplitModel(sizes=(3072, 64, 32, 10))
    params = plan.init(jax.random.PRNGKey(0))
    ids = [0, 1, 2, 3, 4, 5]
    gw_of = np.array([0, 0, 0, 1, 1, 1])
    l_n = np.array([0, 1, 2, 3, 1, 2])

    flat = sample_cohort_batch(np.random.default_rng(42), ds, ids, d_tilde,
                               int(d_tilde.max()), capacity=6)
    onehot = np.zeros((6, 2), np.float32)
    onehot[np.arange(6), gw_of] = 1.0
    ref = cohort_lib.cohort_round(plan, params, flat, l_n,
                                  d_tilde.astype(np.float32), onehot, 3, 0.05)

    layout = CohortLayout.build(d_tilde, capacity=6, tiers=3)
    tiered = sample_cohort_batch(np.random.default_rng(42), ds, ids, d_tilde,
                                 layout=layout)
    s = layout.n_slots
    l_slot, w_slot = np.zeros(s, int), np.zeros(s, np.float32)
    oh_slot = np.zeros((s, 2), np.float32)
    for di, n in enumerate(ids):
        sl = int(tiered.slot_of[di])
        l_slot[sl], w_slot[sl] = l_n[n], d_tilde[n]
        oh_slot[sl, gw_of[n]] = 1.0
    got = cohort_lib.cohort_round(plan, params, tiered, l_slot, w_slot,
                                  oh_slot, 3, 0.05)
    for a, b in zip(jax.tree.leaves(got[0]), jax.tree.leaves(ref[0])):
        np.testing.assert_allclose(a, b, atol=1e-5)
    np.testing.assert_allclose(got[1], ref[1], atol=1e-4)   # gateway losses
    # per-slot boundary RMS maps back to the same per-device values
    np.testing.assert_allclose(np.asarray(got[4])[tiered.slot_of],
                               np.asarray(ref[4]), atol=1e-5)


# ---------------------------------------------------------------------------
# sharded engine parity (whatever mesh this host provides; 8-way in CI)
# ---------------------------------------------------------------------------


def _aligned_pair(sc):
    """(cohort sim, sharded sim) sharing stats and batch-RNG state, so both
    runs see identical data, channel draws and scheduling decisions."""
    ref = Simulation(dataclasses.replace(sc, engine="cohort"))
    shd = Simulation(dataclasses.replace(sc, engine="sharded"),
                     _stats=ref.stats)
    shd.rng.bit_generator.state = ref._rng_state0
    return ref, shd


def test_sharded_run_matches_cohort():
    sc = _scenario(tiers=2, net=NetworkConfig(n_gateways=4, n_devices=16,
                                              n_channels=4))
    ref, shd = _aligned_pair(sc)
    r1, r2 = ref.run("ddsra"), shd.run("ddsra")
    np.testing.assert_array_equal(r1.participation, r2.participation)
    np.testing.assert_allclose(r1.losses, r2.losses, atol=1e-5)
    assert r1.accuracy[-1] == pytest.approx(r2.accuracy[-1], abs=0.02)
    for a, b in zip(jax.tree.leaves(ref.params), jax.tree.leaves(shd.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    # both engines trained on the same padded-slot area
    assert ref.padding_stats["real_samples"] == \
        shd.padding_stats["real_samples"]


def test_sharded_compiles_once_across_rounds(compile_count):
    sc = _scenario(rounds=4, tiers=2)
    with compile_count((shard_lib.TRACE_COUNTS, "round")) as c:
        Simulation(sc_sharded := dataclasses.replace(sc, engine="sharded"))
        Simulation(sc_sharded).run("ddsra")
    assert c.count <= 1


def test_sharded_shop_floor_round_matches_cohort():
    """The masked-psum gateway models equal the single-host fused ones,
    including when the all-device row count does not divide the mesh."""
    sim = Simulation(_scenario(rounds=1))
    ids = [d.idx for gw in sim.gateways for d in gw.devices]
    l_n = np.full(sim.net.cfg.n_devices, sim.plan.n_blocks // 2, int)
    a = sim.engine.shop_floor_round(sim, ids, l_n,
                                    rng=np.random.default_rng(3))
    b = make_engine("sharded").shop_floor_round(
        sim, ids, l_n, rng=np.random.default_rng(3))
    for x, y in zip(jax.tree.leaves(a[0]), jax.tree.leaves(b[0])):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=1e-5)
    for x, y in zip(jax.tree.leaves(a[1]), jax.tree.leaves(b[1])):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=1e-5)
    np.testing.assert_allclose(a[2], b[2], atol=1e-4)


def test_sharded_estimate_stats_matches_cohort():
    sim = Simulation(_scenario(rounds=1))
    sim.rng = np.random.default_rng(5)
    a = sim.estimate_stats(engine="cohort")
    sim.rng = np.random.default_rng(5)
    b = sim.estimate_stats(engine="sharded")
    np.testing.assert_allclose(a.sigma, b.sigma, rtol=1e-3, atol=1e-5)
    np.testing.assert_allclose(a.delta, b.delta, rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(a.lipschitz, b.lipschitz, rtol=1e-3, atol=1e-4)


_MESH8_SCRIPT = textwrap.dedent("""
    import dataclasses
    import numpy as np, jax
    assert len(jax.devices()) == 8, len(jax.devices())
    from repro.core.network import NetworkConfig
    from repro.fl import Scenario, Simulation
    from repro.sharding import COHORT_AXIS, cohort_mesh
    assert cohort_mesh(None).shape[COHORT_AXIS] == 8
    sc = Scenario(model="mlp", rounds=2, eval_every=2, seed=0, tiers=2,
                  net=NetworkConfig(n_gateways=4, n_devices=16, n_channels=4))
    ref = Simulation(dataclasses.replace(sc, engine="cohort"))
    shd = Simulation(dataclasses.replace(sc, engine="sharded"),
                     _stats=ref.stats)
    shd.rng.bit_generator.state = ref._rng_state0
    r1, r2 = ref.run("ddsra"), shd.run("ddsra")
    np.testing.assert_array_equal(r1.participation, r2.participation)
    np.testing.assert_allclose(r1.losses, r2.losses, atol=1e-5)
    for a, b in zip(jax.tree.leaves(ref.params), jax.tree.leaves(shd.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    print("MESH8_PARITY_OK")
""")


def test_sharded_parity_on_forced_8_device_mesh():
    """The headline contract: ShardedCohortEngine == CohortEngine at atol
    1e-5 on a real 8-way mesh (forced host devices; subprocess because
    XLA_FLAGS must be set before jax is imported)."""
    if len(jax.devices()) >= 8:
        pytest.skip("already on a multi-device host; covered in-process")
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH="src" + os.pathsep + os.environ.get("PYTHONPATH",
                                                              ""))
    proc = subprocess.run([sys.executable, "-c", _MESH8_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=600,
                          cwd=os.path.dirname(os.path.dirname(
                              os.path.abspath(__file__))))
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "MESH8_PARITY_OK" in proc.stdout


# ---------------------------------------------------------------------------
# docs can't rot: every public repro.fl symbol is documented
# ---------------------------------------------------------------------------


def test_public_api_has_docstrings():
    import repro.fl.cohort
    import repro.fl.data
    import repro.fl.shard
    import repro.fl.sim
    for mod in (fl, repro.fl.sim, repro.fl.cohort, repro.fl.shard,
                repro.fl.data):
        assert (mod.__doc__ or "").strip(), mod.__name__
    for name in fl.__all__:
        obj = getattr(fl, name)
        if inspect.isclass(obj) or inspect.isfunction(obj):
            assert (obj.__doc__ or "").strip(), f"{name} lacks a docstring"
            if inspect.isclass(obj):
                for mname, raw in vars(obj).items():
                    if mname.startswith("_"):
                        continue
                    if not (inspect.isfunction(raw)
                            or isinstance(raw, (classmethod, staticmethod))):
                        continue
                    fn = raw.__func__ \
                        if isinstance(raw, (classmethod, staticmethod)) \
                        else raw
                    assert (fn.__doc__ or "").strip(), \
                        f"{name}.{mname} lacks a docstring"
