"""End-to-end FL system behaviour: the full two-tier loop trains a model to
useful accuracy, DDSRA beats chance, participation tracks targets."""
import jax
import numpy as np
import pytest

from repro.fl import FLConfig, FLTrainer


@pytest.fixture(scope="module")
def trainer():
    cfg = FLConfig(model="mlp", rounds=12, eval_every=6, seed=0)
    return FLTrainer(cfg)


def test_gamma_favours_wide_data_gateway(trainer):
    # gateway 0's devices hold all 10 classes -> lowest divergence bound
    assert int(np.argmax(trainer.gamma)) == 0
    assert (trainer.gamma <= 1.0).all() and (trainer.gamma > 0).all()


def test_ddsra_learns_and_respects_participation(trainer):
    res = trainer.run("ddsra")
    assert res.accuracy[-1] > 0.6            # well above 0.1 chance
    assert res.failures == 0                 # resource-feasible rounds only
    rates = res.participation.mean(axis=0)
    assert (rates >= res.gamma_targets - 0.35).all()
    assert len(res.cum_delay) == 12
    assert np.all(np.diff(res.cum_delay) >= 0)


def test_baseline_runs_and_is_not_better(trainer):
    from repro.models import vgg
    trainer.bs.params = vgg.init_mlp(jax.random.PRNGKey(0),
                                     (3072, 128, 64, 10))[1]
    res = trainer.run("random")
    assert res.accuracy[-1] > 0.2            # it does learn something
