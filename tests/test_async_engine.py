"""AsyncCohortEngine: degenerate parity, determinism, staleness semantics,
checkpoint/resume through the buffer, and the non-blocking save contract.

The anchor test is degenerate parity: ``engine="async"`` with every fault
axis at 0 and ``buffer_k=None`` (the barrier sentinel) must replay
``engine="cohort"`` — same schedule, same queue trajectory, same losses and
params up to float re-association (the async path averages gateway models
with ``buffer_fedavg`` where the fused round averages slots directly).
Everything the fault/buffer machinery adds is then tested *relative to that
oracle*.
"""
import dataclasses
import types

import jax
import numpy as np
import pytest

from repro.core.network import NetworkConfig
from repro.fl import ENGINES, AsyncCohortEngine, Scenario, Simulation
from repro.fl.async_engine import BufferedUpdate


def _net(**kw):
    base = dict(n_gateways=4, n_devices=8, n_channels=2)
    base.update(kw)
    return NetworkConfig(**base)


def _scenario(**kw):
    base = dict(model="mlp", rounds=5, eval_every=2, seed=3,
                max_dataset=120, net=_net(), engine="async")
    base.update(kw)
    return Scenario(**base)


def _faulty(**kw):
    base = dict(churn=0.15, dropout=0.1, straggler_frac=0.4,
                straggler_scale=2.0, buffer_k=1, rounds=8, eval_every=10)
    base.update(kw)
    return _scenario(**base)


def _params_vec(sim):
    return np.concatenate([np.ravel(np.asarray(x))
                           for x in jax.tree.leaves(sim.params)])


def _assert_records_identical(a, b):
    """Bit-exact record equality (same engine on both sides)."""
    for f in dataclasses.fields(a):
        va, vb = getattr(a, f.name), getattr(b, f.name)
        if isinstance(va, np.ndarray):
            np.testing.assert_array_equal(va, vb, err_msg=f.name)
        else:
            assert va == vb, (f.name, va, vb)


def test_async_engine_registered():
    assert "async" in ENGINES and ENGINES["async"] is AsyncCohortEngine
    assert AsyncCohortEngine.supports_faults


# ---------------------------------------------------------------------------
# degenerate parity: zero faults + barrier == CohortEngine
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("policy", ["ddsra", "round_robin"])
def test_degenerate_parity_with_cohort(policy):
    sync = Simulation(_scenario(engine="cohort"))
    full_sync = list(sync.rounds(policy))
    asyn = Simulation(_scenario(engine="async"))
    full_async = list(asyn.rounds(policy))

    for a, b in zip(full_sync, full_async):
        assert a.t == b.t and a.trained == b.trained
        np.testing.assert_array_equal(a.selected, b.selected)
        np.testing.assert_array_equal(a.l_n, b.l_n)
        # identical schedule => identical queue trajectory, bit-for-bit
        # (the realized-queue override must not fire on fault-free rounds)
        np.testing.assert_array_equal(a.queues, b.queues)
        assert a.failures == b.failures
        np.testing.assert_allclose(a.delay, b.delay, rtol=1e-9)
        np.testing.assert_allclose(a.losses, b.losses, atol=1e-5)
        if a.accuracy is not None:
            np.testing.assert_allclose(a.accuracy, b.accuracy, atol=1e-2)
        # barrier-mode telemetry collapses to synchronous semantics
        assert b.aggregations == a.aggregations
        assert b.staleness_max == 0 and b.stale_discarded == 0
        assert b.buffer_fill == 0 and b.inflight == 0
    np.testing.assert_allclose(_params_vec(sync), _params_vec(asyn),
                               atol=1e-5)


def test_degenerate_parity_survives_checkpoint_resume(tmp_path):
    """Parity must hold even when the async run is checkpointed mid-stream
    (the engine side-car state round-trips through save/resume)."""
    sc = _scenario(engine="cohort", rounds=6)
    full_sync = list(Simulation(sc).rounds("ddsra"))

    asyn = Simulation(_scenario(engine="async", rounds=6))
    it = asyn.rounds("ddsra")
    head = [next(it) for _ in range(3)]
    asyn.save(tmp_path)
    asyn.flush()
    resumed = Simulation.resume(tmp_path)
    tail = list(resumed.rounds())
    for a, b in zip(full_sync, head + tail):
        np.testing.assert_array_equal(a.queues, b.queues)
        np.testing.assert_allclose(a.losses, b.losses, atol=1e-5)


# ---------------------------------------------------------------------------
# determinism + fault telemetry
# ---------------------------------------------------------------------------


def test_same_seed_same_faults_same_records():
    """The seed pins the whole faulted run: churn/straggler draws come from
    the network stream, so two runs yield identical RoundRecord streams."""
    a = list(Simulation(_faulty()).rounds("ddsra"))
    b = list(Simulation(_faulty()).rounds("ddsra"))
    for ra, rb in zip(a, b):
        _assert_records_identical(ra, rb)
    # and the faults actually fired somewhere in 8 rounds
    assert sum(r.dropped_devices for r in a) > 0
    assert sum(r.straggler_devices for r in a) > 0


def test_fault_rates_do_not_shift_the_channel_stream():
    """Two runs differing only in fault *rates* advance the network RNG
    stream identically per round (the fixed-draw-count contract), and the
    degenerate run advances it exactly like the synchronous engine (the
    zero-draw contract)."""
    sims = [Simulation(_faulty(churn=0.01, dropout=0.0)),
            Simulation(_faulty(churn=0.6, dropout=0.3))]
    for sim in sims:
        next(sim.rounds("ddsra"))
    assert (sims[0].net.rng.bit_generator.state
            == sims[1].net.rng.bit_generator.state)

    degen = Simulation(_scenario(engine="async"))
    sync = Simulation(_scenario(engine="cohort"))
    next(degen.rounds("ddsra"))
    next(sync.rounds("ddsra"))
    assert (degen.net.rng.bit_generator.state
            == sync.net.rng.bit_generator.state)


def test_staleness_accrues_and_max_staleness_discards():
    """buffer_k=1 with two dispatches per round leaves updates in flight
    across aggregations, so staleness must exceed 0; capping max_staleness
    at 0 then turns exactly those late updates into discards."""
    recs = list(Simulation(_faulty()).rounds("ddsra"))
    assert max(r.staleness_max for r in recs) >= 1
    assert all(r.aggregations in (0, 1) for r in recs)
    assert any(r.inflight > 0 for r in recs)

    capped = list(Simulation(_faulty(max_staleness=0)).rounds("ddsra"))
    assert sum(r.stale_discarded for r in capped) > 0


def test_inflight_counts_match_telemetry():
    sim = Simulation(_faulty())
    recs = list(sim.rounds("ddsra"))
    counts = sim.engine.inflight_counts(sim)
    assert counts.shape == (sim.net.cfg.n_gateways,)
    assert counts.sum() == recs[-1].inflight


def test_reset_clears_engine_state_for_fair_sweeps():
    """reset() must not leak in-flight/parked updates into the next run:
    leftover arrivals carry old-clock timestamps and old versions, so a
    swept second policy would aggregate the first policy's models. After
    reset() the replay must match a fresh Simulation record-for-record."""
    sc = _faulty(buffer_k=3)
    sim = Simulation(sc)
    for rec in sim.rounds("ddsra"):
        if rec.inflight > 0 or rec.buffer_fill > 0:
            break
    assert sim.engine._pending or sim.engine._buffer
    sim.reset()
    assert not sim.engine._pending and not sim.engine._buffer
    assert sim.engine._version == 0 and sim.engine._seq == 0
    replay = list(sim.rounds("ddsra"))
    fresh = list(Simulation(sc).rounds("ddsra"))
    for a, b in zip(fresh, replay):
        _assert_records_identical(a, b)


def test_restart_clears_engine_state():
    """restart() (what run() does first) rewinds the clock to 0, so it
    must also drop whatever the previous rounds() left in flight."""
    sim = Simulation(_faulty(buffer_k=3))
    for rec in sim.rounds("ddsra"):
        if rec.inflight > 0 or rec.buffer_fill > 0:
            break
    sim.restart()
    assert not sim.engine._pending and not sim.engine._buffer
    assert sim.engine._version == 0 and sim.engine._seq == 0


def test_realized_queues_diverge_from_schedule_under_churn():
    """With heavy churn some selected gateway's update never lands, so the
    recorded queues must diverge from the scheduled Eq. (14) update — the
    realized-participation feedback actually fired."""
    from repro.core.lyapunov import update_queues
    sc = _faulty(churn=0.5, rounds=10, straggler_frac=0.0,
                 straggler_scale=0.0, buffer_k=None)   # land == same round
    sim = Simulation(sc)
    prev = np.zeros(sim.net.cfg.n_gateways)
    diverged = False
    for rec in sim.rounds("ddsra"):
        scheduled = update_queues(prev, rec.selected, sim.gamma)
        if not np.array_equal(scheduled, rec.queues):
            diverged = True
        prev = rec.queues
    assert diverged


# ---------------------------------------------------------------------------
# realized-delay accounting across under-full buffer rounds
# ---------------------------------------------------------------------------


def _engine_only_sim(max_staleness=None, staleness_alpha=0.5):
    """The minimal stand-in _land_and_aggregate needs: scenario knobs plus
    a writable ``params`` slot."""
    return types.SimpleNamespace(
        scenario=types.SimpleNamespace(max_staleness=max_staleness,
                                       staleness_alpha=staleness_alpha),
        params=None)


def test_parked_straggler_charges_its_arrival_at_aggregation():
    """An update landing into an under-full buffer is *parked*, not paid
    for; when a later round's aggregation finally consumes it, the charged
    delay must cover its arrival time — the server cannot aggregate at a
    simulated time earlier than an aggregated update physically arrived."""
    eng = AsyncCohortEngine()
    model = {"w": np.ones(2)}
    for arrival in (5.0, 100.0):        # 100.0 = the heavy straggler
        eng._pending_push(BufferedUpdate(gateway=0, version=0,
                                         arrival=arrival, seq=eng._seq,
                                         weight=1.0, model=model))
    sim = _engine_only_sim()
    delay, agg, _, _ = eng._land_and_aggregate(sim, barrier=False,
                                               buffer_k=3, now=0.0)
    assert delay == 0.0 and not agg and len(eng._buffer) == 2

    eng._pending_push(BufferedUpdate(gateway=1, version=0, arrival=3.0,
                                     seq=eng._seq, weight=1.0, model=model))
    delay, agg, _, _ = eng._land_and_aggregate(sim, barrier=False,
                                               buffer_k=3, now=0.0)
    assert len(agg) == 3
    assert delay == 100.0               # not 3.0 (this round's only pop)


def test_aggregation_delay_is_clamped_monotone():
    """Arrivals earlier than ``now`` land free of charge: the aggregation
    never rewinds the clock."""
    eng = AsyncCohortEngine()
    model = {"w": np.ones(2)}
    eng._pending_push(BufferedUpdate(gateway=0, version=0, arrival=2.0,
                                     seq=0, weight=1.0, model=model))
    delay, agg, _, _ = eng._land_and_aggregate(
        _engine_only_sim(), barrier=False, buffer_k=1, now=50.0)
    assert len(agg) == 1 and delay == 0.0


# ---------------------------------------------------------------------------
# checkpoint/resume through a partially-filled buffer
# ---------------------------------------------------------------------------


def test_checkpoint_resume_bit_identical_through_buffer(tmp_path):
    """Interrupting a faulted buffered run mid-stream and resuming replays
    the uninterrupted run record-for-record — including rounds whose
    aggregation consumes updates dispatched *before* the checkpoint."""
    sc = _faulty(buffer_k=3)          # buffer carries entries across rounds
    uninterrupted = Simulation(sc)
    full = list(uninterrupted.rounds("ddsra"))

    sim = Simulation(sc)
    it = sim.rounds("ddsra")
    head, cut = [], 0
    for rec in it:
        head.append(rec)
        cut = rec.t + 1
        if rec.buffer_fill > 0 or rec.inflight > 0:
            break                     # engine state is genuinely non-empty
    assert head[-1].buffer_fill > 0 or head[-1].inflight > 0
    sim.save(tmp_path)
    sim.flush()
    assert list(tmp_path.glob("engine_*.npz"))     # side-car state written

    resumed = Simulation.resume(tmp_path)
    assert resumed.t == cut
    tail = list(resumed.rounds())
    assert len(head) + len(tail) == len(full)
    for a, b in zip(full, head + tail):
        _assert_records_identical(a, b)
    np.testing.assert_array_equal(_params_vec(uninterrupted),
                                  _params_vec(resumed))


# ---------------------------------------------------------------------------
# the non-blocking save contract
# ---------------------------------------------------------------------------


def test_save_is_nonblocking_and_flush_completes(tmp_path):
    sim = Simulation(_faulty())
    it = sim.rounds("ddsra")
    next(it)
    fname = sim.save(tmp_path)
    sim.flush()                       # after flush: everything on disk
    assert fname.exists()
    assert not list(tmp_path.glob("*.tmp")), "atomic renames left tmp files"
    assert Simulation.resume(tmp_path).t == 1


def test_save_block_true_writes_inline(tmp_path):
    sim = Simulation(_scenario(rounds=2))
    next(sim.rounds("round_robin"))
    fname = sim.save(tmp_path, block=True)
    assert fname.exists()             # no flush needed
    assert Simulation.resume(tmp_path).t == 1


def test_writer_drains_at_interpreter_exit_without_flush(tmp_path):
    """A process that exits without ever calling flush() must not lose
    queued checkpoints: the writer's atexit hook drains the queue (here
    invoked directly — the interpreter runs it at shutdown)."""
    sim = Simulation(_scenario(rounds=2))
    next(sim.rounds("round_robin"))
    fname = sim.save(tmp_path)
    sim._ckpt_writer._drain_at_exit()
    assert fname.exists()
    assert Simulation.resume(tmp_path).t == 1


def test_run_flushes_pending_saves(tmp_path):
    """run() is a completion barrier for earlier non-blocking saves."""
    sim = Simulation(_scenario(rounds=2))
    next(sim.rounds("round_robin"))
    fname = sim.save(tmp_path)
    sim.run("round_robin")              # no explicit flush()
    assert fname.exists()


def test_flush_reraises_background_write_errors(tmp_path):
    sim = Simulation(_scenario(rounds=2))
    next(sim.rounds("round_robin"))
    target = tmp_path / "not_a_dir"
    target.write_text("occupied")     # mkdir under a file must fail
    sim.save(target / "ckpt")
    with pytest.raises(OSError):
        sim.flush()
    sim.flush()                       # the error is consumed; writer lives
    sim.save(tmp_path)                # and still accepts new work
    sim.flush()
    assert Simulation.resume(tmp_path).t == 1
