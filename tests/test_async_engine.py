"""AsyncCohortEngine: degenerate parity, determinism, staleness semantics,
checkpoint/resume through the buffer, and the non-blocking save contract.

The anchor test is degenerate parity: ``engine="async"`` with every fault
axis at 0 and ``buffer_k=None`` (the barrier sentinel) must replay
``engine="cohort"`` — same schedule, same queue trajectory, same losses and
params up to float re-association (the async path averages gateway models
with ``buffer_fedavg`` where the fused round averages slots directly).
Everything the fault/buffer machinery adds is then tested *relative to that
oracle*.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core.network import NetworkConfig
from repro.fl import ENGINES, AsyncCohortEngine, Scenario, Simulation


def _net(**kw):
    base = dict(n_gateways=4, n_devices=8, n_channels=2)
    base.update(kw)
    return NetworkConfig(**base)


def _scenario(**kw):
    base = dict(model="mlp", rounds=5, eval_every=2, seed=3,
                max_dataset=120, net=_net(), engine="async")
    base.update(kw)
    return Scenario(**base)


def _faulty(**kw):
    base = dict(churn=0.15, dropout=0.1, straggler_frac=0.4,
                straggler_scale=2.0, buffer_k=1, rounds=8, eval_every=10)
    base.update(kw)
    return _scenario(**base)


def _params_vec(sim):
    return np.concatenate([np.ravel(np.asarray(x))
                           for x in jax.tree.leaves(sim.params)])


def _assert_records_identical(a, b):
    """Bit-exact record equality (same engine on both sides)."""
    for f in dataclasses.fields(a):
        va, vb = getattr(a, f.name), getattr(b, f.name)
        if isinstance(va, np.ndarray):
            np.testing.assert_array_equal(va, vb, err_msg=f.name)
        else:
            assert va == vb, (f.name, va, vb)


def test_async_engine_registered():
    assert "async" in ENGINES and ENGINES["async"] is AsyncCohortEngine
    assert AsyncCohortEngine.supports_faults


# ---------------------------------------------------------------------------
# degenerate parity: zero faults + barrier == CohortEngine
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("policy", ["ddsra", "round_robin"])
def test_degenerate_parity_with_cohort(policy):
    sync = Simulation(_scenario(engine="cohort"))
    full_sync = list(sync.rounds(policy))
    asyn = Simulation(_scenario(engine="async"))
    full_async = list(asyn.rounds(policy))

    for a, b in zip(full_sync, full_async):
        assert a.t == b.t and a.trained == b.trained
        np.testing.assert_array_equal(a.selected, b.selected)
        np.testing.assert_array_equal(a.l_n, b.l_n)
        # identical schedule => identical queue trajectory, bit-for-bit
        # (the realized-queue override must not fire on fault-free rounds)
        np.testing.assert_array_equal(a.queues, b.queues)
        assert a.failures == b.failures
        np.testing.assert_allclose(a.delay, b.delay, rtol=1e-9)
        np.testing.assert_allclose(a.losses, b.losses, atol=1e-5)
        if a.accuracy is not None:
            np.testing.assert_allclose(a.accuracy, b.accuracy, atol=1e-2)
        # barrier-mode telemetry collapses to synchronous semantics
        assert b.aggregations == a.aggregations
        assert b.staleness_max == 0 and b.stale_discarded == 0
        assert b.buffer_fill == 0 and b.inflight == 0
    np.testing.assert_allclose(_params_vec(sync), _params_vec(asyn),
                               atol=1e-5)


def test_degenerate_parity_survives_checkpoint_resume(tmp_path):
    """Parity must hold even when the async run is checkpointed mid-stream
    (the engine side-car state round-trips through save/resume)."""
    sc = _scenario(engine="cohort", rounds=6)
    full_sync = list(Simulation(sc).rounds("ddsra"))

    asyn = Simulation(_scenario(engine="async", rounds=6))
    it = asyn.rounds("ddsra")
    head = [next(it) for _ in range(3)]
    asyn.save(tmp_path)
    asyn.flush()
    resumed = Simulation.resume(tmp_path)
    tail = list(resumed.rounds())
    for a, b in zip(full_sync, head + tail):
        np.testing.assert_array_equal(a.queues, b.queues)
        np.testing.assert_allclose(a.losses, b.losses, atol=1e-5)


# ---------------------------------------------------------------------------
# determinism + fault telemetry
# ---------------------------------------------------------------------------


def test_same_seed_same_faults_same_records():
    """The seed pins the whole faulted run: churn/straggler draws come from
    the network stream, so two runs yield identical RoundRecord streams."""
    a = list(Simulation(_faulty()).rounds("ddsra"))
    b = list(Simulation(_faulty()).rounds("ddsra"))
    for ra, rb in zip(a, b):
        _assert_records_identical(ra, rb)
    # and the faults actually fired somewhere in 8 rounds
    assert sum(r.dropped_devices for r in a) > 0
    assert sum(r.straggler_devices for r in a) > 0


def test_fault_rates_do_not_shift_the_channel_stream():
    """Two runs differing only in fault *rates* advance the network RNG
    stream identically per round (the fixed-draw-count contract), and the
    degenerate run advances it exactly like the synchronous engine (the
    zero-draw contract)."""
    sims = [Simulation(_faulty(churn=0.01, dropout=0.0)),
            Simulation(_faulty(churn=0.6, dropout=0.3))]
    for sim in sims:
        next(sim.rounds("ddsra"))
    assert (sims[0].net.rng.bit_generator.state
            == sims[1].net.rng.bit_generator.state)

    degen = Simulation(_scenario(engine="async"))
    sync = Simulation(_scenario(engine="cohort"))
    next(degen.rounds("ddsra"))
    next(sync.rounds("ddsra"))
    assert (degen.net.rng.bit_generator.state
            == sync.net.rng.bit_generator.state)


def test_staleness_accrues_and_max_staleness_discards():
    """buffer_k=1 with two dispatches per round leaves updates in flight
    across aggregations, so staleness must exceed 0; capping max_staleness
    at 0 then turns exactly those late updates into discards."""
    recs = list(Simulation(_faulty()).rounds("ddsra"))
    assert max(r.staleness_max for r in recs) >= 1
    assert all(r.aggregations in (0, 1) for r in recs)
    assert any(r.inflight > 0 for r in recs)

    capped = list(Simulation(_faulty(max_staleness=0)).rounds("ddsra"))
    assert sum(r.stale_discarded for r in capped) > 0


def test_inflight_counts_match_telemetry():
    sim = Simulation(_faulty())
    recs = list(sim.rounds("ddsra"))
    counts = sim.engine.inflight_counts(sim)
    assert counts.shape == (sim.net.cfg.n_gateways,)
    assert counts.sum() == recs[-1].inflight


def test_realized_queues_diverge_from_schedule_under_churn():
    """With heavy churn some selected gateway's update never lands, so the
    recorded queues must diverge from the scheduled Eq. (14) update — the
    realized-participation feedback actually fired."""
    from repro.core.lyapunov import update_queues
    sc = _faulty(churn=0.5, rounds=10, straggler_frac=0.0,
                 straggler_scale=0.0, buffer_k=None)   # land == same round
    sim = Simulation(sc)
    prev = np.zeros(sim.net.cfg.n_gateways)
    diverged = False
    for rec in sim.rounds("ddsra"):
        scheduled = update_queues(prev, rec.selected, sim.gamma)
        if not np.array_equal(scheduled, rec.queues):
            diverged = True
        prev = rec.queues
    assert diverged


# ---------------------------------------------------------------------------
# checkpoint/resume through a partially-filled buffer
# ---------------------------------------------------------------------------


def test_checkpoint_resume_bit_identical_through_buffer(tmp_path):
    """Interrupting a faulted buffered run mid-stream and resuming replays
    the uninterrupted run record-for-record — including rounds whose
    aggregation consumes updates dispatched *before* the checkpoint."""
    sc = _faulty(buffer_k=3)          # buffer carries entries across rounds
    uninterrupted = Simulation(sc)
    full = list(uninterrupted.rounds("ddsra"))

    sim = Simulation(sc)
    it = sim.rounds("ddsra")
    head, cut = [], 0
    for rec in it:
        head.append(rec)
        cut = rec.t + 1
        if rec.buffer_fill > 0 or rec.inflight > 0:
            break                     # engine state is genuinely non-empty
    assert head[-1].buffer_fill > 0 or head[-1].inflight > 0
    sim.save(tmp_path)
    sim.flush()
    assert list(tmp_path.glob("engine_*.npz"))     # side-car state written

    resumed = Simulation.resume(tmp_path)
    assert resumed.t == cut
    tail = list(resumed.rounds())
    assert len(head) + len(tail) == len(full)
    for a, b in zip(full, head + tail):
        _assert_records_identical(a, b)
    np.testing.assert_array_equal(_params_vec(uninterrupted),
                                  _params_vec(resumed))


# ---------------------------------------------------------------------------
# the non-blocking save contract
# ---------------------------------------------------------------------------


def test_save_is_nonblocking_and_flush_completes(tmp_path):
    sim = Simulation(_faulty())
    it = sim.rounds("ddsra")
    next(it)
    fname = sim.save(tmp_path)
    sim.flush()                       # after flush: everything on disk
    assert fname.exists()
    assert not list(tmp_path.glob("*.tmp")), "atomic renames left tmp files"
    assert Simulation.resume(tmp_path).t == 1


def test_save_block_true_writes_inline(tmp_path):
    sim = Simulation(_scenario(rounds=2))
    next(sim.rounds("round_robin"))
    fname = sim.save(tmp_path, block=True)
    assert fname.exists()             # no flush needed
    assert Simulation.resume(tmp_path).t == 1


def test_flush_reraises_background_write_errors(tmp_path):
    sim = Simulation(_scenario(rounds=2))
    next(sim.rounds("round_robin"))
    target = tmp_path / "not_a_dir"
    target.write_text("occupied")     # mkdir under a file must fail
    sim.save(target / "ckpt")
    with pytest.raises(OSError):
        sim.flush()
    sim.flush()                       # the error is consumed; writer lives
    sim.save(tmp_path)                # and still accepts new work
    sim.flush()
    assert Simulation.resume(tmp_path).t == 1
