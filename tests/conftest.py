# Smoke tests and benches must see the host's real device count (1 CPU);
# only repro.launch.dryrun (run as a subprocess) forces 512 host devices.
# No XLA_FLAGS are set here on purpose.
import contextlib

import numpy as np
import pytest


@pytest.fixture(autouse=True)
def _seed_numpy():
    np.random.seed(0)


class _CompileCounter:
    """Live view over one or more compile-count sources.

    A source is either ``(TRACE_COUNTS_dict, key)`` — the trace-time
    side-effect counters the repro modules expose (``repro.fl.cohort``,
    ``repro.fl.shard``, ``repro.core.ddsra_jax``) — or a jitted callable,
    read through ``_cache_size()``. ``count`` is the number of traces since
    the counter was entered, summed over all sources.
    """

    def __init__(self, sources):
        self._sources = tuple(sources)
        self._start = self._read()

    def _read(self) -> int:
        total = 0
        for s in self._sources:
            if isinstance(s, tuple):
                d, key = s
                total += d[key]
            else:
                total += s._cache_size()
        return total

    @property
    def count(self) -> int:
        return self._read() - self._start


@pytest.fixture
def compile_count():
    """Factory for compile/retrace counters (shared across the suite).

    Usage::

        with compile_count((cohort_lib.TRACE_COUNTS, "round")) as c:
            ... run rounds ...
        assert c.count <= 1          # one trace, zero retraces

    Pass several sources to count them jointly; pass a jitted function to
    count via its ``_cache_size()`` instead of a TRACE_COUNTS dict.
    ``c.count`` also reads *inside* the block (it is a live delta).
    """
    @contextlib.contextmanager
    def factory(*sources):
        yield _CompileCounter(sources)
    return factory
