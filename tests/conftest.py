# Smoke tests and benches must see the host's real device count (1 CPU);
# only repro.launch.dryrun (run as a subprocess) forces 512 host devices.
# No XLA_FLAGS are set here on purpose.
import numpy as np
import pytest


@pytest.fixture(autouse=True)
def _seed_numpy():
    np.random.seed(0)
