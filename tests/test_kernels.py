"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles
(interpret=True executes the kernel bodies on CPU)."""
import zlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ops import gqa_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.fused_linear import ops as fused_ops
from repro.kernels.fused_linear.kernel import (fused_linear,
                                               fused_linear_bwd_dw_db,
                                               fused_linear_bwd_dx, tile_plan)
from repro.kernels.fused_linear.ref import (fused_linear_bwd_dw_db_ref,
                                            fused_linear_bwd_dx_ref,
                                            fused_linear_ref)
from repro.kernels.ssd_scan.kernel import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_ref

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FLASH_CASES = [
    # (b, h, s, d, causal, window, bq, bk)
    (2, 2, 256, 64, True, None, 128, 128),
    (1, 4, 256, 128, True, None, 64, 64),
    (2, 1, 128, 64, False, None, 64, 128),
    (1, 2, 512, 64, True, 128, 128, 128),
    (1, 1, 128, 128, True, 64, 32, 32),
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("case", FLASH_CASES)
def test_flash_attention_matches_ref(case, dtype):
    b, h, s, d, causal, window, bq, bk = case
    keys = jax.random.split(jax.random.PRNGKey(hash(case) % 2**31), 3)
    q, k, v = (_rand(kk, (b, h, s, d), dtype) for kk in keys)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=bq, block_k=bk, interpret=True)
    ref = attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                        v.astype(jnp.float32), causal=causal, window=window)
    np.testing.assert_allclose(out.astype(jnp.float32), ref,
                               atol=TOL[dtype], rtol=TOL[dtype])


def test_gqa_wrapper_matches_grouped_ref():
    b, s, h, kvh, d = 2, 128, 8, 2, 64
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    q = _rand(keys[0], (b, s, h, d), jnp.float32)
    k = _rand(keys[1], (b, s, kvh, d), jnp.float32)
    v = _rand(keys[2], (b, s, kvh, d), jnp.float32)
    out = gqa_attention(q, k, v, interpret=True, use_pallas=True, block_q=64,
                        block_k=64)
    ref = gqa_attention(q, k, v, use_pallas=False)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


def test_flash_matches_model_layer_attention():
    """Kernel agrees with the model's chunked-jnp attention path."""
    from repro.models.layers import causal_attention
    b, s, h, d = 2, 128, 4, 64
    keys = jax.random.split(jax.random.PRNGKey(1), 3)
    q, k, v = (_rand(kk, (b, s, h, d), jnp.float32) for kk in keys)
    out = gqa_attention(q, k, v, interpret=True, block_q=64, block_k=64)
    ref = causal_attention(q, k, v, block_q=64)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# ssd scan
# ---------------------------------------------------------------------------

SSD_CASES = [
    # (b, s, n, p, ds, chunk, block_h)
    (2, 128, 8, 32, 16, 32, 4),
    (1, 256, 4, 64, 32, 64, 4),
    (1, 64, 2, 16, 8, 64, 2),
    (2, 256, 8, 64, 64, 128, 8),
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("case", SSD_CASES)
def test_ssd_scan_matches_sequential_ref(case, dtype):
    b, s, n, p, ds, chunk, bh = case
    keys = jax.random.split(jax.random.PRNGKey(hash(case) % 2**31), 5)
    xh = _rand(keys[0], (b, s, n, p), dtype)
    dt = jax.nn.softplus(_rand(keys[1], (b, s, n), jnp.float32)) * 0.5
    a_log = _rand(keys[2], (n,), jnp.float32) * 0.3
    b_ssm = (_rand(keys[3], (b, s, ds), jnp.float32) * 0.5).astype(dtype)
    c_ssm = (_rand(keys[4], (b, s, ds), jnp.float32) * 0.5).astype(dtype)
    out = ssd_scan(xh, dt, a_log, b_ssm, c_ssm, chunk=chunk, block_h=bh,
                   interpret=True)
    ref = ssd_ref(xh.astype(jnp.float32), dt, a_log,
                  b_ssm.astype(jnp.float32), c_ssm.astype(jnp.float32))
    tol = {jnp.float32: 1e-4, jnp.bfloat16: 5e-2}[dtype]
    np.testing.assert_allclose(out.astype(jnp.float32), ref, atol=tol, rtol=tol)


def test_ssd_op_vjp_matches_ref_grads():
    """The ssd_scan_vjp custom VJP: gradients through the (interpret) kernel
    forward equal gradients through the sequential oracle — the backward is
    a recompute through ssd_ref, so this pins that the residual plumbing and
    the impl dispatch agree."""
    from repro.kernels.ssd_scan.ops import ssd
    b, s, n, p, ds = 1, 64, 4, 16, 8
    keys = jax.random.split(jax.random.PRNGKey(7), 5)
    xh = _rand(keys[0], (b, s, n, p), jnp.float32)
    dt = jax.nn.softplus(_rand(keys[1], (b, s, n), jnp.float32)) * 0.5
    a_log = _rand(keys[2], (n,), jnp.float32) * 0.3
    b_ssm = _rand(keys[3], (b, s, ds), jnp.float32) * 0.5
    c_ssm = _rand(keys[4], (b, s, ds), jnp.float32) * 0.5

    def loss_via(impl):
        def f(xh_, bs_, cs_):
            y = ssd(xh_, dt, a_log, bs_, cs_, chunk=32, block_h=4,
                    impl=impl)
            return jnp.sum(y.astype(jnp.float32) ** 2)
        return jax.grad(f, argnums=(0, 1, 2))(xh, b_ssm, c_ssm)

    got = loss_via("interpret")
    want = loss_via("ref")
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, atol=2e-4, rtol=2e-4)


def test_model_chunked_ssd_matches_sequential_ref():
    """The model's own chunked SSD (repro.models.ssm) is also validated."""
    from repro.models.ssm import ssd_chunked
    b, s, n, p, ds = 2, 128, 4, 32, 16
    keys = jax.random.split(jax.random.PRNGKey(2), 5)
    xh = _rand(keys[0], (b, s, n, p), jnp.float32)
    dt = jax.nn.softplus(_rand(keys[1], (b, s, n), jnp.float32)) * 0.5
    a_log = _rand(keys[2], (n,), jnp.float32) * 0.3
    b_ssm = _rand(keys[3], (b, s, ds), jnp.float32) * 0.5
    c_ssm = _rand(keys[4], (b, s, ds), jnp.float32) * 0.5
    y, _ = ssd_chunked(xh, dt, a_log, b_ssm, c_ssm, chunk=32)
    ref = ssd_ref(xh, dt, a_log, b_ssm, c_ssm)
    np.testing.assert_allclose(y, ref, atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# fused linear
# ---------------------------------------------------------------------------

LIN_CASES = [
    # (m, k, n, act, bm, bn, bk)
    (128, 128, 128, "relu", 128, 128, 128),
    (256, 512, 128, "silu", 128, 128, 128),
    (64, 256, 512, "none", 64, 128, 64),
    (128, 384, 256, "gelu", 64, 128, 128),
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("case", LIN_CASES)
def test_fused_linear_matches_ref(case, dtype):
    m, k, n, act, bm, bn, bk = case
    keys = jax.random.split(jax.random.PRNGKey(hash(case) % 2**31), 3)
    x = _rand(keys[0], (m, k), dtype)
    w = _rand(keys[1], (k, n), dtype) / np.sqrt(k)
    b = _rand(keys[2], (n,), dtype)
    out = fused_linear(x, w, b, activation=act, block_m=bm, block_n=bn,
                       block_k=bk, interpret=True)
    ref = fused_linear_ref(x.astype(jnp.float32), w.astype(jnp.float32),
                           b.astype(jnp.float32), act)
    np.testing.assert_allclose(out.astype(jnp.float32), ref,
                               atol=TOL[dtype], rtol=TOL[dtype])


# ---------------------------------------------------------------------------
# fused linear backward kernels (interpret mode runs the kernel bodies)
# ---------------------------------------------------------------------------

BWD_CASES = [
    # (m, k, n, mask, bm, bn, bk) — non-square tiles included
    (128, 128, 128, "relu", 128, 128, 128),
    (256, 512, 128, "none", 128, 128, 128),
    (64, 256, 512, "relu", 32, 128, 64),
    (96, 160, 192, "relu", 48, 64, 32),
    (128, 384, 256, "none", 64, 128, 128),
]


def _seed(obj) -> int:
    """Deterministic across processes (str hashes are salted per run)."""
    return zlib.crc32(repr(obj).encode())


def _bwd_operands(case, dtype):
    m, k, n, mask, _, _, _ = case
    keys = jax.random.split(jax.random.PRNGKey(_seed(case)), 3)
    x = _rand(keys[0], (m, k), dtype)
    w = (_rand(keys[1], (k, n), jnp.float32) / np.sqrt(k)).astype(dtype)
    dy = _rand(keys[2], (m, n), dtype)
    y = fused_linear_ref(x, w, jnp.zeros((n,), dtype), "relu") \
        if mask == "relu" else None
    return x, w, dy, y


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("case", BWD_CASES)
def test_bwd_dx_kernel_matches_ref(case, dtype):
    m, k, n, mask, bm, bn, bk = case
    x, w, dy, y = _bwd_operands(case, dtype)
    out = fused_linear_bwd_dx(dy, w, y, mask=mask, block_m=bm, block_n=bn,
                              block_k=bk, interpret=True)
    ref = fused_linear_bwd_dx_ref(dy.astype(jnp.float32),
                                  w.astype(jnp.float32),
                                  None if y is None else y.astype(jnp.float32),
                                  mask=mask)
    np.testing.assert_allclose(out.astype(jnp.float32), ref,
                               atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("case", BWD_CASES)
def test_bwd_dw_db_kernel_matches_ref(case, dtype):
    m, k, n, mask, bm, bn, bk = case
    x, w, dy, y = _bwd_operands(case, dtype)
    dw, db = fused_linear_bwd_dw_db(x, dy, y, mask=mask, block_m=bm,
                                    block_n=bn, block_k=bk, interpret=True)
    dw_ref, db_ref = fused_linear_bwd_dw_db_ref(
        x.astype(jnp.float32), dy.astype(jnp.float32),
        None if y is None else y.astype(jnp.float32), mask=mask)
    tol = {jnp.float32: 1e-4, jnp.bfloat16: 1e-1}[dtype]
    np.testing.assert_allclose(dw.astype(jnp.float32), dw_ref,
                               atol=tol, rtol=tol)
    np.testing.assert_allclose(db.astype(jnp.float32), db_ref,
                               atol=tol, rtol=tol)


# ---------------------------------------------------------------------------
# the differentiable op: gradients through the Pallas path vs jax.grad(ref)
# ---------------------------------------------------------------------------

GRAD_SHAPES = [(128, 256, 128), (64, 128, 384)]   # tile-aligned, non-square


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("act", ["none", "relu", "silu", "gelu"])
@pytest.mark.parametrize("shape", GRAD_SHAPES)
def test_linear_grad_matches_ref_autodiff(shape, act, dtype):
    """Interpret-mode gradient check: the custom-VJP backward kernels agree
    with jax.grad of the pure-jnp oracle for every activation."""
    m, k, n = shape
    keys = jax.random.split(jax.random.PRNGKey(_seed((shape, act))), 4)
    x = _rand(keys[0], (m, k), dtype)
    w = (_rand(keys[1], (k, n), jnp.float32) / np.sqrt(k)).astype(dtype)
    b = _rand(keys[2], (n,), dtype)
    ct = _rand(keys[3], (m, n), jnp.float32)

    def loss_kernel(x, w, b):
        y = fused_ops.linear(x, w, b, activation=act, impl="interpret")
        return jnp.vdot(y.astype(jnp.float32), ct)

    def loss_ref(x, w, b):
        y = fused_linear_ref(x.astype(jnp.float32), w.astype(jnp.float32),
                             b.astype(jnp.float32), act)
        return jnp.vdot(y, ct)

    got = jax.grad(loss_kernel, argnums=(0, 1, 2))(x, w, b)
    want = jax.grad(loss_ref, argnums=(0, 1, 2))(x, w, b)
    tol = {jnp.float32: 1e-4, jnp.bfloat16: 1e-2}[dtype]
    for g, r, name in zip(got, want, ("dx", "dw", "db")):
        g, r = g.astype(jnp.float32), r.astype(jnp.float32)
        # bf16 storage quantizes large-scale grads to ~1e-2 relative either
        # way, so its atol scales with the gradient's own magnitude; f32
        # holds the strict 1e-4.
        scale = 1.0 if dtype == jnp.float32 \
            else max(1.0, float(jnp.max(jnp.abs(r))))
        np.testing.assert_allclose(g, r, atol=tol * scale, rtol=tol,
                                   err_msg=name)


@pytest.mark.parametrize("act", ["none", "relu", "gelu"])
def test_linear_backward_contains_no_transpose(act):
    """The training-path jaxpr of the Pallas/interpret impl must carry the
    operand transposes in BlockSpec index maps / dot_general dimension
    numbers only — no transpose primitive on w or x anywhere."""
    x = jnp.ones((128, 256))
    w = jnp.ones((256, 128))
    b = jnp.ones((128,))

    def loss(x, w, b):
        return fused_ops.linear(x, w, b, activation=act,
                                impl="interpret").sum()

    jaxpr = str(jax.make_jaxpr(jax.grad(loss, argnums=(0, 1, 2)))(x, w, b))
    assert "transpose" not in jaxpr
    # the ref fallback keeps the same property (dot_general dim numbers)
    def loss_ref(x, w, b):
        return fused_ops.linear(x, w, b, activation=act, impl="ref").sum()
    assert "transpose" not in str(
        jax.make_jaxpr(jax.grad(loss_ref, argnums=(0, 1, 2)))(x, w, b))


# ---------------------------------------------------------------------------
# tile_plan: the one shared clamping/alignment rule + the routing boundary
# ---------------------------------------------------------------------------

def test_tile_plan_clamps_per_dim_and_gates_kernels():
    plan = tile_plan(100, 300, 128)
    assert (plan.block_m, plan.block_k, plan.block_n) == (100, 128, 128)
    assert not plan.aligned            # 300 % 128 != 0
    assert tile_plan(100, 256, 128).aligned     # 100 clamps to one block
    assert tile_plan(127, 127, 127).aligned     # single full-size block
    assert not tile_plan(129, 128, 128).aligned


OFF_TILE = [127, 128, 129]


@pytest.mark.parametrize("act", ["relu", "silu"])
@pytest.mark.parametrize("m", OFF_TILE)
@pytest.mark.parametrize("k", OFF_TILE)
@pytest.mark.parametrize("n", OFF_TILE)
def test_routing_boundary_off_tile_shapes(m, k, n, act):
    """Property: whichever side of the pallas↔ref boundary tile_plan routes
    to, forward and all three backward contractions are correct — the exact
    shapes (127/129) that straddle the 128-tile alignment rule."""
    keys = jax.random.split(jax.random.PRNGKey(m * 10007 + k * 101 + n), 3)
    x = _rand(keys[0], (m, k), jnp.float32)
    w = _rand(keys[1], (k, n), jnp.float32) / np.sqrt(k)
    b = _rand(keys[2], (n,), jnp.float32)

    def loss_kernel(x, w, b):
        return fused_ops.linear(x, w, b, activation=act,
                                impl="interpret").sum()

    def loss_ref(x, w, b):
        return fused_linear_ref(x, w, b, act).sum()

    np.testing.assert_allclose(
        fused_ops.linear(x, w, b, activation=act, impl="interpret"),
        fused_linear_ref(x, w, b, act), atol=1e-4, rtol=1e-4)
    got = jax.grad(loss_kernel, argnums=(0, 1, 2))(x, w, b)
    want = jax.grad(loss_ref, argnums=(0, 1, 2))(x, w, b)
    for g, r, name in zip(got, want, ("dx", "dw", "db")):
        np.testing.assert_allclose(g, r, atol=1e-4, rtol=1e-4, err_msg=name)


@pytest.mark.parametrize("act", ["none", "relu", "silu", "gelu"])
@pytest.mark.parametrize("m,k,n", [(127, 128, 129), (129, 127, 128),
                                   (128, 256, 128)])
def test_routing_boundary_off_tile_bf16(m, k, n, act):
    """bf16 mixed-precision parity on the exact shapes straddling the
    128-tile routing boundary: forward and all three backward contractions
    of the bf16 path (f32 VMEM accumulation) match the f32 reference at
    bf16 storage tolerances, on both sides of the pallas↔ref boundary."""
    keys = jax.random.split(jax.random.PRNGKey(m * 211 + k * 31 + n), 3)
    x16 = _rand(keys[0], (m, k), jnp.bfloat16)
    w16 = (_rand(keys[1], (k, n), jnp.float32) / np.sqrt(k)
           ).astype(jnp.bfloat16)
    b16 = _rand(keys[2], (n,), jnp.bfloat16)
    x32, w32, b32 = (a.astype(jnp.float32) for a in (x16, w16, b16))

    def loss_kernel(x, w, b):
        return fused_ops.linear(x, w, b, activation=act,
                                impl="interpret").astype(jnp.float32).sum()

    def loss_ref(x, w, b):
        return fused_linear_ref(x, w, b, act).sum()

    tol = TOL[jnp.bfloat16]
    np.testing.assert_allclose(
        np.asarray(fused_ops.linear(x16, w16, b16, activation=act,
                                    impl="interpret"), jnp.float32),
        fused_linear_ref(x32, w32, b32, act), atol=tol, rtol=tol)
    got = jax.grad(loss_kernel, argnums=(0, 1, 2))(x16, w16, b16)
    want = jax.grad(loss_ref, argnums=(0, 1, 2))(x32, w32, b32)
    for g, r, name in zip(got, want, ("dx", "dw", "db")):
        assert g.dtype == jnp.bfloat16    # cotangents match operand storage
        g, r = np.asarray(g, jnp.float32), np.asarray(r)
        scale = max(1.0, float(np.max(np.abs(r))))
        np.testing.assert_allclose(g, r, atol=tol * scale, rtol=tol,
                                   err_msg=name)


def test_linear_bf16_e2e_no_transpose_pinned():
    """Pinned acceptance test for the mixed-precision data plane: the bf16
    fused_linear fwd+bwd passes parity vs the f32 reference at bf16
    tolerances AND its whole training-step jaxpr carries zero transpose
    primitives (operand transposition lives in BlockSpec index maps /
    dot_general dimension numbers only)."""
    m, k, n = 128, 256, 128
    keys = jax.random.split(jax.random.PRNGKey(7), 3)
    x = _rand(keys[0], (m, k), jnp.bfloat16)
    w = (_rand(keys[1], (k, n), jnp.float32) / np.sqrt(k)
         ).astype(jnp.bfloat16)
    b = _rand(keys[2], (n,), jnp.bfloat16)

    def loss(x, w, b):
        return fused_ops.linear(x, w, b, activation="relu",
                                impl="interpret").astype(jnp.float32).sum()

    jaxpr = str(jax.make_jaxpr(jax.grad(loss, argnums=(0, 1, 2)))(x, w, b))
    assert "transpose" not in jaxpr

    got = jax.grad(loss, argnums=(0, 1, 2))(x, w, b)
    want = jax.grad(
        lambda x_, w_, b_: fused_linear_ref(x_, w_, b_, "relu").sum(),
        argnums=(0, 1, 2))(*(a.astype(jnp.float32) for a in (x, w, b)))
    for g, r, name in zip(got, want, ("dx", "dw", "db")):
        g, r = np.asarray(g, jnp.float32), np.asarray(r)
        scale = max(1.0, float(np.max(np.abs(r))))
        np.testing.assert_allclose(g, r, atol=2e-2 * scale, rtol=2e-2,
                                   err_msg=name)
