"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles
(interpret=True executes the kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ops import gqa_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.fused_linear.kernel import fused_linear
from repro.kernels.fused_linear.ref import fused_linear_ref
from repro.kernels.ssd_scan.kernel import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_ref

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FLASH_CASES = [
    # (b, h, s, d, causal, window, bq, bk)
    (2, 2, 256, 64, True, None, 128, 128),
    (1, 4, 256, 128, True, None, 64, 64),
    (2, 1, 128, 64, False, None, 64, 128),
    (1, 2, 512, 64, True, 128, 128, 128),
    (1, 1, 128, 128, True, 64, 32, 32),
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("case", FLASH_CASES)
def test_flash_attention_matches_ref(case, dtype):
    b, h, s, d, causal, window, bq, bk = case
    keys = jax.random.split(jax.random.PRNGKey(hash(case) % 2**31), 3)
    q, k, v = (_rand(kk, (b, h, s, d), dtype) for kk in keys)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=bq, block_k=bk, interpret=True)
    ref = attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                        v.astype(jnp.float32), causal=causal, window=window)
    np.testing.assert_allclose(out.astype(jnp.float32), ref,
                               atol=TOL[dtype], rtol=TOL[dtype])


def test_gqa_wrapper_matches_grouped_ref():
    b, s, h, kvh, d = 2, 128, 8, 2, 64
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    q = _rand(keys[0], (b, s, h, d), jnp.float32)
    k = _rand(keys[1], (b, s, kvh, d), jnp.float32)
    v = _rand(keys[2], (b, s, kvh, d), jnp.float32)
    out = gqa_attention(q, k, v, interpret=True, use_pallas=True, block_q=64,
                        block_k=64)
    ref = gqa_attention(q, k, v, use_pallas=False)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


def test_flash_matches_model_layer_attention():
    """Kernel agrees with the model's chunked-jnp attention path."""
    from repro.models.layers import causal_attention
    b, s, h, d = 2, 128, 4, 64
    keys = jax.random.split(jax.random.PRNGKey(1), 3)
    q, k, v = (_rand(kk, (b, s, h, d), jnp.float32) for kk in keys)
    out = gqa_attention(q, k, v, interpret=True, block_q=64, block_k=64)
    ref = causal_attention(q, k, v, block_q=64)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# ssd scan
# ---------------------------------------------------------------------------

SSD_CASES = [
    # (b, s, n, p, ds, chunk, block_h)
    (2, 128, 8, 32, 16, 32, 4),
    (1, 256, 4, 64, 32, 64, 4),
    (1, 64, 2, 16, 8, 64, 2),
    (2, 256, 8, 64, 64, 128, 8),
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("case", SSD_CASES)
def test_ssd_scan_matches_sequential_ref(case, dtype):
    b, s, n, p, ds, chunk, bh = case
    keys = jax.random.split(jax.random.PRNGKey(hash(case) % 2**31), 5)
    xh = _rand(keys[0], (b, s, n, p), dtype)
    dt = jax.nn.softplus(_rand(keys[1], (b, s, n), jnp.float32)) * 0.5
    a_log = _rand(keys[2], (n,), jnp.float32) * 0.3
    b_ssm = (_rand(keys[3], (b, s, ds), jnp.float32) * 0.5).astype(dtype)
    c_ssm = (_rand(keys[4], (b, s, ds), jnp.float32) * 0.5).astype(dtype)
    out = ssd_scan(xh, dt, a_log, b_ssm, c_ssm, chunk=chunk, block_h=bh,
                   interpret=True)
    ref = ssd_ref(xh.astype(jnp.float32), dt, a_log,
                  b_ssm.astype(jnp.float32), c_ssm.astype(jnp.float32))
    tol = {jnp.float32: 1e-4, jnp.bfloat16: 5e-2}[dtype]
    np.testing.assert_allclose(out.astype(jnp.float32), ref, atol=tol, rtol=tol)


def test_model_chunked_ssd_matches_sequential_ref():
    """The model's own chunked SSD (repro.models.ssm) is also validated."""
    from repro.models.ssm import ssd_chunked
    b, s, n, p, ds = 2, 128, 4, 32, 16
    keys = jax.random.split(jax.random.PRNGKey(2), 5)
    xh = _rand(keys[0], (b, s, n, p), jnp.float32)
    dt = jax.nn.softplus(_rand(keys[1], (b, s, n), jnp.float32)) * 0.5
    a_log = _rand(keys[2], (n,), jnp.float32) * 0.3
    b_ssm = _rand(keys[3], (b, s, ds), jnp.float32) * 0.5
    c_ssm = _rand(keys[4], (b, s, ds), jnp.float32) * 0.5
    y, _ = ssd_chunked(xh, dt, a_log, b_ssm, c_ssm, chunk=32)
    ref = ssd_ref(xh, dt, a_log, b_ssm, c_ssm)
    np.testing.assert_allclose(y, ref, atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# fused linear
# ---------------------------------------------------------------------------

LIN_CASES = [
    # (m, k, n, act, bm, bn, bk)
    (128, 128, 128, "relu", 128, 128, 128),
    (256, 512, 128, "silu", 128, 128, 128),
    (64, 256, 512, "none", 64, 128, 64),
    (128, 384, 256, "gelu", 64, 128, 128),
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("case", LIN_CASES)
def test_fused_linear_matches_ref(case, dtype):
    m, k, n, act, bm, bn, bk = case
    keys = jax.random.split(jax.random.PRNGKey(hash(case) % 2**31), 3)
    x = _rand(keys[0], (m, k), dtype)
    w = _rand(keys[1], (k, n), dtype) / np.sqrt(k)
    b = _rand(keys[2], (n,), dtype)
    out = fused_linear(x, w, b, activation=act, block_m=bm, block_n=bn,
                       block_k=bk, interpret=True)
    ref = fused_linear_ref(x.astype(jnp.float32), w.astype(jnp.float32),
                           b.astype(jnp.float32), act)
    np.testing.assert_allclose(out.astype(jnp.float32), ref,
                               atol=TOL[dtype], rtol=TOL[dtype])
