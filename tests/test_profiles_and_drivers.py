"""Optimized-profile rules, train/serve driver smokes, checkpoint resume."""
import dataclasses
import pathlib

import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as cfg_lib
from repro.launch import specs as specs_lib


class _FakeMesh:
    """Just enough mesh for rules_for (axis sizes, no devices)."""
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


MESH1 = _FakeMesh({"data": 16, "model": 16})


def test_optimized_profile_decode_rules():
    cfg = cfg_lib.get_config("qwen2.5-32b")
    shape = cfg_lib.get_shape("decode_32k")
    base = specs_lib.rules_for(cfg, shape, MESH1)
    opt = specs_lib.rules_for(cfg, shape, MESH1, profile="optimized")
    assert base["hd"] == "model" and base["seq"] is None
    assert opt["hd"] is None and opt["seq"] == "model"   # §Perf winner


def test_optimized_profile_keeps_long500k_context_parallel():
    cfg = cfg_lib.get_config("jamba-v0.1-52b")
    shape = cfg_lib.get_shape("long_500k")
    opt = specs_lib.rules_for(cfg, shape, MESH1, profile="optimized")
    # context-parallel decode already shards seq over data; optimized profile
    # must not clobber it
    assert opt["seq"] == "data" and opt["batch"] is None


def test_optimized_profile_train_rules_unchanged():
    cfg = cfg_lib.get_config("stablelm-3b")
    shape = cfg_lib.get_shape("train_4k")
    base = specs_lib.rules_for(cfg, shape, MESH1)
    opt = specs_lib.rules_for(cfg, shape, MESH1, profile="optimized")
    assert base == opt


def test_train_driver_smoke(tmp_path):
    from repro.launch.train import train
    losses = train("granite-moe-1b-a400m", smoke=True, steps=6, batch=2,
                   seq=32, ckpt_dir=str(tmp_path), log_every=100)
    assert len(losses) == 6 and np.isfinite(losses).all()
    # checkpoint written and resumable
    from repro.checkpoint import latest_step
    assert latest_step(tmp_path) == 6
    more = train("granite-moe-1b-a400m", smoke=True, steps=8, batch=2,
                 seq=32, ckpt_dir=str(tmp_path), log_every=100)
    assert len(more) == 2          # resumed from step 6


def test_serve_driver_smoke():
    from repro.launch.serve import serve
    out = serve("stablelm-3b", smoke=True, batch=2, prompt_len=4, gen=4)
    assert out.shape == (2, 4)
    assert (out >= 0).all()


def test_pipeline_cut_on_real_arch_costs():
    """The paper's partition picks a mid cut for every assigned arch's cost
    vector under the bottleneck objective with ample memory."""
    from repro.core import costmodel as cm
    from repro.launch.pipeline import choose_cut
    for arch in cfg_lib.ARCHS:
        cfg = cfg_lib.get_config(arch)
        layers = cm.arch_layers(cfg, seq=4096)
        costs = cm.flops_vector(layers)
        mem = cm.mem_vector(layers, batch=1)
        cut = choose_cut(costs, mem, hbm_per_pod=1e18)
        c = np.concatenate([[0], np.cumsum(costs)])
        frac = c[cut.cut] / c[-1]
        assert 0.25 <= frac <= 0.75, (arch, frac)   # balanced-ish stages
