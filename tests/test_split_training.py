"""Split training correctness: a split step at ANY partition point computes
exactly the same update as unsplit SGD (the boundary activation/error
exchange is mathematically transparent)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.fl import split as split_lib
from repro.models import split_model as sm


@pytest.fixture(scope="module")
def setup():
    model = sm.MLPSplitModel(sizes=(48, 32, 16, 10))
    params = model.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 48))
    y = jax.random.randint(jax.random.PRNGKey(2), (8,), 0, 10)
    return model, params, x, y


def _direct_sgd(model, params, x, y, lr):
    def loss_of(p):
        return model.loss(model.forward(p, x), y)
    g = jax.grad(loss_of)(params)
    return jax.tree.map(lambda w, gw: w - lr * gw, params, g)


@pytest.mark.parametrize("l", [0, 1, 2, 3])
def test_split_step_equals_direct_sgd(setup, l):
    model, params, x, y = setup
    lr = jnp.float32(0.05)
    split_new, loss = split_lib.split_sgd_step(model, params, (x, y), l, lr)
    direct_new = _direct_sgd(model, params, x, y, 0.05)
    for a, b in zip(jax.tree.leaves(split_new), jax.tree.leaves(direct_new)):
        np.testing.assert_allclose(a, b, atol=1e-5, rtol=1e-5)
    assert jnp.isfinite(loss)


def test_split_vgg_all_cuts():
    model = sm.VGGSplitModel(width_mult=0.06)
    params = model.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32, 3))
    y = jax.random.randint(jax.random.PRNGKey(2), (2,), 0, 10)
    direct = _direct_sgd(model, params, x, y, 0.01)
    for l in (0, 4, 9, 13, 16):
        assert l in model.valid_cuts
        split_new, _ = split_lib.split_sgd_step(model, params, (x, y), l,
                                                jnp.float32(0.01))
        for a, b in zip(jax.tree.leaves(split_new), jax.tree.leaves(direct)):
            np.testing.assert_allclose(a, b, atol=2e-5, rtol=2e-5)


def test_local_train_reduces_loss(setup):
    model, params, x, y = setup
    p1, loss1 = split_lib.local_train(model, params, x, y, 2, 1, 0.05)
    p5, loss5 = split_lib.local_train(model, params, x, y, 2, 10, 0.05)
    assert loss5 < loss1
