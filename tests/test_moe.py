"""MoE dispatch correctness: the sort-based capacity dispatch equals a naive
per-token loop when capacity is not binding; capacity semantics when it is."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # container may lack hypothesis
from hypothesis import given, settings, strategies as st

from repro.configs.base import MoEConfig
from repro.models.moe import capacity, moe_ffn, router_topk


def _params(key, e, d, f):
    ks = jax.random.split(key, 4)
    return {
        "router": jax.random.normal(ks[0], (d, e)) * 0.1,
        "w1": jax.random.normal(ks[1], (e, d, f)) / np.sqrt(d),
        "w3": jax.random.normal(ks[2], (e, d, f)) / np.sqrt(d),
        "w2": jax.random.normal(ks[3], (e, f, d)) / np.sqrt(f),
    }


def _naive_moe(x, p, cfg: MoEConfig):
    """Per-token loop oracle (no capacity limit)."""
    b, s, d = x.shape
    xt = np.asarray(x.reshape(-1, d))
    gates, idx = router_topk(jnp.asarray(xt) @ p["router"], cfg.top_k)
    gates, idx = np.asarray(gates), np.asarray(idx)
    y = np.zeros_like(xt)
    for t in range(len(xt)):
        for j in range(cfg.top_k):
            e = idx[t, j]
            h = (jax.nn.silu(xt[t] @ p["w1"][e]) * (xt[t] @ p["w3"][e]))
            y[t] += gates[t, j] * np.asarray(h @ p["w2"][e])
    return y.reshape(b, s, d)


@pytest.mark.parametrize("e,k", [(4, 1), (4, 2), (8, 4)])
def test_moe_matches_naive_loop_when_no_drops(e, k):
    cfg = MoEConfig(n_experts=e, top_k=k, capacity_factor=float(e))  # no drops
    b, s, d, f = 2, 8, 16, 32
    key = jax.random.PRNGKey(e * 10 + k)
    p = _params(key, e, d, f)
    x = jax.random.normal(jax.random.fold_in(key, 9), (b, s, d))
    got = moe_ffn(x, p, cfg)
    want = _naive_moe(x, p, cfg)
    np.testing.assert_allclose(np.asarray(got), want, atol=1e-4, rtol=1e-3)


def test_moe_capacity_drops_bounded():
    """With capacity 1.0x and adversarial routing, output stays finite and
    dropped tokens contribute zero (residual-only)."""
    cfg = MoEConfig(n_experts=2, top_k=1, capacity_factor=0.1)
    b, s, d, f = 1, 64, 8, 16
    key = jax.random.PRNGKey(0)
    p = _params(key, 2, d, f)
    # force every token to expert 0: zero logits tie-break to the first expert
    p["router"] = jnp.zeros((d, 2))
    x = jax.random.normal(jax.random.fold_in(key, 1), (b, s, d))
    y = moe_ffn(x, p, cfg)
    assert jnp.isfinite(y).all()
    cap = capacity(b * s, cfg)
    nonzero_rows = int(jnp.sum(jnp.any(jnp.abs(y[0]) > 1e-9, axis=-1)))
    assert nonzero_rows <= cap


@settings(max_examples=20, deadline=None)
@given(st.integers(8, 200), st.integers(1, 8), st.integers(1, 8))
def test_capacity_formula(tokens, e, k):
    k = min(k, e)
    cfg = MoEConfig(n_experts=e, top_k=k, capacity_factor=1.25)
    c = capacity(tokens, cfg)
    assert c >= 8 and c % 8 == 0
    assert c * e >= tokens * k            # cf >= 1 never under-provisions


def test_router_topk_gates_normalized():
    logits = jax.random.normal(jax.random.PRNGKey(0), (32, 8))
    gates, idx = router_topk(logits, 3)
    np.testing.assert_allclose(np.asarray(gates.sum(-1)), 1.0, rtol=1e-5)
    assert int(idx.max()) < 8


def test_grouped_dispatch_matches_global_when_no_drops():
    """dispatch_groups changes locality, not math (given ample capacity)."""
    import dataclasses
    cfg1 = MoEConfig(n_experts=4, top_k=2, capacity_factor=8.0)
    cfg4 = dataclasses.replace(cfg1, dispatch_groups=4)
    b, s, d, f = 4, 8, 16, 32
    key = jax.random.PRNGKey(3)
    p = _params(key, 4, d, f)
    x = jax.random.normal(jax.random.fold_in(key, 5), (b, s, d))
    y1 = moe_ffn(x, p, cfg1)
    y4 = moe_ffn(x, p, cfg4)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y4),
                               atol=1e-5, rtol=1e-5)
