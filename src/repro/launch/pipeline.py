"""Pod-axis pipeline split — the paper's DNN partition mapped to TPU pods.

The paper's device/gateway tier split becomes a two-stage GPipe pipeline
over the multi-pod mesh's ``pod`` axis: pod 0 (≙ device tier) owns the
bottom layers, pod 1 (≙ gateway tier) owns the top layers; boundary
activations flow pod0->pod1 over ICI during forward and boundary errors
flow pod1->pod0 during backward — exactly the split-learning exchange of
Sec. II-B3, with ``repro.core.partition.best_partition`` choosing the cut
from per-layer TPU costs instead of WiFi rates.

Implementation: ``shard_map`` over the pod axis; each pod runs its stage on
a microbatch stream; ``jax.lax.ppermute`` moves boundary tensors between
stages. Stage weights are stacked with a leading pod dim so each pod reads
only its own slice (true pipeline parallelism, not replication).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.partition import Tier, best_partition


@dataclasses.dataclass(frozen=True)
class PipelineCut:
    """Chosen partition for a layered model on a 2-pod mesh."""
    cut: int              # layers [0, cut) on pod 0, [cut, L) on pod 1
    n_layers: int

    @property
    def stage_layers(self) -> Tuple[int, int]:
        return self.cut, self.n_layers - self.cut


def choose_cut(costs: np.ndarray, mem: np.ndarray, hbm_per_pod: float,
               boundary_bytes: Optional[np.ndarray] = None,
               ici_bw: float = 50e9, throughput: float = 197e12 * 256) -> PipelineCut:
    """Run the paper's bisection over TPU per-layer costs (sub-problem 21)."""
    tier = Tier(throughput=throughput, mem_capacity=hbm_per_pod)
    cut = best_partition(costs, mem, tier, tier,
                         boundary_bytes=boundary_bytes, link_bw=ici_bw,
                         objective="bottleneck")
    if cut is None:
        raise ValueError("no feasible pipeline partition")
    return PipelineCut(cut, len(costs))


def _stage_apply(layer_fn: Callable, stage_params, x, n_layers: int):
    """Run ``n_layers`` stacked layers sequentially on this stage."""
    def body(c, lp):
        return layer_fn(lp, c), None
    y, _ = jax.lax.scan(body, x, stage_params)
    return y


def gpipe_forward(layer_fn: Callable, params_stacked, x,
                  mesh, n_micro: int, layers_per_stage: int):
    """Two-stage GPipe forward over the 'pod' mesh axis.

    params_stacked: pytree with leading dims (2, layers_per_stage, ...)
                    sharded P('pod', ...); x: (B, ...) batch-partitioned
                    microbatch stream (B = n_micro * mb).
    Returns y: (B, ...) logits-side activations produced by stage 1.

    Schedule: n_micro + 1 ticks; at each tick stage 0 consumes microbatch i
    and ppermutes its boundary activation to stage 1, which processes the
    previous tick's activation (classic 1F1B fill/drain for 2 stages).
    """
    pod_axis = "pod"

    def per_pod(stage_params, xs):
        # stage_params: (1, layers_per_stage, ...) local slice; drop pod dim
        stage_params = jax.tree.map(lambda t: t[0], stage_params)
        pod_id = jax.lax.axis_index(pod_axis)
        mb = jnp.reshape(xs, (n_micro, xs.shape[0] // n_micro) + xs.shape[1:])

        def tick(carry, i):
            pending = carry                   # activation received last tick
            my_in = jnp.where(pod_id == 0,
                              mb[jnp.minimum(i, n_micro - 1)], pending)
            out = _stage_apply(layer_fn, stage_params, my_in, layers_per_stage)
            # stage0 -> stage1 handoff
            recv = jax.lax.ppermute(out, pod_axis, [(0, 1)])
            # only stage 1 emits finished microbatches; psum makes the
            # result identical on both pods (out_specs is replicated)
            y_done = jax.lax.psum(
                jnp.where(pod_id == 1, out, jnp.zeros_like(out)), pod_axis)
            return recv, y_done

        _, ys = jax.lax.scan(tick, jnp.zeros_like(mb[0]), jnp.arange(n_micro + 1))
        # stage 1 produced valid outputs on ticks 1..n_micro
        ys = ys[1:]
        return jnp.reshape(ys, xs.shape)

    spec_params = jax.tree.map(lambda _: P(pod_axis), params_stacked)
    return shard_map(
        per_pod, mesh=mesh,
        in_specs=(spec_params, P(None)),
        out_specs=P(None),
        check_rep=False,
    )(params_stacked, x)


# ---------------------------------------------------------------------------
# demo layer: the fused-linear unit the split-FL experiment uses
# ---------------------------------------------------------------------------


def mlp_layer_fn(lp, x):
    return jax.nn.relu(x @ lp["w"] + lp["b"])


def build_demo(mesh, n_layers: int = 8, width: int = 512, batch: int = 32,
               n_micro: int = 4, rng=None):
    """A runnable 2-stage pipeline demo (also used by tests)."""
    assert n_layers % 2 == 0
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(rng)
    w = jax.random.normal(k1, (2, n_layers // 2, width, width)) * (width ** -0.5)
    b = jnp.zeros((2, n_layers // 2, width))
    x = jax.random.normal(k2, (batch, width))
    params = {"w": w, "b": b}
    y = gpipe_forward(mlp_layer_fn, params, x, mesh, n_micro, n_layers // 2)
    return params, x, y


def reference_forward(params, x):
    """Unpipelined oracle for the demo."""
    w = params["w"].reshape(-1, *params["w"].shape[2:])
    b = params["b"].reshape(-1, *params["b"].shape[2:])
    for i in range(w.shape[0]):
        x = jax.nn.relu(x @ w[i] + b[i])
    return x
