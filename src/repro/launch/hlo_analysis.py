"""Post-compile HLO analysis: collective-traffic accounting + roofline terms.

``cost_analysis()`` gives HLO FLOPs and bytes, but not collective traffic;
we parse the optimized HLO text and sum the tensor bytes flowing through
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute. (Ring-algorithm per-link factors ~(n-1)/n are folded
into the link-bandwidth constant, not modeled per op.)
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"\b(pred|[suf]\d+|bf16|c64|c128)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES.get(dt, 4)
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum output-tensor bytes per collective kind over the whole module."""
    out = {k: 0 for k in COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if "=" not in stripped:
            continue
        for kind in COLLECTIVES:
            # match `= <type> kind(` — kind as the op, not a metadata mention
            m = re.search(r"=\s+(.+?)\s+%?" + kind + r"(-start|-done)?\(", stripped)
            if m:
                if m.group(2) == "-done":
                    break               # counted at -start
                out[kind] += _shape_bytes(m.group(1))
                out["count"] += 1
                break
    out["total"] = sum(out[k] for k in COLLECTIVES)
    return out


# hardware constants (TPU v5e, per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link


@dataclasses.dataclass
class Roofline:
    flops: float
    hbm_bytes: float
    coll_bytes: float
    chips: int

    @property
    def t_compute(self) -> float:
        return self.flops / (self.chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / (self.chips * ICI_BW)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    def as_dict(self) -> dict:
        return {
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "hlo_flops": self.flops,
            "hlo_bytes": self.hbm_bytes,
            "collective_bytes": self.coll_bytes,
            "chips": self.chips,
        }


def roofline_from_compiled(compiled, mesh) -> Roofline:
    """cost_analysis() describes the per-device SPMD program; scale by chip
    count so Roofline holds GLOBAL quantities (its terms divide by chips)."""
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    chips = mesh.devices.size
    flops = float(cost.get("flops", 0.0)) * chips
    byts = float(cost.get("bytes accessed", 0.0)) * chips
    coll = collective_bytes(compiled.as_text())
    return Roofline(flops, byts, float(coll["total"]) * chips, chips)
