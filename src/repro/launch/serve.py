"""Batched decode/serving driver: prefill-free cache warmup + greedy decode.

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-2.7b --smoke \
        --batch 4 --prompt-len 32 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as cfg_lib
from repro.models import get_bundle
from repro.models import model as model_lib
from repro.models import params as params_lib


def serve(arch: str, smoke: bool, batch: int, prompt_len: int, gen: int,
          cache_len: int = 128, seed: int = 0, ring: bool = False):
    bundle = get_bundle(arch, smoke=smoke)
    cfg = bundle.cfg
    params = bundle.init(jax.random.PRNGKey(seed))
    cache_t = bundle.cache_template(batch, cache_len, enc_len=16)
    cache = params_lib.init_params(jax.random.PRNGKey(1), cache_t)
    if cfg.enc_layers:
        enc = jax.random.normal(jax.random.PRNGKey(2), (batch, 16, cfg.d_model))
        enc_out = model_lib.encode_for_decode(params, enc, cfg)
        cache = model_lib.fill_cross_cache(params, cache, enc_out, cfg)

    step = jax.jit(lambda p, c, t, pos: model_lib.serve_step(
        p, c, t, pos, cfg, ring=ring))

    rng = np.random.default_rng(seed)
    prompt = rng.integers(0, cfg.vocab, (batch, prompt_len)).astype(np.int32)
    # feed prompt token by token (decode-mode prefill)
    t0 = time.time()
    logits = None
    for i in range(prompt_len):
        logits, cache = step(params, cache, jnp.asarray(prompt[:, i:i + 1]),
                             jnp.int32(i))
    generated = []
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    for i in range(gen):
        generated.append(np.asarray(tok))
        logits, cache = step(params, cache, tok, jnp.int32(prompt_len + i))
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    dt = time.time() - t0
    out = np.concatenate(generated, axis=1)
    tput = batch * (prompt_len + gen) / dt
    print(f"{arch}: served {batch} seqs, {prompt_len}+{gen} tokens each, "
          f"{tput:.1f} tok/s ({dt:.1f}s total)")
    return out


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="mamba2-2.7b", choices=list(cfg_lib.ARCHS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--ring", action="store_true")
    args = ap.parse_args(argv)
    serve(args.arch, args.smoke, args.batch, args.prompt_len, args.gen,
          ring=args.ring)


if __name__ == "__main__":
    main()
