"""Production mesh construction.

Defined as FUNCTIONS so importing this module never touches jax device
state; the dry-run entrypoint sets XLA_FLAGS before importing anything.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model).
    Multi-pod: 2 pods x 256 = 512 chips (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever this host actually has (CPU smoke runs): 1D data mesh."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))


def batch_axes(mesh) -> tuple:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)
