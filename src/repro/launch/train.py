"""LM training driver: same code path on host CPU (reduced configs) as on
the production mesh (full configs).

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-14b --smoke \
        --steps 50 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as cfg_lib
from repro.checkpoint import latest_step, load_pytree, save_pytree
from repro.data import markov_stream
from repro.models import get_bundle
from repro.models import model as model_lib
from repro.optim import adamw, clip_by_global_norm, cosine_schedule
from repro.optim.optimizers import apply_updates


def train(arch: str, smoke: bool, steps: int, batch: int, seq: int,
          lr: float = 3e-4, ckpt_dir=None, log_every: int = 10,
          seed: int = 0):
    bundle = get_bundle(arch, smoke=smoke)
    cfg = bundle.cfg
    stream = markov_stream(cfg.vocab, seq, batch, seed)

    params = bundle.init(jax.random.PRNGKey(seed))
    opt = adamw(cosine_schedule(lr, warmup=max(steps // 20, 5), total=steps),
                weight_decay=0.01)
    opt_state = opt.init(params)
    start = 0
    if ckpt_dir and (s := latest_step(ckpt_dir)) is not None:
        params = load_pytree(f"{ckpt_dir}/step_{s:08d}.npz", params)
        start = s

    @jax.jit
    def step_fn(params, opt_state, tokens, labels):
        batch_d = {"tokens": tokens, "labels": labels}
        if cfg.enc_layers:
            batch_d["enc_frames"] = jnp.zeros(
                (tokens.shape[0], 16, cfg.d_model), params["final_norm"].dtype)
        loss, grads = jax.value_and_grad(
            lambda p: model_lib.loss_fn(p, batch_d, cfg))(params)
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        upd, opt_state = opt.update(grads, opt_state, params)
        return apply_updates(params, upd), opt_state, loss, gnorm

    losses = []
    t0 = time.time()
    for i in range(start, steps):
        b = stream.next_batch()
        params, opt_state, loss, gnorm = step_fn(
            params, opt_state, jnp.asarray(b["tokens"]), jnp.asarray(b["labels"]))
        losses.append(float(loss))
        if (i + 1) % log_every == 0:
            dt = (time.time() - t0) / (i + 1 - start)
            print(f"step {i+1:5d}  loss {float(loss):.4f}  gnorm {float(gnorm):.2f} "
                  f" {dt*1e3:.0f} ms/step  (floor ~{stream.entropy_floor():.2f})")
        if ckpt_dir and (i + 1) % 100 == 0:
            save_pytree(ckpt_dir, params, step=i + 1)
    if ckpt_dir:
        save_pytree(ckpt_dir, params, step=steps)
    return losses


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="stablelm-3b", choices=list(cfg_lib.ARCHS))
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args(argv)
    losses = train(args.arch, args.smoke, args.steps, args.batch, args.seq,
                   lr=args.lr, ckpt_dir=args.ckpt_dir)
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")


if __name__ == "__main__":
    main()
