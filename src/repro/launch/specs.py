"""Per-(arch x shape x mesh) step functions, abstract inputs and shardings.

``input_specs`` returns ShapeDtypeStruct stand-ins for every model input —
weak-type-correct, shardable, no device allocation — as required by the
multi-pod dry-run. ``build_case`` packages (fn, abstract args, in_shardings)
ready for ``jax.jit(...).lower(...)``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs as cfg_lib
from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import model as model_lib
from repro.models import params as params_lib
from repro.optim import adamw
from repro.launch.mesh import batch_axes

ENC_LEN = 1024          # stubbed audio frontend frames (precomputed embeddings)
RING_FAMILIES = ("dense", "vlm", "moe", "audio")


def is_ring(cfg: ArchConfig, shape: ShapeConfig) -> bool:
    """long_500k on full-attention archs -> sliding-window ring cache."""
    return shape.name == "long_500k" and cfg.family in RING_FAMILIES


def cache_len_for(cfg: ArchConfig, shape: ShapeConfig) -> int:
    return cfg.window if is_ring(cfg, shape) else shape.seq_len


def rules_for(cfg: ArchConfig, shape: ShapeConfig, mesh,
              profile: str = "baseline") -> Dict[str, Any]:
    rules = params_lib.rules_for_mesh(mesh)
    if shape.mode == "decode" and shape.global_batch < _axis_size(mesh, rules["batch"]):
        # long_500k: batch=1 cannot use the batch axes; context-parallel the
        # cache sequence dim over 'data' instead (SSM/hybrid full caches).
        rules["batch"] = None
        rules["seq"] = None if is_ring(cfg, shape) else "data"
    if profile == "optimized" and shape.mode == "decode" and rules.get("seq") is None:
        # SPerf winner (qwen2.5 decode): shard the cache sequence dim over
        # 'model' instead of head_dim — kills the GQA resharding full-remat
        # (collective term 26x down on qwen2.5-32b x decode_32k).
        rules["hd"] = None
        rules["seq"] = "model"
    return rules


def _axis_size(mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    import numpy as np
    return int(np.prod([mesh.shape[a] for a in axes]))


def _shard(mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def _batch_spec(cfg: ArchConfig, shape: ShapeConfig, mesh, rules) -> Dict[str, P]:
    b_ax = rules["batch"]
    specs = {"tokens": P(b_ax, None)}
    if shape.mode == "train":
        specs["labels"] = P(b_ax, None)
    if cfg.enc_layers:
        specs["enc_frames"] = P(b_ax, None, None)
    return specs


def abstract_batch(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    b = shape.global_batch
    s = shape.seq_len if shape.mode != "decode" else 1
    out = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    if shape.mode == "train":
        out["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    if cfg.enc_layers:
        out["enc_frames"] = jax.ShapeDtypeStruct((b, ENC_LEN), jnp.int32)
        # frames arrive as embeddings; see input_specs
        out["enc_frames"] = jax.ShapeDtypeStruct((b, ENC_LEN, cfg.d_model), jnp.bfloat16)
    return out


@dataclasses.dataclass
class Case:
    """One dry-run case: jit-able fn + abstract args + shardings."""
    name: str
    fn: Callable
    args: Tuple[Any, ...]
    in_shardings: Tuple[Any, ...]
    donate: Tuple[int, ...] = ()


def input_specs(arch: str, shape_name: str) -> Dict[str, jax.ShapeDtypeStruct]:
    """Public helper: ShapeDtypeStruct stand-ins for every model input."""
    cfg = cfg_lib.get_config(arch)
    shape = cfg_lib.get_shape(shape_name)
    return abstract_batch(cfg, shape)


def acts_for(cfg: ArchConfig, rules) -> model_lib.ActShardings:
    b_ax = rules["batch"]
    return model_lib.ActShardings(
        residual=P(b_ax, None, None),
        logits=P(b_ax, None, rules.get("vocab")),
    )


def build_case(arch: str, shape_name: str, mesh, *, dtype=jnp.bfloat16,
               remat: bool = True, extra_rules: Optional[dict] = None,
               n_layers: Optional[int] = None, unroll: bool = False,
               microbatch: int = 4,
               grad_acc_dtype=jnp.float32,
               moment_dtype=jnp.float32,
               moe_groups: Optional[int] = None,
               profile: str = "baseline") -> Case:
    import dataclasses as _dc
    cfg = cfg_lib.get_config(arch)
    shape = cfg_lib.get_shape(shape_name)
    if n_layers is not None:
        enc = min(cfg.enc_layers, n_layers)
        cfg = _dc.replace(cfg, n_layers=n_layers, enc_layers=enc)
    if moe_groups and cfg.moe is not None:
        cfg = _dc.replace(cfg, moe=_dc.replace(cfg.moe,
                                               dispatch_groups=moe_groups))
    if profile == "optimized" and cfg.moe is not None:
        # SPerf winner: shard-local (grouped) MoE dispatch + room for bf16
        # moments is selected by the train path below
        groups = _axis_size(mesh, rules_for(cfg, shape, mesh)["batch"])
        if shape.mode != "decode" or shape.global_batch % max(groups, 1) == 0:
            if moe_groups is None and groups > 1:
                cfg = _dc.replace(cfg, moe=_dc.replace(cfg.moe,
                                                       dispatch_groups=groups))
    rules = rules_for(cfg, shape, mesh, profile)
    if extra_rules:
        rules.update(extra_rules)
    acts = acts_for(cfg, rules)

    template = model_lib.build_template(cfg)
    params_abs = params_lib.abstract_params(template, dtype)
    params_specs = params_lib.partition_specs(template, mesh, rules)
    params_sh = jax.tree.map(lambda s: _shard(mesh, s), params_specs)

    batch_abs = abstract_batch(cfg, shape)
    batch_specs = _batch_spec(cfg, shape, mesh, rules)
    batch_sh = {k: _shard(mesh, v) for k, v in batch_specs.items()}

    if shape.mode == "train":
        if profile == "optimized":
            moment_dtype = jnp.bfloat16      # SPerf winner: state HBM halves
        opt = adamw(1e-4, weight_decay=0.1, moment_dtype=moment_dtype)
        opt_abs = jax.eval_shape(opt.init, params_abs)
        opt_sh = {
            "step": _shard(mesh, P()),
            "m": params_sh, "v": params_sh,
        }

        # gradient accumulation: activations live for one microbatch only
        n_micro = max(1, microbatch)
        assert shape.global_batch % n_micro == 0

        def loss_of(p, b):
            return model_lib.loss_fn(p, b, cfg, remat=remat, acts=acts,
                                     unroll=unroll)

        def train_step(params, opt_state, batch):
            if n_micro == 1:
                loss, grads = jax.value_and_grad(loss_of)(params, batch)
            else:
                mb = jax.tree.map(
                    lambda t: t.reshape(t.shape[0] // n_micro, n_micro,
                                        *t.shape[1:]).swapaxes(0, 1), batch)

                def acc_fn(carry, b):
                    loss_i, g_i = jax.value_and_grad(loss_of)(params, b)
                    l_acc, g_acc = carry
                    return (l_acc + loss_i,
                            jax.tree.map(lambda a, g: a + g.astype(a.dtype),
                                         g_acc, g_i)), None

                zero = (jnp.zeros((), jnp.float32),
                        jax.tree.map(lambda p: jnp.zeros(p.shape, grad_acc_dtype),
                                     params))
                if unroll:
                    # cost-measurement path: unrolled so XLA cost analysis
                    # sees every microbatch (a scanned body is counted once)
                    carry = zero
                    for i in range(n_micro):
                        carry, _ = acc_fn(carry, jax.tree.map(lambda t: t[i], mb))
                    loss, grads = carry
                else:
                    (loss, grads), _ = jax.lax.scan(acc_fn, zero, mb)
                loss = loss / n_micro
                grads = jax.tree.map(lambda g: g / n_micro, grads)
            upd, opt_state = opt.update(grads, opt_state, params)
            params = jax.tree.map(lambda p, u: (p + u).astype(p.dtype),
                                  params, upd)
            return params, opt_state, loss

        return Case(f"{arch}:{shape_name}", train_step,
                    (params_abs, opt_abs, batch_abs),
                    (params_sh, opt_sh, batch_sh), donate=(0, 1))

    if shape.mode == "prefill":
        def prefill(params, batch):
            return model_lib.forward(params, batch, cfg, acts=acts,
                                      unroll=unroll)

        return Case(f"{arch}:{shape_name}", prefill,
                    (params_abs, batch_abs), (params_sh, batch_sh))

    # decode
    clen = cache_len_for(cfg, shape)
    ring = is_ring(cfg, shape)
    cache_t = model_lib.cache_template(cfg, shape.global_batch, clen,
                                       enc_len=ENC_LEN if cfg.enc_layers else 0)
    cache_abs = params_lib.abstract_params(cache_t, dtype)
    cache_specs = params_lib.partition_specs(cache_t, mesh, rules)
    cache_sh = jax.tree.map(lambda s: _shard(mesh, s), cache_specs)
    pos_val = shape.seq_len - 1

    def decode_step(params, cache, tokens):
        return model_lib.serve_step(params, cache, tokens, jnp.int32(pos_val),
                                    cfg, ring=ring, acts=acts, unroll=unroll)

    tok_abs = batch_abs["tokens"]
    tok_sh = batch_sh["tokens"]
    return Case(f"{arch}:{shape_name}", decode_step,
                (params_abs, cache_abs, tok_abs),
                (params_sh, cache_sh, tok_sh), donate=(1,))
