import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove every (arch x input-shape x mesh) combination
lowers and compiles on the production meshes, and extract the roofline terms.

    PYTHONPATH=src python -m repro.launch.dryrun --arch stablelm-3b \
        --shape train_4k [--multi-pod] [--out artifacts/dryrun]

The XLA_FLAGS line above MUST run before any other import (jax locks the
device count at first init); do not move it.
"""
import argparse      # noqa: E402
import json          # noqa: E402
import pathlib       # noqa: E402
import sys           # noqa: E402
import time          # noqa: E402

import jax           # noqa: E402

from repro import configs as cfg_lib                     # noqa: E402
from repro.launch import specs as specs_lib              # noqa: E402
from repro.launch.hlo_analysis import (collective_bytes,  # noqa: E402
                                       roofline_from_compiled)
from repro.launch.mesh import make_production_mesh       # noqa: E402
from repro.models.model import pattern_of                # noqa: E402


def model_pattern(cfg):
    pat = pattern_of(cfg)
    return pat


def _compile(case, mesh):
    with mesh:
        jitted = jax.jit(case.fn, in_shardings=case.in_shardings,
                         donate_argnums=case.donate)
        lowered = jitted.lower(*case.args)
        return lowered.compile()


def _cost_terms(compiled, mesh):
    roof = roofline_from_compiled(compiled, mesh)
    return roof.flops, roof.hbm_bytes, roof.coll_bytes


def run_case(arch: str, shape: str, multi_pod: bool, out_dir=None,
             extra_rules=None, remat: bool = True, verbose: bool = True,
             profile: str = "baseline") -> dict:
    """Compile the full-depth model (memory + sharding proof), plus two
    shallow-depth replicas whose costs are linearly extrapolated to full
    depth — XLA's cost analysis counts a while(scan) body once, so the raw
    full-depth numbers undercount by ~n_units."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    case = specs_lib.build_case(arch, shape, mesh, extra_rules=extra_rules,
                                remat=remat, profile=profile)
    t0 = time.time()
    compiled = _compile(case, mesh)
    t_compile = time.time() - t0

    cfg_full = cfg_lib.get_config(arch)
    plen = len(model_pattern(cfg_full))
    d1, d2 = plen, 2 * plen
    f1 = _cost_terms(_compile(specs_lib.build_case(
        arch, shape, mesh, extra_rules=extra_rules, remat=remat,
        n_layers=d1, unroll=True, microbatch=1, profile=profile), mesh), mesh)
    f2 = _cost_terms(_compile(specs_lib.build_case(
        arch, shape, mesh, extra_rules=extra_rules, remat=remat,
        n_layers=d2, unroll=True, microbatch=1, profile=profile), mesh), mesh)
    scale = (cfg_full.n_layers - d1) / (d2 - d1)
    flops, hbm_bytes, coll_total = (
        a + (b - a) * scale for a, b in zip(f1, f2))

    from repro.launch.hlo_analysis import Roofline
    mem = compiled.memory_analysis()
    roof = Roofline(flops, hbm_bytes, coll_total, mesh.devices.size)
    coll = collective_bytes(compiled.as_text())
    coll["total_extrapolated"] = int(coll_total)
    cfg = cfg_lib.get_config(arch)
    shape_cfg = cfg_lib.get_shape(shape)
    tokens = shape_cfg.global_batch * (shape_cfg.seq_len if shape_cfg.mode != "decode" else 1)
    n_active = cfg.n_active_params
    mult = {"train": 6, "prefill": 2, "decode": 2}[shape_cfg.mode]
    model_flops = mult * n_active * tokens

    result = {
        "arch": arch, "shape": shape,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "mode": shape_cfg.mode,
        "ok": True,
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "peak_bytes": (mem.argument_size_in_bytes + mem.temp_size_in_bytes
                           + mem.output_size_in_bytes),
        },
        "roofline": roof.as_dict(),
        "collectives": coll,
        "model_flops": model_flops,
        "useful_flops_frac": model_flops / max(roof.flops, 1.0),
    }
    if verbose:
        m = result["memory"]
        print(f"[{result['mesh']}] {arch} x {shape}: compile {t_compile:.1f}s")
        print(f"  memory/device: args {m['argument_bytes']/2**30:.2f} GiB, "
              f"temp {m['temp_bytes']/2**30:.2f} GiB")
        r = result["roofline"]
        print(f"  roofline: compute {r['t_compute_s']:.3e}s  memory "
              f"{r['t_memory_s']:.3e}s  collective {r['t_collective_s']:.3e}s "
              f"-> {r['bottleneck']}-bound")
        print(f"  HLO flops {r['hlo_flops']:.3e}  model flops {model_flops:.3e} "
              f"(useful frac {result['useful_flops_frac']:.2f})  "
              f"collective bytes {coll['total']/2**30:.2f} GiB "
              f"({coll['count']} ops)")
    if out_dir is not None:
        out_dir = pathlib.Path(out_dir)
        out_dir.mkdir(parents=True, exist_ok=True)
        tag = f"{arch}_{shape}_{result['mesh'].replace('x', '-')}"
        (out_dir / f"{tag}.json").write_text(json.dumps(result, indent=2))
    return result


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True, choices=list(cfg_lib.ARCHS) + ["all"])
    ap.add_argument("--shape", required=True,
                    choices=list(cfg_lib.SHAPES) + ["all"])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--profile", default="baseline",
                    choices=["baseline", "optimized"],
                    help="'optimized' applies the §Perf winning shardings")
    args = ap.parse_args(argv)

    archs = list(cfg_lib.ARCHS) if args.arch == "all" else [args.arch]
    shapes = list(cfg_lib.SHAPES) if args.shape == "all" else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    run_case(arch, shape, mp, out_dir=args.out,
                             remat=not args.no_remat, profile=args.profile)
                except Exception as e:  # noqa: BLE001 — report and continue
                    print(f"FAIL {arch} x {shape} mesh={'2pod' if mp else '1pod'}: "
                          f"{type(e).__name__}: {e}")
                    failures.append((arch, shape, mp))
    if failures:
        print(f"{len(failures)} failures: {failures}")
        return 1
    print("all dry-run cases compiled OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
