import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Perf hillclimbing driver (§Perf): hypothesis -> change -> re-lower ->
re-analyse, on the dominant roofline term of a chosen (arch x shape) pair.

Each named VARIANT is a concrete change (sharding rule, microbatch count,
grad-accumulation dtype, remat policy, cache layout) with the hypothesis
recorded next to it. Results land in artifacts/hillclimb/<arch>_<shape>.json
and are summarised into EXPERIMENTS.md §Perf.

    PYTHONPATH=src python -m repro.launch.hillclimb --arch stablelm-3b \
        --shape train_4k
"""
import argparse     # noqa: E402
import json         # noqa: E402
import pathlib      # noqa: E402
import time         # noqa: E402

import jax          # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro import configs as cfg_lib                 # noqa: E402
from repro.launch import specs as specs_lib          # noqa: E402
from repro.launch.dryrun import _compile, _cost_terms, model_pattern  # noqa: E402
from repro.launch.hlo_analysis import Roofline       # noqa: E402
from repro.launch.mesh import make_production_mesh   # noqa: E402


# name -> (hypothesis, build_case kwargs)
TRAIN_VARIANTS = {
    "baseline": (
        "paper-faithful baseline: FSDP over data, f32 grad accumulation, "
        "microbatch=4, remat", {}),
    "micro1": (
        "one microbatch: weights gathered once per fwd+bwd instead of 4x -> "
        "collective term ~/3, memory term up (activations live longer)",
        dict(microbatch=1)),
    "micro8": (
        "more microbatches: lower activation memory, but 8x weight regathers "
        "-> collective term up (expected regression, bounds the knob)",
        dict(microbatch=8)),
    "grad_bf16": (
        "accumulate/all-reduce grads in bf16: halves the gradient collective "
        "bytes at the cost of summation precision",
        dict(grad_acc_dtype=jnp.bfloat16)),
    "micro1_grad_bf16": (
        "combine the two collective wins",
        dict(microbatch=1, grad_acc_dtype=jnp.bfloat16)),
    "no_fsdp": (
        "replicate weights over 'data' (no FSDP): removes per-layer weight "
        "all-gathers entirely; HBM must absorb full weights + opt state",
        dict(extra_rules={"embed": None})),
    "no_remat": (
        "disable activation checkpointing: compute term -1/3 (no recompute), "
        "memory term up",
        dict(remat=False)),
    "experts_f_shard": (
        "MoE only: shard expert hidden dim F over 'data' instead of the "
        "expert D dim: expert GEMMs become reduce-scatter-shaped, dispatch "
        "buffer (E,C,D) stops being regathered per microbatch",
        dict(extra_rules={"moe_d": None, "moe_f": "data"})),
    "moe_grouped": (
        "MoE: dispatch in 16 data-aligned groups — routing argsort/scatter "
        "stay shard-local so the global token all-gather disappears; only "
        "the (G,E,C,D) x (E,D,F) expert GEMM crosses the mesh",
        dict(moe_groups=16)),
    "moe_grouped_micro1": (
        "grouped dispatch + single microbatch (combine the two wins)",
        dict(moe_groups=16, microbatch=1)),
    "adam_bf16_moments": (
        "bf16 Adam moments: optimizer state HBM and its read/write traffic "
        "halve; fp32 update math preserved — targets the memory term that "
        "no sharding variant moved",
        dict(moment_dtype=jnp.bfloat16)),
    "best_combo": (
        "bf16 moments + grouped dispatch + micro8 (lowest temp) together",
        dict(moment_dtype=jnp.bfloat16, moe_groups=16, microbatch=8,
             grad_acc_dtype=jnp.bfloat16)),
}

DECODE_VARIANTS = {
    "baseline": ("baseline: cache head_dim sharded over 'model'", {}),
    "cache_seq_model": (
        "shard the cache SEQUENCE dim over 'model' instead of head_dim: "
        "avoids the GQA reshape resharding (involuntary full remat warning); "
        "softmax reduces over the sharded axis with an all-reduce",
        dict(extra_rules={"hd": None, "seq": "model"})),
    "cache_replicated_hd": (
        "replicate head_dim, shard only batch: no resharding at all, "
        "memory term up by model-axis factor",
        dict(extra_rules={"hd": None})),
}

PREFILL_VARIANTS = {
    "baseline": ("baseline rules", {}),
    "experts_2d": (
        "shard MoE expert FFN hidden dim over 'data' as well (2D expert "
        "sharding): halves dispatch-buffer memory per device, adds "
        "reduce-scatter inside each expert GEMM",
        dict(extra_rules={"mlp": "data"})),
    "no_fsdp": (
        "replicate non-expert weights over 'data': fewer gathers on the "
        "attention path", dict(extra_rules={"embed": None})),
    "experts_f_shard": (
        "MoE: shard expert hidden dim F over 'data' instead of expert D",
        dict(extra_rules={"moe_d": None, "moe_f": "data"})),
    "moe_grouped": (
        "MoE: 16 data-aligned dispatch groups — shard-local routing, "
        "no global token all-gather",
        dict(moe_groups=16)),
}


def variants_for(mode: str):
    return {"train": TRAIN_VARIANTS, "decode": DECODE_VARIANTS,
            "prefill": PREFILL_VARIANTS}[mode]


def measure(arch: str, shape: str, mesh, **kw) -> dict:
    """Full-depth compile (memory) + shallow unrolled extrapolation (cost)."""
    case = specs_lib.build_case(arch, shape, mesh, **kw)
    t0 = time.time()
    compiled = _compile(case, mesh)
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()

    cfg = cfg_lib.get_config(arch)
    plen = len(model_pattern(cfg))
    d1, d2 = plen, 2 * plen
    kw_cost = dict(kw)   # unroll=True below also unrolls the microbatch loop
    f1 = _cost_terms(_compile(specs_lib.build_case(
        arch, shape, mesh, n_layers=d1, unroll=True, **kw_cost), mesh), mesh)
    f2 = _cost_terms(_compile(specs_lib.build_case(
        arch, shape, mesh, n_layers=d2, unroll=True, **kw_cost), mesh), mesh)
    scale = (cfg.n_layers - d1) / (d2 - d1)
    flops, hbm, coll = (a + (b - a) * scale for a, b in zip(f1, f2))
    roof = Roofline(flops, hbm, coll, mesh.devices.size)
    return {
        "compile_s": round(t_compile, 1),
        "temp_gib": mem.temp_size_in_bytes / 2**30,
        "args_gib": mem.argument_size_in_bytes / 2**30,
        **roof.as_dict(),
    }


def hillclimb(arch: str, shape: str, out_dir="artifacts/hillclimb",
              only=None) -> dict:
    mode = cfg_lib.get_shape(shape).mode
    mesh = make_production_mesh()
    log = {"arch": arch, "shape": shape, "mesh": "16x16", "iterations": []}
    for name, (hypothesis, kw) in variants_for(mode).items():
        if only and name not in only:
            continue
        print(f"--- {arch} x {shape} [{name}]")
        print(f"    hypothesis: {hypothesis}")
        try:
            m = measure(arch, shape, mesh, **kw)
        except Exception as e:  # noqa: BLE001
            m = {"error": f"{type(e).__name__}: {e}"}
        entry = {"variant": name, "hypothesis": hypothesis, **m}
        log["iterations"].append(entry)
        if "error" in m:
            print(f"    ERROR {m['error']}")
        else:
            print(f"    compute {m['t_compute_s']:.3e}s  memory "
                  f"{m['t_memory_s']:.3e}s  collective {m['t_collective_s']:.3e}s"
                  f"  temp {m['temp_gib']:.1f} GiB -> {m['bottleneck']}")
    out = pathlib.Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    (out / f"{arch}_{shape}.json").write_text(json.dumps(log, indent=2))
    return log


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True, choices=list(cfg_lib.ARCHS))
    ap.add_argument("--shape", required=True, choices=list(cfg_lib.SHAPES))
    ap.add_argument("--only", nargs="*", default=None)
    args = ap.parse_args(argv)
    hillclimb(args.arch, args.shape, only=args.only)


if __name__ == "__main__":
    main()
