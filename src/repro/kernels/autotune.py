"""Block-shape autotuner + persistent kernel-selection table.

The hand-written ``tile_plan`` heuristic (fixed 128^3 blocks clamped to the
problem) leaves roofline performance on the table: the best block shape for
a Pallas kernel depends on the problem shape, the dtype (bf16 halves HBM
traffic and doubles the useful VMEM tile budget) and the backend. This
module sweeps candidate block shapes per (op, shape, dtype, backend), times
each candidate through the *existing* jit/interpret call paths, and caches
the winners in a TensorRT-LLM-style selection table:

* **Persistent table** — one JSON file per op under ``artifacts/autotune/``
  (override with ``REPRO_AUTOTUNE_DIR``), keyed
  ``op|shape|dtype|backend``. Entries carry their own (shape, dtype,
  backend, blocks, us) so the key is re-derivable — CI validates the
  committed tables round-trip (load -> schema -> deterministic re-key).
* **In-process LRU** — resolved plans (including fallbacks) are memoized,
  so the hot path costs one dict hit per traced shape.
* **Exact-match -> clamped-heuristic fallback** — a lookup miss returns
  the op's default blocks (the historical 128-aligned heuristic, clamped
  by ``tile_plan`` at the call site). Cold keys never trigger a sweep and
  therefore never block a training round; sweeps only run when explicitly
  requested (``benchmarks/kernel_bench.py --autotune`` or the
  ``sweep_*`` functions here).

``blocks_for`` is the single source of block defaults for every op layer
(``fused_linear/ops.py``, ``flash_attention/ops.py``, ``ssd_scan/ops.py``)
— the old per-module ``_BLOCKS`` constants are gone, so routing
(``tile_plan``) and kernel block choices can never drift.
"""
from __future__ import annotations

import collections
import functools
import itertools
import json
import os
import pathlib
import time
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

TABLE_VERSION = 1

# op -> (default blocks, block-tuple arity, block field names).
# fused_linear blocks are (block_m, block_k, block_n) — TilePlan field
# order; flash_attention (block_q, block_k); ssd_scan (chunk, block_h).
OPS: Dict[str, Tuple[Tuple[int, ...], Tuple[str, ...]]] = {
    "fused_linear": ((128, 128, 128), ("block_m", "block_k", "block_n")),
    "flash_attention": ((128, 128), ("block_q", "block_k")),
    "ssd_scan": ((128, 8), ("chunk", "block_h")),
}

_POW2 = (32, 64, 128, 256, 512)
_VMEM_F32_BUDGET = 3 << 20          # ~12 MB of f32 words across resident tiles
_LRU_MAX = 1024


def table_dir() -> pathlib.Path:
    """Directory holding the per-op selection tables (JSON)."""
    env = os.environ.get("REPRO_AUTOTUNE_DIR", "")
    if env:
        return pathlib.Path(env)
    return pathlib.Path(__file__).resolve().parents[3] / "artifacts" / "autotune"


def backend_id(interpret: bool = False) -> str:
    """Selection-table backend key: the jax backend, ``-interpret`` when the
    kernels run under the Pallas interpreter (their timings differ wildly
    from compiled TPU timings, so they must never share entries)."""
    import jax
    return jax.default_backend() + ("-interpret" if interpret else "")


def make_key(op: str, shape: Sequence[int], dtype: str, backend: str) -> str:
    """``op|shape|dtype|backend`` — the deterministic table key."""
    return f"{op}|{'x'.join(str(int(s)) for s in shape)}|{dtype}|{backend}"


# ---------------------------------------------------------------------------
# table load / store
# ---------------------------------------------------------------------------

_TABLES: Dict[str, Dict[str, dict]] = {}          # op -> entries (in-process)
_LRU: "collections.OrderedDict[str, Tuple[int, ...]]" = collections.OrderedDict()


def _table_path(op: str) -> pathlib.Path:
    return table_dir() / f"{op}.json"


def _entries(op: str) -> Dict[str, dict]:
    """Lazily-loaded entries for ``op``; a missing or corrupt table is an
    empty one (the heuristic fallback must never be blocked by disk state)."""
    if op not in _TABLES:
        try:
            payload = json.loads(_table_path(op).read_text())
            entries = payload["entries"]
            assert isinstance(entries, dict)
        except (OSError, ValueError, KeyError, AssertionError):
            entries = {}
        _TABLES[op] = entries
    return _TABLES[op]


def clear_cache() -> None:
    """Drop the in-process table + LRU caches (tests; table regeneration)."""
    _TABLES.clear()
    _LRU.clear()


def _valid_blocks(op: str, blocks) -> Optional[Tuple[int, ...]]:
    default, _ = OPS[op]
    if (isinstance(blocks, (list, tuple)) and len(blocks) == len(default)
            and all(isinstance(b, int) and b > 0 for b in blocks)):
        return tuple(blocks)
    return None


def blocks_for(op: str, shape: Sequence[int], dtype: str, *,
               interpret: bool = False,
               backend: Optional[str] = None) -> Tuple[int, ...]:
    """Resolve block sizes for one kernel call site.

    Resolution order: in-process LRU -> exact table match -> the op's
    default blocks (the clamped-128 heuristic). Never sweeps, never
    raises on missing/corrupt tables — a cold key costs one dict miss.
    The caller still clamps/validates through ``tile_plan`` (or the
    kernel's own divisibility asserts), so a stale table entry can only
    cost performance, never correctness.
    """
    default, _ = OPS[op]
    key = make_key(op, shape, dtype, backend or backend_id(interpret))
    if key in _LRU:
        _LRU.move_to_end(key)
        return _LRU[key]
    entry = _entries(op).get(key)
    blocks = _valid_blocks(op, entry.get("blocks")) if entry else None
    if blocks is None:
        blocks = default
    _LRU[key] = blocks
    if len(_LRU) > _LRU_MAX:
        _LRU.popitem(last=False)
    return blocks


def record(op: str, shape: Sequence[int], dtype: str, backend: str,
           blocks: Sequence[int], us: float, baseline_us: float,
           *, save: bool = True) -> dict:
    """Store a sweep winner in the table (and on disk when ``save``)."""
    key = make_key(op, shape, dtype, backend)
    entry = {
        "shape": [int(s) for s in shape],
        "dtype": dtype,
        "backend": backend,
        "blocks": [int(b) for b in blocks],
        "us": float(us),
        "baseline_us": float(baseline_us),
        "speedup_vs_default": float(baseline_us / us) if us > 0 else 1.0,
    }
    _entries(op)[key] = entry
    _LRU.pop(key, None)
    if save:
        save_table(op)
    return entry


def save_table(op: str) -> pathlib.Path:
    """Write ``op``'s entries to its JSON table (sorted keys: stable diffs)."""
    path = _table_path(op)
    path.parent.mkdir(parents=True, exist_ok=True)
    payload = {
        "version": TABLE_VERSION,
        "op": op,
        "entries": {k: _entries(op)[k] for k in sorted(_entries(op))},
    }
    path.write_text(json.dumps(payload, indent=2) + "\n")
    return path


def validate_table(op: str) -> int:
    """Strict round-trip check of ``op``'s on-disk table, for CI.

    Loads the JSON, validates the schema, and re-derives every key from
    the entry's own (shape, dtype, backend) fields — a renamed/edited key
    or a blocks tuple of the wrong arity fails loudly here (unlike the
    forgiving runtime ``blocks_for`` path). Returns the entry count; a
    missing table is 0 entries.
    """
    path = _table_path(op)
    if not path.exists():
        return 0
    payload = json.loads(path.read_text())
    if payload.get("version") != TABLE_VERSION or payload.get("op") != op:
        raise ValueError(f"{path}: bad version/op header: "
                         f"{payload.get('version')!r}/{payload.get('op')!r}")
    entries = payload["entries"]
    for key, e in entries.items():
        rekey = make_key(op, e["shape"], e["dtype"], e["backend"])
        if rekey != key:
            raise ValueError(f"{path}: key {key!r} does not round-trip "
                             f"(re-derived {rekey!r})")
        if _valid_blocks(op, e["blocks"]) is None:
            raise ValueError(f"{path}: entry {key!r} has bad blocks "
                             f"{e['blocks']!r}")
        if not (float(e["us"]) > 0 and float(e["baseline_us"]) > 0):
            raise ValueError(f"{path}: entry {key!r} has non-positive timing")
    return len(entries)


# ---------------------------------------------------------------------------
# candidate enumeration
# ---------------------------------------------------------------------------


def _dim_candidates(dim: int) -> List[int]:
    """Power-of-two divisors of ``dim`` (<= 512) plus ``dim`` itself: every
    value yields an exactly-aligned tiling after ``tile_plan`` clamping."""
    out = [c for c in _POW2 if c <= dim and dim % c == 0]
    if dim <= 512 and dim not in out:
        out.append(dim)
    return sorted(out)


def candidates(op: str, shape: Sequence[int],
               max_candidates: int = 24) -> List[Tuple[int, ...]]:
    """Aligned candidate block tuples for (op, shape), VMEM-bounded.

    fused_linear shape is (m, k, n); flash_attention (b, h, s, d);
    ssd_scan (b, s, n, p, ds). The list is capped at ``max_candidates``,
    preferring larger blocks (fewer grid steps, better MXU utilization),
    and the clamped default blocks are always included when aligned — so
    a sweep can never pick something worse than the heuristic on its own
    timing metric.
    """
    if op == "fused_linear":
        m, k, n = shape
        combos = [
            (bm, bk, bn)
            for bm, bk, bn in itertools.product(
                _dim_candidates(m), _dim_candidates(k), _dim_candidates(n))
            # fwd tiles (bm,bk)+(bk,bn)+(bm,bn) resident in VMEM at once
            if bm * bk + bk * bn + bm * bn <= _VMEM_F32_BUDGET
        ]
    elif op == "flash_attention":
        b, h, s, d = shape
        combos = [
            (bq, bk)
            for bq, bk in itertools.product(_dim_candidates(s), repeat=2)
            if (bq + 2 * bk) * d + bq * bk + 2 * bq * d <= _VMEM_F32_BUDGET
        ]
    elif op == "ssd_scan":
        b, s, n, p, ds = shape
        combos = [
            (chunk, bh)
            for chunk in _dim_candidates(s)
            for bh in (1, 2, 4, 8, 16)
            if n % min(bh, n) == 0
            and chunk * chunk * bh + bh * ds * p <= _VMEM_F32_BUDGET
        ]
        combos = sorted(set((c, min(bh, n)) for c, bh in combos))
    else:
        raise KeyError(f"unknown op {op!r}; known: {sorted(OPS)}")
    combos = sorted(set(combos),
                    key=lambda c: (-_volume(c), c))[:max_candidates]
    default = tuple(min(b, s_) for b, s_ in _clamp_pairs(op, shape))
    aligned = all(s_ % min(b, s_) == 0
                  for b, s_ in _clamp_pairs(op, shape))
    if aligned and default not in combos:
        combos.append(default)
    return sorted(combos)


def _volume(blocks: Tuple[int, ...]) -> int:
    v = 1
    for b in blocks:
        v *= b
    return v


def _clamp_pairs(op: str, shape: Sequence[int]) -> Iterable[Tuple[int, int]]:
    """(default block, clamping dim) pairs — which shape axis each block
    dimension clamps against."""
    default, _ = OPS[op]
    if op == "fused_linear":
        m, k, n = shape
        dims = (m, k, n)
    elif op == "flash_attention":
        dims = (shape[2], shape[2])          # both blocks tile the seq axis
    else:                                    # ssd_scan
        dims = (shape[1], shape[2])          # chunk | seq, block_h | heads
    return zip(default, dims)


# ---------------------------------------------------------------------------
# sweeps (explicit only — the lookup path never calls these)
# ---------------------------------------------------------------------------


def _time_call(fn, *args, iters: int = 3, repeats: int = 2) -> float:
    """us/call: warm up (compile), then best mean over ``repeats`` runs."""
    import jax
    jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, (time.perf_counter() - t0) / iters)
    return best * 1e6


def _default_interpret() -> bool:
    import jax
    return jax.default_backend() != "tpu"


def sweep_fused_linear(m: int, k: int, n: int, dtype: str = "float32",
                       *, activation: str = "relu",
                       interpret: Optional[bool] = None, iters: int = 3,
                       save: bool = True, seed: int = 0) -> dict:
    """Sweep (block_m, block_k, block_n) for one fused_linear GEMM shape and
    record the winner. Times the *forward* kernel; the backward kernels tile
    the same (m, k, n) triple, so one winner routes the whole custom VJP
    (see ``fused_linear/ops.py``)."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.fused_linear.kernel import fused_linear, tile_plan
    from repro.kernels.fused_linear.ref import fused_linear_ref

    interpret = _default_interpret() if interpret is None else interpret
    jdt = jnp.dtype(dtype)
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jax.random.normal(ks[0], (m, k), jnp.float32).astype(jdt)
    w = (jax.random.normal(ks[1], (k, n), jnp.float32) / max(k, 1) ** 0.5
         ).astype(jdt)
    b = jnp.zeros((n,), jdt)

    def timed(blocks) -> float:
        bm, bk, bn = blocks
        fn = jax.jit(functools.partial(
            fused_linear, activation=activation, block_m=bm, block_k=bk,
            block_n=bn, interpret=interpret))
        return _time_call(fn, x, w, b, iters=iters)

    default, _ = OPS["fused_linear"]
    base_plan = tile_plan(m, k, n, block_m=default[0], block_n=default[2],
                          block_k=default[1])
    if base_plan.aligned:
        baseline = timed((base_plan.block_m, base_plan.block_k,
                          base_plan.block_n))
    else:    # default plan would route to ref — that's the time to beat
        fn = jax.jit(lambda a, b_, c: fused_linear_ref(a, b_, c, activation))
        baseline = _time_call(fn, x, w, b, iters=iters)

    cands = candidates("fused_linear", (m, k, n))
    if not cands:
        return None          # no aligned tiling exists; ref path only
    best_blocks, best_us = None, float("inf")
    for cand in cands:
        us = timed(cand)
        if us < best_us:
            best_blocks, best_us = cand, us
    return record("fused_linear", (m, k, n), str(jdt),
                  backend_id(interpret), best_blocks, best_us, baseline,
                  save=save)


def sweep_flash_attention(b: int, h: int, s: int, d: int,
                          dtype: str = "float32", *, causal: bool = True,
                          interpret: Optional[bool] = None, iters: int = 3,
                          save: bool = True, seed: int = 0) -> dict:
    """Sweep (block_q, block_k) for one (B, H, S, D) attention shape."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.flash_attention.kernel import flash_attention

    interpret = _default_interpret() if interpret is None else interpret
    jdt = jnp.dtype(dtype)
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q, k_, v = (jax.random.normal(kk, (b, h, s, d), jnp.float32).astype(jdt)
                for kk in ks)

    def timed(blocks) -> float:
        bq, bk = blocks
        fn = jax.jit(functools.partial(
            flash_attention, causal=causal, block_q=bq, block_k=bk,
            interpret=interpret))
        return _time_call(fn, q, k_, v, iters=iters)

    cands = candidates("flash_attention", (b, h, s, d))
    if not cands:
        return None
    default, _ = OPS["flash_attention"]
    base = tuple(min(c, s) for c in default)
    if all(s % c == 0 for c in base):
        baseline = timed(base)
    else:    # default blocks can't tile s — the jnp oracle is the time to beat
        from repro.kernels.flash_attention.ref import attention_ref
        fn = jax.jit(functools.partial(attention_ref, causal=causal))
        baseline = _time_call(fn, q, k_, v, iters=iters)
    best_blocks, best_us = None, float("inf")
    for cand in cands:
        us = timed(cand)
        if us < best_us:
            best_blocks, best_us = cand, us
    return record("flash_attention", (b, h, s, d), str(jdt),
                  backend_id(interpret), best_blocks, best_us, baseline,
                  save=save)


def sweep_ssd_scan(b: int, s: int, n: int, p: int, ds: int,
                   dtype: str = "float32", *,
                   interpret: Optional[bool] = None, iters: int = 3,
                   save: bool = True, seed: int = 0) -> dict:
    """Sweep (chunk, block_h) for one (B, S, n, p, ds) SSD-scan shape."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.ssd_scan.kernel import ssd_scan

    interpret = _default_interpret() if interpret is None else interpret
    jdt = jnp.dtype(dtype)
    k = jax.random.PRNGKey(seed)
    xh = jax.random.normal(k, (b, s, n, p), jnp.float32).astype(jdt)
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(k, 1),
                                           (b, s, n))) * 0.5
    a_log = jax.random.normal(jax.random.fold_in(k, 2), (n,)) * 0.3
    b_ssm = (jax.random.normal(jax.random.fold_in(k, 3), (b, s, ds)) * 0.5
             ).astype(jdt)
    c_ssm = (jax.random.normal(jax.random.fold_in(k, 4), (b, s, ds)) * 0.5
             ).astype(jdt)

    def timed(blocks) -> float:
        chunk, bh = blocks
        fn = jax.jit(functools.partial(ssd_scan, chunk=chunk, block_h=bh,
                                       interpret=interpret))
        return _time_call(fn, xh, dt, a_log, b_ssm, c_ssm, iters=iters)

    cands = candidates("ssd_scan", (b, s, n, p, ds))
    if not cands:
        return None
    default, _ = OPS["ssd_scan"]
    base = (min(default[0], s), min(default[1], n))
    if s % base[0] == 0 and n % base[1] == 0:
        baseline = timed(base)
    else:
        from repro.kernels.ssd_scan.ref import ssd_ref
        fn = jax.jit(ssd_ref)
        baseline = _time_call(fn, xh, dt, a_log, b_ssm, c_ssm, iters=iters)
    best_blocks, best_us = None, float("inf")
    for cand in cands:
        us = timed(cand)
        if us < best_us:
            best_blocks, best_us = cand, us
    return record("ssd_scan", (b, s, n, p, ds), str(jdt),
                  backend_id(interpret), best_blocks, best_us, baseline,
                  save=save)


def _main() -> None:
    import argparse
    ap = argparse.ArgumentParser(
        description="Validate the committed kernel-selection tables "
                    "(load -> schema -> deterministic re-key).")
    ap.add_argument("--check", action="store_true",
                    help="strict round-trip validation of every op table")
    args = ap.parse_args()
    if args.check:
        for op in OPS:
            n = validate_table(op)
            print(f"{op}: {n} entries OK ({_table_path(op)})")


if __name__ == "__main__":
    _main()
