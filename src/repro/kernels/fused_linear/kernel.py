"""Fused matmul + bias + activation Pallas TPU kernels, forward and backward.

The per-layer unit of work of the paper's split training (each partitioned
fc/conv-as-GEMM layer is exactly one of these). Three kernels share one
tiling contract (:func:`tile_plan`):

* :func:`fused_linear` — forward ``act(x @ w + b)``. Grid (M/bm, N/bn, K/bk)
  with K innermost-sequential; partial products accumulate in a VMEM fp32
  scratch; bias + activation fuse into the final K step, saving one HBM
  round-trip of the (M, N) output versus unfused matmul-then-activation.
* :func:`fused_linear_bwd_dx` — ``dx = dz @ wᵀ`` without materializing
  ``w.T``: the BlockSpec index map hands the kernel ``w`` blocks indexed
  ``(ki, ni)`` and ``dot_general`` contracts both operands on their trailing
  (N) axis, so the transpose exists only in the block-index arithmetic.
* :func:`fused_linear_bwd_dw_db` — ``dw = xᵀ @ dz`` (same trick: ``x``
  blocks indexed ``(mi, ki)``, contraction on the leading M axis) with the
  ``db = Σ_m dz`` column reduction fused into the first K-block's pass over
  M, so ``dz`` is read once for both gradients.

Both backward kernels take the *activation mask* inline (``mask="relu"``
recomputes ``dz = dy * (y > 0)`` from the saved forward output per block),
so ``dz`` is never written to HBM. Smooth activations (silu/gelu) pass a
pre-masked ``dz`` with ``mask="none"`` (see ``ops._linear_bwd``).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.fused_linear.ref import ACTS


class TilePlan(NamedTuple):
    """Clamped per-dimension block sizes + Pallas eligibility for one GEMM.

    The single source of truth for the block-clamping rule: each requested
    block is clamped to its dimension (a (100, 128) problem runs with a
    100-row block), and the shape is ``aligned`` — i.e. eligible for the
    Pallas kernels — iff every dimension divides evenly into its clamped
    block. Shared by the kernels (which assert it) and by the op-layer
    routing predicate in ``ops`` (which falls back to the jnp reference
    when it fails), so the two can never drift.
    """
    block_m: int
    block_k: int
    block_n: int
    aligned: bool


def tile_plan(m: int, k: int, n: int, block_m: int = 128,
              block_n: int = 128, block_k: int = 128) -> TilePlan:
    """Tiling plan for an (M, K) x (K, N) GEMM — forward or backward.

    The same (m, k, n) triple covers all three training contractions: the
    dx kernel tiles M/K as outputs and N as the reduction, the dw kernel
    tiles K/N as outputs and M as the reduction, so one predicate gates
    the whole custom-VJP path.
    """
    bm, bk, bn = min(block_m, m), min(block_k, k), min(block_n, n)
    return TilePlan(bm, bk, bn,
                    m % bm == 0 and k % bk == 0 and n % bn == 0)


def _masked_dz(dy_ref, y_ref, mask: str) -> jax.Array:
    """Recompute dz from the incoming cotangent block, in fp32.

    ``mask="relu"`` applies the activation derivative recovered from the
    saved forward *output* (``y > 0``) — the residual policy that lets the
    relu/none path keep no pre-activation buffer at all.
    """
    dz = dy_ref[...].astype(jnp.float32)
    if mask == "relu":
        dz = dz * (y_ref[...] > 0).astype(jnp.float32)
    return dz


def fused_linear(x: jax.Array, w: jax.Array, b: jax.Array,
                 *, activation: str = "relu", block_m: int = 128,
                 block_n: int = 128, block_k: int = 128,
                 interpret: bool = False) -> jax.Array:
    """x (M, K) @ w (K, N) + b (N,), activation fused. MXU-aligned tiles."""
    m, k = x.shape
    _, n = w.shape
    plan = tile_plan(m, k, n, block_m, block_n, block_k)
    assert plan.aligned, (m, k, n, plan)
    bm, bk, bn = plan.block_m, plan.block_k, plan.block_n

    def kernel(x_ref, w_ref, b_ref, o_ref, acc_scr):
        ki = pl.program_id(2)

        @pl.when(ki == 0)
        def _init():
            acc_scr[...] = jnp.zeros_like(acc_scr)

        acc_scr[...] += jax.lax.dot_general(
            x_ref[...].astype(jnp.float32), w_ref[...].astype(jnp.float32),
            (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

        @pl.when(ki == pl.num_programs(2) - 1)
        def _finalize():
            y = acc_scr[...] + b_ref[...].astype(jnp.float32)[None, :]
            o_ref[...] = ACTS[activation](y).astype(o_ref.dtype)

    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, k // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda mi, ni, ki: (mi, ki)),
            pl.BlockSpec((bk, bn), lambda mi, ni, ki: (ki, ni)),
            pl.BlockSpec((bn,), lambda mi, ni, ki: (ni,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda mi, ni, ki: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w, b)


def fused_linear_bwd_dx(dy: jax.Array, w: jax.Array, y: jax.Array | None = None,
                        *, mask: str = "none", block_m: int = 128,
                        block_n: int = 128, block_k: int = 128,
                        interpret: bool = False) -> jax.Array:
    """dx (M, K) = (dy ⊙ mask(y)) @ wᵀ with no materialized ``w.T``.

    Grid (M/bm, K/bk, N/bn), N innermost-sequential: ``w`` blocks are
    fetched at block index ``(ki, ni)`` — the transposed-operand trick —
    and ``dot_general`` contracts dz's and w's trailing N axes directly.
    """
    m, n = dy.shape
    k = w.shape[0]
    plan = tile_plan(m, k, n, block_m, block_n, block_k)
    assert plan.aligned, (m, k, n, plan)
    assert mask == "none" or y is not None
    bm, bk, bn = plan.block_m, plan.block_k, plan.block_n

    def kernel(*refs):
        dy_ref, y_ref = (refs[0], refs[1]) if mask != "none" else (refs[0], None)
        w_ref, o_ref, acc_scr = refs[-3:]
        ni = pl.program_id(2)

        @pl.when(ni == 0)
        def _init():
            acc_scr[...] = jnp.zeros_like(acc_scr)

        dz = _masked_dz(dy_ref, y_ref, mask)
        # dz (bm, bn) · w (bk, bn) contracted on N -> (bm, bk): w enters in
        # its stored layout; only its *block index* is transposed.
        acc_scr[...] += jax.lax.dot_general(
            dz, w_ref[...].astype(jnp.float32),
            (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)

        @pl.when(ni == pl.num_programs(2) - 1)
        def _finalize():
            o_ref[...] = acc_scr[...].astype(o_ref.dtype)

    in_specs = [pl.BlockSpec((bm, bn), lambda mi, ki, ni: (mi, ni))]
    operands = [dy]
    if mask != "none":
        in_specs.append(pl.BlockSpec((bm, bn), lambda mi, ki, ni: (mi, ni)))
        operands.append(y)
    in_specs.append(pl.BlockSpec((bk, bn), lambda mi, ki, ni: (ki, ni)))
    operands.append(w)

    return pl.pallas_call(
        kernel,
        grid=(m // bm, k // bk, n // bn),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bk), lambda mi, ki, ni: (mi, ki)),
        out_shape=jax.ShapeDtypeStruct((m, k), dy.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bk), jnp.float32)],
        interpret=interpret,
    )(*operands)


def fused_linear_bwd_dw_db(x: jax.Array, dy: jax.Array,
                           y: jax.Array | None = None, *, mask: str = "none",
                           block_m: int = 128, block_n: int = 128,
                           block_k: int = 128,
                           interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """(dw, db) = (xᵀ @ dz, Σ_m dz) in one pass, no materialized ``x.T``.

    Grid (N/bn, K/bk, M/bm), M innermost-sequential: ``x`` blocks are
    fetched at ``(mi, ki)`` and contracted with dz on their *leading* M
    axis. The db column reduction rides along in the ki == 0 sweep over M
    (each dz block is already in VMEM there), so dz is materialized for
    neither gradient. N is the outermost grid axis so the db output block
    stays resident across the whole (ki, mi) inner loop.
    """
    m, n = dy.shape
    k = x.shape[1]
    plan = tile_plan(m, k, n, block_m, block_n, block_k)
    assert plan.aligned, (m, k, n, plan)
    assert mask == "none" or y is not None
    bm, bk, bn = plan.block_m, plan.block_k, plan.block_n

    def kernel(*refs):
        x_ref = refs[0]
        dy_ref, y_ref = (refs[1], refs[2]) if mask != "none" else (refs[1], None)
        dw_ref, db_ref, acc_scr, db_scr = refs[-4:]
        ki, mi = pl.program_id(1), pl.program_id(2)
        nm = pl.num_programs(2)

        @pl.when(mi == 0)
        def _init():
            acc_scr[...] = jnp.zeros_like(acc_scr)

        @pl.when(jnp.logical_and(ki == 0, mi == 0))
        def _init_db():
            db_scr[...] = jnp.zeros_like(db_scr)

        dz = _masked_dz(dy_ref, y_ref, mask)
        # x (bm, bk) · dz (bm, bn) contracted on M -> (bk, bn)
        acc_scr[...] += jax.lax.dot_general(
            x_ref[...].astype(jnp.float32), dz,
            (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)

        @pl.when(ki == 0)
        def _db_accum():
            db_scr[...] += jnp.sum(dz, axis=0, keepdims=True)

        @pl.when(mi == nm - 1)
        def _finalize():
            dw_ref[...] = acc_scr[...].astype(dw_ref.dtype)

        @pl.when(jnp.logical_and(ki == 0, mi == nm - 1))
        def _finalize_db():
            db_ref[...] = db_scr[0].astype(db_ref.dtype)

    in_specs = [pl.BlockSpec((bm, bk), lambda ni, ki, mi: (mi, ki)),
                pl.BlockSpec((bm, bn), lambda ni, ki, mi: (mi, ni))]
    operands = [x, dy]
    if mask != "none":
        in_specs.append(pl.BlockSpec((bm, bn), lambda ni, ki, mi: (mi, ni)))
        operands.append(y)

    return pl.pallas_call(
        kernel,
        grid=(n // bn, k // bk, m // bm),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((bk, bn), lambda ni, ki, mi: (ki, ni)),
            pl.BlockSpec((bn,), lambda ni, ki, mi: (ni,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k, n), x.dtype),
            jax.ShapeDtypeStruct((n,), dy.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((bk, bn), jnp.float32),
                        pltpu.VMEM((1, bn), jnp.float32)],
        interpret=interpret,
    )(*operands)
