"""Fused matmul + bias + activation Pallas TPU kernel.

The per-layer unit of work of the paper's split training (each partitioned
fc/conv-as-GEMM layer is exactly one of these). Grid (M/bm, N/bn, K/bk) with
K innermost-sequential; partial products accumulate in a VMEM fp32 scratch;
bias + activation fuse into the final K step, saving one HBM round-trip of
the (M, N) output versus unfused matmul-then-activation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.fused_linear.ref import ACTS


def _kernel(x_ref, w_ref, b_ref, o_ref, acc_scr, *, activation: str):
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    acc_scr[...] += jax.lax.dot_general(
        x_ref[...].astype(jnp.float32), w_ref[...].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _finalize():
        y = acc_scr[...] + b_ref[...].astype(jnp.float32)[None, :]
        o_ref[...] = ACTS[activation](y).astype(o_ref.dtype)


def fused_linear(x: jax.Array, w: jax.Array, b: jax.Array,
                 *, activation: str = "relu", block_m: int = 128,
                 block_n: int = 128, block_k: int = 128,
                 interpret: bool = False) -> jax.Array:
    """x (M, K) @ w (K, N) + b (N,), activation fused. MXU-aligned tiles."""
    m, k = x.shape
    _, n = w.shape
    block_m = min(block_m, m)
    block_n = min(block_n, n)
    block_k = min(block_k, k)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0

    kern = functools.partial(_kernel, activation=activation)
    return pl.pallas_call(
        kern,
        grid=(m // block_m, n // block_n, k // block_k),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda mi, ni, ki: (mi, ki)),
            pl.BlockSpec((block_k, block_n), lambda mi, ni, ki: (ki, ni)),
            pl.BlockSpec((block_n,), lambda mi, ni, ki: (ni,)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda mi, ni, ki: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(x, w, b)
