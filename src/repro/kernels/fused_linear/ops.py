"""Differentiable public wrapper for the fused linear kernel.

``linear`` is the training-path entry point: a ``jax.custom_vjp`` around the
Pallas forward (TPU) or the pure-jnp reference (CPU/GPU/interpret), so the
fc layers of ``repro.models.vgg`` — and therefore the cohort split-training
engine — run the kernels directory on the hot path in both directions.

Backward strategy: for ``relu``/``none`` the activation mask is recovered
from the saved *output* (``y > 0``), so the residuals are just ``(x, w, y)``
and no pre-activation buffer is kept. For smooth activations (silu/gelu) the
pre-activation is rematerialized with one extra GEMM in the backward pass.
The three backward contractions (dz@w^T, x^T@dz, sum dz) reuse the fused
kernel (activation="none") whenever shapes are MXU-tile aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.fused_linear.kernel import fused_linear
from repro.kernels.fused_linear.ref import ACTS, fused_linear_ref

_BLOCKS = (128, 128, 128)


def _impl_default() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def _aligned(m: int, k: int, n: int, blocks=_BLOCKS) -> bool:
    bm, bn, bk = blocks
    return (m % min(bm, m) == 0 and n % min(bn, n) == 0
            and k % min(bk, k) == 0)


def _matmul_act(x, w, b, activation: str, impl: str):
    """One fused GEMM via the chosen implementation."""
    m, k = x.shape
    n = w.shape[1]
    if impl in ("pallas", "interpret") and _aligned(m, k, n):
        bm, bn, bk = _BLOCKS
        return fused_linear(x, w, b, activation=activation, block_m=bm,
                            block_n=bn, block_k=bk,
                            interpret=impl == "interpret")
    return fused_linear_ref(x, w, b, activation)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _linear_p(activation: str, impl: str, x, w, b):
    return _matmul_act(x, w, b, activation, impl)


def _linear_fwd(activation, impl, x, w, b):
    y = _matmul_act(x, w, b, activation, impl)
    if activation in ("relu", "none"):
        return y, (x, w, y, None)
    return y, (x, w, None, b)            # rematerialize z in bwd


def _linear_bwd(activation, impl, res, dy):
    x, w, y, b = res
    if activation == "none":
        dz = dy
    elif activation == "relu":
        dz = dy * (y > 0).astype(dy.dtype)
    else:
        z = _matmul_act(x, w, b, "none", impl)
        _, act_vjp = jax.vjp(ACTS[activation], z)
        (dz,) = act_vjp(dy)
    dx = _matmul_act(dz, w.T, jnp.zeros((w.shape[0],), dy.dtype), "none", impl)
    dw = _matmul_act(x.T, dz, jnp.zeros((w.shape[1],), dy.dtype), "none", impl)
    db = jnp.sum(dz.astype(jnp.float32), axis=0).astype(dy.dtype)
    return dx, dw, db


_linear_p.defvjp(_linear_fwd, _linear_bwd)


def linear(x, w, b, *, activation: str = "relu", impl: str | None = None):
    """Fused ``act(x @ w + b)`` with a custom VJP.

    ``impl``: "pallas" | "interpret" | "ref"; defaults to "pallas" on TPU and
    "ref" elsewhere.
    """
    if impl is None:
        impl = _impl_default()
    if impl == "ref":
        # plain jnp: autodiff differentiates it directly; the custom VJP is
        # only needed where autodiff can't see through pallas_call (and its
        # hand-written transposes cost ~40% extra on CPU hot loops).
        return fused_linear_ref(x, w, b, activation)
    return _linear_p(activation, impl, x, w, b)
