"""Jit'd public wrapper for the fused linear kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.fused_linear.kernel import fused_linear
from repro.kernels.fused_linear.ref import fused_linear_ref


@functools.partial(jax.jit, static_argnames=("activation", "block_m", "block_n",
                                             "block_k", "interpret", "use_pallas"))
def linear(x, w, b, *, activation: str = "relu", block_m: int = 128,
           block_n: int = 128, block_k: int = 128, interpret: bool = False,
           use_pallas: bool = True):
    if use_pallas:
        return fused_linear(x, w, b, activation=activation, block_m=block_m,
                            block_n=block_n, block_k=block_k, interpret=interpret)
    return fused_linear_ref(x, w, b, activation)
