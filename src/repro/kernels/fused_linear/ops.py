"""Differentiable public wrapper for the fused linear kernels.

``linear`` is the training-path entry point: a ``jax.custom_vjp`` whose
forward *and* backward both run the dedicated Pallas kernels (TPU /
interpret) or their ``dot_general`` references (CPU/GPU), so the fc layers
of ``repro.models.vgg`` — and therefore the cohort split-training engine —
run the kernels directory on the hot path in both directions.

Residual policy: for ``relu``/``none`` the activation mask is recovered
from the saved *output* (``y > 0``) inside the backward kernels, so the
residuals are just ``(x, w[, y])`` and no pre-activation buffer is kept.
For smooth activations (silu/gelu) the pre-activation is rematerialized
with one extra fused GEMM in the backward pass (remat rule: one GEMM is
cheaper than holding an (M, N) buffer across the whole cohort vmap).

Routing: every contraction of the step — forward, ``dz @ wᵀ`` and
``xᵀ @ dz`` — tiles the same (M, K, N) triple, so a single
``kernel.tile_plan`` verdict decides pallas-vs-ref for the whole VJP; the
backward kernels index their transposed operand through the BlockSpec map
and never materialize ``w.T``/``x.T`` (nor does the ref path — see
``ref.py``). ``REPRO_FUSED_LINEAR_IMPL`` overrides the default impl
(e.g. ``interpret`` on CPU CI so kernel bodies actually execute).

Block sizes come from the kernel-selection table
(``repro.kernels.autotune.blocks_for``): an autotuned exact match per
(shape, dtype, backend) when one exists, the clamped-128 heuristic
otherwise. The forward GEMM's (M, K, N) triple keys the lookup for all
three contractions, so the whole VJP tiles from one table entry.
"""
from __future__ import annotations

import functools
import os

import jax

from repro.kernels import autotune
from repro.kernels.fused_linear.kernel import (TilePlan, fused_linear,
                                               fused_linear_bwd_dw_db,
                                               fused_linear_bwd_dx, tile_plan)
from repro.kernels.fused_linear.ref import (ACTS, fused_linear_bwd_dw_db_ref,
                                            fused_linear_bwd_dx_ref,
                                            fused_linear_ref)

_IMPLS = ("pallas", "interpret", "ref")


def _impl_default() -> str:
    env = os.environ.get("REPRO_FUSED_LINEAR_IMPL", "")
    if env:
        if env not in _IMPLS:
            raise ValueError(f"REPRO_FUSED_LINEAR_IMPL={env!r}: "
                             f"expected one of {_IMPLS}")
        return env
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def _plan(m: int, k: int, n: int, dtype, impl: str) -> TilePlan:
    """Tile plan from the selection table (exact autotuned match or the
    clamped-128 heuristic), validated by ``tile_plan``'s alignment rule —
    the single source of block choices for the whole VJP."""
    bm, bk, bn = autotune.blocks_for("fused_linear", (m, k, n), str(dtype),
                                     interpret=impl == "interpret")
    return tile_plan(m, k, n, block_m=bm, block_n=bn, block_k=bk)


def _kern_kwargs(plan: TilePlan, impl: str) -> dict:
    return dict(block_m=plan.block_m, block_n=plan.block_n,
                block_k=plan.block_k, interpret=impl == "interpret")


def _matmul_act(x, w, b, activation: str, impl: str):
    """One fused forward GEMM via the chosen implementation."""
    m, k = x.shape
    n = w.shape[1]
    plan = _plan(m, k, n, x.dtype, impl)
    if impl != "ref" and plan.aligned:
        return fused_linear(x, w, b, activation=activation,
                            **_kern_kwargs(plan, impl))
    return fused_linear_ref(x, w, b, activation)


def _bwd_dx(dy, w, y, mask: str, impl: str):
    m, n = dy.shape
    plan = _plan(m, w.shape[0], n, dy.dtype, impl)
    if impl != "ref" and plan.aligned:
        return fused_linear_bwd_dx(dy, w, y, mask=mask,
                                   **_kern_kwargs(plan, impl))
    return fused_linear_bwd_dx_ref(dy, w, y, mask=mask)


def _bwd_dw_db(x, dy, y, mask: str, impl: str):
    m, n = dy.shape
    plan = _plan(m, x.shape[1], n, dy.dtype, impl)
    if impl != "ref" and plan.aligned:
        return fused_linear_bwd_dw_db(x, dy, y, mask=mask,
                                      **_kern_kwargs(plan, impl))
    return fused_linear_bwd_dw_db_ref(x, dy, y, mask=mask)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _linear_p(activation: str, impl: str, x, w, b):
    return _matmul_act(x, w, b, activation, impl)


def _linear_fwd(activation, impl, x, w, b):
    y = _matmul_act(x, w, b, activation, impl)
    if activation == "relu":
        return y, (x, w, y, None)      # mask recovered from y > 0 in bwd
    if activation == "none":
        return y, (x, w, None, None)   # identity: dz is dy, nothing extra
    return y, (x, w, None, b)          # smooth: rematerialize z in bwd


def _linear_bwd(activation, impl, res, dy):
    x, w, y, b = res
    if activation in ("relu", "none"):
        mask = activation
        dz = dy
    else:
        # remat rule: one extra fused GEMM rebuilds the pre-activation for
        # the smooth-activation derivative; dz is then plain (mask="none").
        z = _matmul_act(x, w, b, "none", impl)
        _, act_vjp = jax.vjp(ACTS[activation], z)
        (dz,) = act_vjp(dy)
        dz = dz.astype(dy.dtype)
        mask, y = "none", None
    dx = _bwd_dx(dz, w, y, mask, impl)
    dw, db = _bwd_dw_db(x, dz, y, mask, impl)
    return dx.astype(x.dtype), dw.astype(w.dtype), db.astype(dy.dtype)


_linear_p.defvjp(_linear_fwd, _linear_bwd)


def linear(x, w, b, *, activation: str = "relu", impl: str | None = None):
    """Fused ``act(x @ w + b)`` with a custom VJP in every implementation.

    ``impl``: "pallas" | "interpret" | "ref"; defaults to "pallas" on TPU
    and "ref" elsewhere (``REPRO_FUSED_LINEAR_IMPL`` overrides). The "ref"
    impl also goes through the hand-written VJP: its contractions carry the
    transposition in ``dot_general`` dimension numbers, so it matches
    autodiff cost while keeping one code path for all backends.
    """
    if impl is None:
        impl = _impl_default()
    return _linear_p(activation, impl, x, w, b)
