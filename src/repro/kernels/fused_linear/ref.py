"""Pure-jnp oracles for the fused linear kernels (forward and backward).

The backward references mirror the kernels' contraction structure —
``dot_general`` with transposed *dimension numbers*, never a materialized
``w.T``/``x.T`` — so they are both the numerics oracle for the Pallas
kernels and the fast CPU fallback the op layer routes off-tile shapes to.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

ACTS = {
    "none": lambda x: x,
    "relu": jax.nn.relu,
    "silu": jax.nn.silu,
    "gelu": jax.nn.gelu,
}


def fused_linear_ref(x: jax.Array, w: jax.Array, b: jax.Array,
                     activation: str = "relu") -> jax.Array:
    y = jnp.dot(x, w, preferred_element_type=jnp.float32) + b.astype(jnp.float32)
    return ACTS[activation](y).astype(x.dtype)


def _masked_dz(dy: jax.Array, y: jax.Array | None, mask: str) -> jax.Array:
    """fp32 dz with the activation derivative applied from the saved output
    (``mask="relu"``: dz = dy * (y > 0)); ``mask="none"`` passes dy through."""
    dz = dy.astype(jnp.float32)
    if mask == "relu":
        dz = dz * (y > 0).astype(jnp.float32)
    return dz


def fused_linear_bwd_dx_ref(dy: jax.Array, w: jax.Array,
                            y: jax.Array | None = None,
                            mask: str = "none") -> jax.Array:
    """dx (M, K) = (dy ⊙ mask(y)) @ wᵀ, as a trailing-axes contraction."""
    dz = _masked_dz(dy, y, mask)
    return jax.lax.dot_general(
        dz, w.astype(jnp.float32), (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32).astype(dy.dtype)


def fused_linear_bwd_dw_db_ref(x: jax.Array, dy: jax.Array,
                               y: jax.Array | None = None,
                               mask: str = "none"):
    """(dw, db) = (xᵀ @ dz, Σ_m dz), as a leading-axes contraction."""
    dz = _masked_dz(dy, y, mask)
    dw = jax.lax.dot_general(
        x.astype(jnp.float32), dz, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(x.dtype)
    return dw, jnp.sum(dz, axis=0).astype(dy.dtype)
