"""Pure-jnp oracle for the fused linear kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

ACTS = {
    "none": lambda x: x,
    "relu": jax.nn.relu,
    "silu": jax.nn.silu,
    "gelu": jax.nn.gelu,
}


def fused_linear_ref(x: jax.Array, w: jax.Array, b: jax.Array,
                     activation: str = "relu") -> jax.Array:
    y = jnp.dot(x, w, preferred_element_type=jnp.float32) + b.astype(jnp.float32)
    return ACTS[activation](y).astype(x.dtype)
