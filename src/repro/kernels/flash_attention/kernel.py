"""Flash attention Pallas TPU kernel (online softmax, VMEM-tiled).

Grid: (batch*heads, num_q_blocks, num_k_blocks); the k axis is innermost and
sequential on TPU, so the running max / denominator / accumulator live in
VMEM scratch across k steps (the canonical flash recurrence). Block shapes
are MXU-aligned (multiples of 128 on the lane dim; block_q/block_k sublane).

Causal + sliding-window masking is applied inside the block; fully-masked
blocks still execute (grid is static) but contribute nothing — ``ops.py``
documents the cost model.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr,
                  *, scale: float, block_q: int, block_k: int,
                  causal: bool, window: Optional[int], seq_len: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                 # (bq, d)
    k = k_ref[0].astype(jnp.float32)                 # (bk, d)
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = kpos < seq_len
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                              # (bq, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                           # (bq, bk)
    l_new = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    *, causal: bool = True, window: Optional[int] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q, k, v: (B, H, S, D) with equal head counts -> (B, H, S, D)."""
    b, h, s, d = q.shape
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    bh = b * h
    qf = q.reshape(bh, s, d)
    kf = k.reshape(bh, s, d)
    vf = v.reshape(bh, s, d)
    grid = (bh, s // block_q, s // block_k)

    kern = functools.partial(
        _flash_kernel, scale=d ** -0.5, block_q=block_q, block_k=block_k,
        causal=causal, window=window, seq_len=s)

    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh_, qi, ki: (bh_, qi, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh_, qi, ki: (bh_, ki, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh_, qi, ki: (bh_, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh_, qi, ki: (bh_, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, s, d)
