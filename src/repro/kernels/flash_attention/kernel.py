"""Flash attention Pallas TPU kernels (online softmax, VMEM-tiled): forward
plus the dedicated backward pair.

Forward grid: (batch*heads, num_q_blocks, num_k_blocks); the k axis is
innermost and sequential on TPU, so the running max / denominator /
accumulator live in VMEM scratch across k steps (the canonical flash
recurrence). Block shapes are MXU-aligned (multiples of 128 on the lane dim;
block_q/block_k sublane). The forward also emits the per-row log-sum-exp so
the backward kernels can rebuild the probabilities without a second online
pass.

Backward follows the standard two-kernel split (dq separately from dk/dv) so
each kernel accumulates over exactly one sequential grid axis:

* ``dq``:   grid (bh, nq, nk), k innermost — dq_scr accumulates over k blocks;
* ``dkdv``: grid (bh, nk, nq), q innermost — dk/dv scratch accumulate over q.

Both rebuild ``p = exp(s - lse)`` from the saved lse, and carry every operand
transposition in ``dot_general`` dimension numbers (``dvᵀ = pᵀ @ do`` and
``dk = dsᵀ @ q`` contract the shared *leading* axis) — the same
transposed-operand recipe as the fused_linear backward kernels: no
materialized transposes anywhere in the training jaxpr.

Causal + sliding-window masking is applied inside the block; fully-masked
blocks still execute (grid is static) but contribute nothing — ``ops.py``
documents the cost model.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _block_mask(qi, ki, *, block_q: int, block_k: int,
                causal: bool, window: Optional[int], seq_len: int):
    qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = kpos < seq_len
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    return mask


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref,
                  m_scr, l_scr, acc_scr,
                  *, scale: float, block_q: int, block_k: int,
                  causal: bool, window: Optional[int], seq_len: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                 # (bq, d)
    k = k_ref[0].astype(jnp.float32)                 # (bk, d)
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    mask = _block_mask(qi, ki, block_q=block_q, block_k=block_k,
                       causal=causal, window=window, seq_len=seq_len)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                              # (bq, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                           # (bq, bk)
    l_new = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)
        lse_ref[0] = (m_scr[...] + jnp.log(denom))[:, 0]


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    *, causal: bool = True, window: Optional[int] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False, return_lse: bool = False):
    """q, k, v: (B, H, S, D) with equal head counts -> (B, H, S, D).

    With ``return_lse=True`` also returns the per-row log-sum-exp
    ``lse = m + log(l)`` of shape (B, H, S) — the residual the backward
    kernels need to rebuild the softmax without a second online pass.
    """
    b, h, s, d = q.shape
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    bh = b * h
    qf = q.reshape(bh, s, d)
    kf = k.reshape(bh, s, d)
    vf = v.reshape(bh, s, d)
    grid = (bh, s // block_q, s // block_k)

    kern = functools.partial(
        _flash_kernel, scale=d ** -0.5, block_q=block_q, block_k=block_k,
        causal=causal, window=window, seq_len=s)

    out, lse = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh_, qi, ki: (bh_, qi, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh_, qi, ki: (bh_, ki, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh_, qi, ki: (bh_, ki, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh_, qi, ki: (bh_, qi, 0)),
            pl.BlockSpec((1, block_q), lambda bh_, qi, ki: (bh_, qi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, d), q.dtype),
            jax.ShapeDtypeStruct((bh, s), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    out = out.reshape(b, h, s, d)
    if return_lse:
        return out, lse.reshape(b, h, s)
    return out


def _flash_bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                         dq_ref, dq_scr,
                         *, scale: float, block_q: int, block_k: int,
                         causal: bool, window: Optional[int], seq_len: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    q = q_ref[0].astype(jnp.float32)                 # (bq, d)
    k = k_ref[0].astype(jnp.float32)                 # (bk, d)
    v = v_ref[0].astype(jnp.float32)
    do = do_ref[0].astype(jnp.float32)               # (bq, d)
    lse = lse_ref[0]                                 # (bq,)
    delta = delta_ref[0]                             # (bq,)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    mask = _block_mask(qi, ki, block_q=block_q, block_k=block_k,
                       causal=causal, window=window, seq_len=seq_len)
    p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - delta[:, None])
    dq_scr[...] += jax.lax.dot_general(
        ds, k, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32) * scale

    @pl.when(ki == nk - 1)
    def _finalize():
        dq_ref[0] = dq_scr[...].astype(dq_ref.dtype)


def _flash_bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                          dk_ref, dv_ref, dk_scr, dv_scr,
                          *, scale: float, block_q: int, block_k: int,
                          causal: bool, window: Optional[int], seq_len: int):
    ki = pl.program_id(1)
    qi = pl.program_id(2)
    nq = pl.num_programs(2)

    @pl.when(qi == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    q = q_ref[0].astype(jnp.float32)                 # (bq, d)
    k = k_ref[0].astype(jnp.float32)                 # (bk, d)
    v = v_ref[0].astype(jnp.float32)
    do = do_ref[0].astype(jnp.float32)               # (bq, d)
    lse = lse_ref[0]                                 # (bq,)
    delta = delta_ref[0]                             # (bq,)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    mask = _block_mask(qi, ki, block_q=block_q, block_k=block_k,
                       causal=causal, window=window, seq_len=seq_len)
    p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)  # (bq, bk)
    # dv = pᵀ @ do: contract the shared q axis (axis 0 of both operands).
    dv_scr[...] += jax.lax.dot_general(
        p, do, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - delta[:, None])
    # dk = dsᵀ @ q: again contract axis 0 — no transposes materialized.
    dk_scr[...] += jax.lax.dot_general(
        ds, q, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32) * scale

    @pl.when(qi == nq - 1)
    def _finalize():
        dk_ref[0] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_scr[...].astype(dv_ref.dtype)


def flash_attention_bwd(q: jax.Array, k: jax.Array, v: jax.Array,
                        do: jax.Array, lse: jax.Array, delta: jax.Array,
                        *, causal: bool = True, window: Optional[int] = None,
                        block_q: int = 128, block_k: int = 128,
                        interpret: bool = False):
    """Backward pass on (B, H, S, D) operands -> (dq, dk, dv).

    ``lse`` is the forward's (B, H, S) log-sum-exp; ``delta`` is the
    precomputed row dot ``sum(do * o, -1)`` of the same shape. Runs the dq
    kernel (k innermost) and the dk/dv kernel (q innermost) back to back.
    """
    b, h, s, d = q.shape
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    bh = b * h
    flat = lambda a: a.reshape(bh, s, d)
    qf, kf, vf, dof = flat(q), flat(k), flat(v), flat(do)
    lsef = lse.reshape(bh, s).astype(jnp.float32)
    deltaf = delta.reshape(bh, s).astype(jnp.float32)

    q_spec = pl.BlockSpec((1, block_q, d), lambda bh_, i, j: (bh_, i, 0))
    row_spec = pl.BlockSpec((1, block_q), lambda bh_, i, j: (bh_, i))

    dq = pl.pallas_call(
        functools.partial(
            _flash_bwd_dq_kernel, scale=d ** -0.5, block_q=block_q,
            block_k=block_k, causal=causal, window=window, seq_len=s),
        grid=(bh, s // block_q, s // block_k),
        in_specs=[
            q_spec,
            pl.BlockSpec((1, block_k, d), lambda bh_, qi, ki: (bh_, ki, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh_, qi, ki: (bh_, ki, 0)),
            q_spec,
            row_spec,
            row_spec,
        ],
        out_specs=q_spec,
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        scratch_shapes=[pltpu.VMEM((block_q, d), jnp.float32)],
        interpret=interpret,
    )(qf, kf, vf, dof, lsef, deltaf)

    k_spec = pl.BlockSpec((1, block_k, d), lambda bh_, ki, qi: (bh_, ki, 0))
    qq_spec = pl.BlockSpec((1, block_q, d), lambda bh_, ki, qi: (bh_, qi, 0))
    qrow_spec = pl.BlockSpec((1, block_q), lambda bh_, ki, qi: (bh_, qi))
    dk, dv = pl.pallas_call(
        functools.partial(
            _flash_bwd_dkv_kernel, scale=d ** -0.5, block_q=block_q,
            block_k=block_k, causal=causal, window=window, seq_len=s),
        grid=(bh, s // block_k, s // block_q),
        in_specs=[qq_spec, k_spec, k_spec, qq_spec, qrow_spec, qrow_spec],
        out_specs=[k_spec, k_spec],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, d), k.dtype),
            jax.ShapeDtypeStruct((bh, s, d), v.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_k, d), jnp.float32),
            pltpu.VMEM((block_k, d), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf, dof, lsef, deltaf)

    unflat = lambda a: a.reshape(b, h, s, d)
    return unflat(dq), unflat(dk), unflat(dv)
