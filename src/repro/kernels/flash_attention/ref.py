"""Pure-jnp oracle for the flash-attention kernel (forward + backward)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def _mask(s: int, causal: bool, window: Optional[int]) -> jax.Array:
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    return mask


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                  *, causal: bool = True,
                  window: Optional[int] = None) -> jax.Array:
    """q, k, v: (B, H, S, D) -> (B, H, S, D). fp32 softmax."""
    s = q.shape[2]
    scale = q.shape[-1] ** -0.5
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    scores = jnp.where(_mask(s, causal, window)[None, None], scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v)


def attention_ref_lse(q: jax.Array, k: jax.Array, v: jax.Array,
                      *, causal: bool = True,
                      window: Optional[int] = None):
    """Like :func:`attention_ref` but also returns the (B, H, S) lse."""
    s = q.shape[2]
    scale = q.shape[-1] ** -0.5
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    scores = jnp.where(_mask(s, causal, window)[None, None], scores, -jnp.inf)
    lse = jax.nn.logsumexp(scores, axis=-1)
    p = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v)
    return o, lse.astype(jnp.float32)


def attention_ref_bwd(q: jax.Array, k: jax.Array, v: jax.Array,
                      do: jax.Array, lse: jax.Array, delta: jax.Array,
                      *, causal: bool = True,
                      window: Optional[int] = None):
    """Closed-form (dq, dk, dv) from the saved lse — the jnp twin of the
    Pallas backward kernels (same math, einsum instead of tiles)."""
    s = q.shape[2]
    scale = q.shape[-1] ** -0.5
    q32, k32, v32 = (a.astype(jnp.float32) for a in (q, k, v))
    do32 = do.astype(jnp.float32)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q32, k32) * scale
    mask = _mask(s, causal, window)[None, None]
    p = jnp.where(mask, jnp.exp(scores - lse[..., None]), 0.0)
    dv = jnp.einsum("bhqk,bhqd->bhkd", p, do32)
    dp = jnp.einsum("bhqd,bhkd->bhqk", do32, v32)
    ds = p * (dp - delta[..., None])
    dq = jnp.einsum("bhqk,bhkd->bhqd", ds, k32) * scale
    dk = jnp.einsum("bhqk,bhqd->bhkd", ds, q32) * scale
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)
