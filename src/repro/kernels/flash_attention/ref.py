"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                  *, causal: bool = True,
                  window: Optional[int] = None) -> jax.Array:
    """q, k, v: (B, H, S, D) -> (B, H, S, D). fp32 softmax."""
    s = q.shape[2]
    scale = q.shape[-1] ** -0.5
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None, None], scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v)
