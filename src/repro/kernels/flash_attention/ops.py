"""Differentiable public wrappers for the flash-attention kernel.

Mirrors the fused_linear op layer: one ``impl`` switch selects

* ``"pallas"``    — compiled Pallas kernels (forward + the dq / dkdv
  backward pair from ``kernel.py``),
* ``"interpret"`` — the same kernels under ``interpret=True`` (CI path),
* ``"ref"``       — the pure-jnp oracle (``ref.py``), same closed form.

All three run through a single ``jax.custom_vjp`` named ``flash_attention``
(the name the training jaxpr pins on), saving ``(q, k, v, o, lse)`` as
residuals; the backward rebuilds the softmax from the log-sum-exp and
computes ``delta = sum(do * o)`` outside the kernels.

The default impl comes from ``REPRO_FLASH_ATTENTION_IMPL`` when set
(``pallas`` / ``interpret`` / ``ref``), else ``pallas`` on TPU and ``ref``
elsewhere — the same contract as ``REPRO_FUSED_LINEAR_IMPL``.
"""
from __future__ import annotations

import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import autotune
from repro.kernels.flash_attention import kernel as _kernel
from repro.kernels.flash_attention.kernel import flash_attention as _flash_fwd_kernel
from repro.kernels.flash_attention.ref import (attention_ref,
                                               attention_ref_bwd,
                                               attention_ref_lse)

_IMPLS = ("pallas", "interpret", "ref")
_ENV_VAR = "REPRO_FLASH_ATTENTION_IMPL"


def default_impl() -> str:
    """Resolve the attention impl: env override, else backend heuristic."""
    env = os.environ.get(_ENV_VAR)
    if env is not None:
        if env not in _IMPLS:
            raise ValueError(
                f"{_ENV_VAR}={env!r} invalid; expected one of {_IMPLS}")
        return env
    return "pallas" if jax.default_backend() == "tpu" else "ref"


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3, 4))
def flash_attention(causal, window, block_q, block_k, impl, q, k, v):
    out, _ = _flash_fwd(causal, window, block_q, block_k, impl, q, k, v)
    return out


def _flash_fwd(causal, window, block_q, block_k, impl, q, k, v):
    if impl == "ref":
        o, lse = attention_ref_lse(q, k, v, causal=causal, window=window)
    else:
        o, lse = _flash_fwd_kernel(
            q, k, v, causal=causal, window=window,
            block_q=block_q, block_k=block_k,
            interpret=(impl == "interpret"), return_lse=True)
    return o, (q, k, v, o, lse)


def _flash_bwd(causal, window, block_q, block_k, impl, res, do):
    q, k, v, o, lse = res
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)
    if impl == "ref":
        return attention_ref_bwd(q, k, v, do, lse, delta,
                                 causal=causal, window=window)
    return _kernel.flash_attention_bwd(
        q, k, v, do, lse, delta, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=(impl == "interpret"))


flash_attention.defvjp(_flash_fwd, _flash_bwd)


def attention(q: jax.Array, k: jax.Array, v: jax.Array,
              *, causal: bool = True, window: Optional[int] = None,
              block_q: Optional[int] = None, block_k: Optional[int] = None,
              impl: Optional[str] = None) -> jax.Array:
    """Differentiable attention on kernel-layout (B, H, S, D) operands."""
    impl = default_impl() if impl is None else impl
    if impl not in _IMPLS:
        raise ValueError(f"impl={impl!r}; expected one of {_IMPLS}")
    if block_q is None or block_k is None:
        b, h, s, hd = q.shape
        tq, tk = autotune.blocks_for("flash_attention", (b, h, s, hd),
                                     str(q.dtype),
                                     interpret=(impl != "pallas"))
        block_q = tq if block_q is None else block_q
        block_k = tk if block_k is None else block_k
    return flash_attention(causal, window, block_q, block_k, impl, q, k, v)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret",
                                             "use_pallas", "impl"))
def gqa_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                  *, causal: bool = True, window: Optional[int] = None,
                  block_q: Optional[int] = None,
                  block_k: Optional[int] = None,
                  interpret: bool = False, use_pallas: bool = True,
                  impl: Optional[str] = None) -> jax.Array:
    """Layout adapter: q (B,S,H,hd), k/v (B,S,KV,hd) -> (B,S,H,hd).

    Repeats KV heads to match the query heads (grouped-query attention),
    transposes to the kernel's (B,H,S,D) layout and dispatches through the
    differentiable :func:`attention` entry (so gradients flow through the
    Pallas backward kernels; the KV-head repeat autodiffs to group-summed
    dk/dv). ``impl`` overrides the legacy ``use_pallas``/``interpret``
    flags when given; block sizes default to the kernel-selection table
    (``repro.kernels.autotune.blocks_for``; clamped-128 heuristic on a
    table miss) — pass ``block_q``/``block_k`` explicitly to override.
    """
    if impl is None:
        impl = ("interpret" if interpret else "pallas") if use_pallas else "ref"
    b, s, h, hd = q.shape
    kvh = k.shape[2]
    rep = h // kvh
    qt = q.transpose(0, 2, 1, 3)
    kt = jnp.repeat(k.transpose(0, 2, 1, 3), rep, axis=1)
    vt = jnp.repeat(v.transpose(0, 2, 1, 3), rep, axis=1)
    out = attention(qt, kt, vt, causal=causal, window=window,
                    block_q=block_q, block_k=block_k, impl=impl)
    return out.transpose(0, 2, 1, 3)
