"""Jit'd public wrapper for the flash-attention kernel (GQA-aware)."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import autotune
from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret", "use_pallas"))
def gqa_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                  *, causal: bool = True, window: Optional[int] = None,
                  block_q: Optional[int] = None,
                  block_k: Optional[int] = None,
                  interpret: bool = False, use_pallas: bool = True) -> jax.Array:
    """Layout adapter: q (B,S,H,hd), k/v (B,S,KV,hd) -> (B,S,H,hd).

    Repeats KV heads to match the query heads (grouped-query attention),
    transposes to the kernel's (B,H,S,D) layout and dispatches to the Pallas
    kernel (or the jnp oracle when ``use_pallas=False``). Block sizes
    default to the kernel-selection table
    (``repro.kernels.autotune.blocks_for`` on the (B,H,S,D) kernel-layout
    shape; clamped-128 heuristic on a table miss) — pass ``block_q``/
    ``block_k`` explicitly to override.
    """
    b, s, h, hd = q.shape
    kvh = k.shape[2]
    rep = h // kvh
    qt = q.transpose(0, 2, 1, 3)
    kt = jnp.repeat(k.transpose(0, 2, 1, 3), rep, axis=1)
    vt = jnp.repeat(v.transpose(0, 2, 1, 3), rep, axis=1)
    fn = flash_attention if use_pallas else attention_ref
    kw = dict(causal=causal, window=window)
    if use_pallas:
        if block_q is None or block_k is None:
            tq, tk = autotune.blocks_for("flash_attention", (b, h, s, hd),
                                         str(q.dtype), interpret=interpret)
            block_q = tq if block_q is None else block_q
            block_k = tk if block_k is None else block_k
        kw.update(block_q=block_q, block_k=block_k, interpret=interpret)
    out = fn(qt, kt, vt, **kw)
    return out.transpose(0, 2, 1, 3)
