"""Mamba-2 SSD chunked scan as a Pallas TPU kernel.

Grid: (batch, head_blocks, num_chunks) — chunks innermost and sequential on
TPU, so the inter-chunk SSM state lives in VMEM scratch across chunk steps
(same carry pattern as the flash-attention accumulators). Within a chunk the
dual quadratic form runs on the MXU; the state update is a rank-Q
outer-product accumulation.

VMEM working set per step: O(Q^2 * block_h + block_h * ds * p) — chosen so
Q=chunk=128..256, block_h<=8 fits comfortably in 16 MB VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, alog_ref, b_ref, c_ref, y_ref, h_scr,
                *, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0].astype(jnp.float32)          # (Q, bh, p)
    dt = dt_ref[0].astype(jnp.float32)        # (Q, bh)
    a = -jnp.exp(alog_ref[...].astype(jnp.float32))   # (bh,)
    b = b_ref[0].astype(jnp.float32)          # (Q, ds)
    c = c_ref[0].astype(jnp.float32)          # (Q, ds)

    adt = dt * a[None, :]                     # (Q, bh) log-decays
    cum = jnp.cumsum(adt, axis=0)             # inclusive

    # --- intra-chunk dual form ------------------------------------------
    scores = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (Q, K)
    qpos = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    kpos = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    causal = qpos >= kpos
    ldec = jnp.exp(cum[:, None, :] - cum[None, :, :])  # (Q, K, bh)
    w = scores[:, :, None] * jnp.where(causal[:, :, None], ldec, 0.0)
    w = w * dt[None, :, :]                    # * dt_k
    # y_intra[q,h,p] = sum_k w[q,k,h] x[k,h,p]  (batched over h)
    y_intra = jax.lax.dot_general(
        w.transpose(2, 0, 1), x.transpose(1, 0, 2),
        (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32).transpose(1, 0, 2)

    # --- inter-chunk contribution from carried state ---------------------
    h = h_scr[...]                            # (bh, ds, p)
    # y_inter[q,h,p] = exp(cum[q,h]) * sum_s c[q,s] h[h,s,p]
    ch = jax.lax.dot_general(
        jnp.broadcast_to(c[None], (h.shape[0],) + c.shape), h,
        (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)   # (bh, Q, p)
    y_inter = ch.transpose(1, 0, 2) * jnp.exp(cum)[:, :, None]

    y_ref[0] = (y_intra + y_inter).astype(y_ref.dtype)

    # --- state update -----------------------------------------------------
    wk = jnp.exp(cum[-1:, :] - cum) * dt      # (Q, bh)
    # S[h,s,p] = sum_k b[k,s] wk[k,h] x[k,h,p]
    xw = x * wk[:, :, None]                   # (Q, bh, p)
    s_new = jax.lax.dot_general(
        jnp.broadcast_to(b.T[None], (x.shape[1],) + (b.shape[1], b.shape[0])),
        xw.transpose(1, 0, 2),
        (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)   # (bh, ds, p)
    h_scr[...] = h * jnp.exp(cum[-1])[:, None, None] + s_new


def ssd_scan(xh: jax.Array, dt: jax.Array, a_log: jax.Array,
             b_ssm: jax.Array, c_ssm: jax.Array,
             *, chunk: int = 128, block_h: int = 8,
             interpret: bool = False) -> jax.Array:
    """xh (B,S,n,p); dt (B,S,n); a_log (n,); b/c (B,S,ds) -> (B,S,n,p)."""
    bsz, s, n, p = xh.shape
    ds = b_ssm.shape[-1]
    chunk = min(chunk, s)
    block_h = min(block_h, n)
    assert s % chunk == 0 and n % block_h == 0, (s, chunk, n, block_h)
    grid = (bsz, n // block_h, s // chunk)

    kern = functools.partial(_ssd_kernel, chunk=chunk)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, block_h, p), lambda b_, h_, c_: (b_, c_, h_, 0)),
            pl.BlockSpec((1, chunk, block_h), lambda b_, h_, c_: (b_, c_, h_)),
            pl.BlockSpec((block_h,), lambda b_, h_, c_: (h_,)),
            pl.BlockSpec((1, chunk, ds), lambda b_, h_, c_: (b_, c_, 0)),
            pl.BlockSpec((1, chunk, ds), lambda b_, h_, c_: (b_, c_, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, block_h, p),
                               lambda b_, h_, c_: (b_, c_, h_, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, s, n, p), xh.dtype),
        scratch_shapes=[pltpu.VMEM((block_h, ds, p), jnp.float32)],
        interpret=interpret,
    )(xh, dt, a_log, b_ssm, c_ssm)
