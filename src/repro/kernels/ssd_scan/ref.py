"""Pure-jnp oracle for the SSD chunked-scan kernel: the sequential
(non-chunked) SSM recurrence, numerically exact."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(xh: jax.Array, dt: jax.Array, a_log: jax.Array,
            b_ssm: jax.Array, c_ssm: jax.Array) -> jax.Array:
    """Sequential scan. xh (B,S,n,p); dt (B,S,n); a_log (n,);
    b_ssm/c_ssm (B,S,ds) -> y (B,S,n,p)."""
    bsz, s, n, p = xh.shape
    ds = b_ssm.shape[-1]
    a = -jnp.exp(a_log.astype(jnp.float32))

    def step(h, xs):
        x_t, dt_t, b_t, c_t = xs
        dec = jnp.exp(dt_t * a)                          # (B,n)
        upd = dt_t[..., None, None] * b_t[:, None, :, None] * x_t[:, :, None, :].astype(jnp.float32)
        h = h * dec[..., None, None] + upd               # (B,n,ds,p)
        y = jnp.einsum("bnsp,bs->bnp", h, c_t.astype(jnp.float32))
        return h, y

    h0 = jnp.zeros((bsz, n, ds, p), jnp.float32)
    xs = (xh.transpose(1, 0, 2, 3), dt.astype(jnp.float32).transpose(1, 0, 2),
          b_ssm.transpose(1, 0, 2), c_ssm.transpose(1, 0, 2))
    _, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2, 3).astype(xh.dtype)
