"""Jit'd public wrapper for the SSD scan kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.ssd_scan.kernel import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_ref


@functools.partial(jax.jit, static_argnames=("chunk", "block_h", "interpret",
                                             "use_pallas"))
def ssd(xh, dt, a_log, b_ssm, c_ssm, *, chunk: int = 128, block_h: int = 8,
        interpret: bool = False, use_pallas: bool = True):
    if use_pallas:
        return ssd_scan(xh, dt, a_log, b_ssm, c_ssm, chunk=chunk,
                        block_h=block_h, interpret=interpret)
    return ssd_ref(xh, dt, a_log, b_ssm, c_ssm)
