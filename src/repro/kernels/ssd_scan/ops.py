"""Differentiable public wrappers for the SSD chunked-scan kernel.

Mirrors the flash-attention op layer: one ``impl`` switch selects

* ``"pallas"``    — the compiled Pallas forward kernel (``kernel.py``),
* ``"interpret"`` — the same kernel under ``interpret=True`` (CI path),
* ``"ref"``       — the pure-jnp sequential oracle (``ref.py``).

All three run through a single ``jax.custom_vjp`` named ``ssd_scan_vjp``
(the name the training jaxpr pins on). There is no hand-written backward
kernel: the VJP saves the five inputs as residuals and backpropagates by
recomputing through :func:`~repro.kernels.ssd_scan.ref.ssd_ref` — the
sequential recurrence is the numerically exact adjoint of every impl, and
its ``lax.scan`` reverse pass keeps memory at O(S) states. That makes the
Pallas forward usable inside ``jax.grad`` (split training), which the bare
``pallas_call`` is not.

The default impl comes from ``REPRO_SSD_SCAN_IMPL`` when set
(``pallas`` / ``interpret`` / ``ref``), else ``pallas`` on TPU and ``ref``
elsewhere — the same contract as ``REPRO_FLASH_ATTENTION_IMPL``.
"""
from __future__ import annotations

import functools
import os
from typing import Optional

import jax

from repro.kernels import autotune
from repro.kernels.ssd_scan.kernel import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_ref

_IMPLS = ("pallas", "interpret", "ref")
_ENV_VAR = "REPRO_SSD_SCAN_IMPL"


def default_impl() -> str:
    """Resolve the SSD impl: env override, else backend heuristic."""
    env = os.environ.get(_ENV_VAR)
    if env is not None:
        if env not in _IMPLS:
            raise ValueError(
                f"{_ENV_VAR}={env!r} invalid; expected one of {_IMPLS}")
        return env
    return "pallas" if jax.default_backend() == "tpu" else "ref"


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def ssd_scan_vjp(chunk, block_h, impl, xh, dt, a_log, b_ssm, c_ssm):
    out, _ = _ssd_fwd(chunk, block_h, impl, xh, dt, a_log, b_ssm, c_ssm)
    return out


def _ssd_fwd(chunk, block_h, impl, xh, dt, a_log, b_ssm, c_ssm):
    if impl == "ref":
        y = ssd_ref(xh, dt, a_log, b_ssm, c_ssm)
    else:
        y = ssd_scan(xh, dt, a_log, b_ssm, c_ssm, chunk=chunk,
                     block_h=block_h, interpret=(impl == "interpret"))
    return y, (xh, dt, a_log, b_ssm, c_ssm)


def _ssd_bwd(chunk, block_h, impl, res, dy):
    # one backward for every impl: recompute through the sequential oracle
    # (exact — the kernels are validated against it bit-for-bit in f32)
    _, vjp = jax.vjp(ssd_ref, *res)
    return vjp(dy)


ssd_scan_vjp.defvjp(_ssd_fwd, _ssd_bwd)


@functools.partial(jax.jit, static_argnames=("chunk", "block_h", "interpret",
                                             "use_pallas", "impl"))
def ssd(xh, dt, a_log, b_ssm, c_ssm, *, chunk: Optional[int] = None,
        block_h: Optional[int] = None, interpret: bool = False,
        use_pallas: bool = True, impl: Optional[str] = None):
    """Differentiable chunked SSD scan.

    ``impl`` overrides the legacy ``use_pallas``/``interpret`` flags when
    given; ``chunk``/``block_h`` default to the kernel-selection table
    (``repro.kernels.autotune.blocks_for`` on the (B, S, n, p, ds) shape;
    clamped heuristic on a miss) — pass them explicitly to override. Every
    impl dispatches through the ``ssd_scan_vjp`` custom VJP, so the Pallas
    forward participates in ``jax.grad`` (the routing pin in
    ``tests/test_split_models.py`` walks the jaxpr for it).
    """
    if impl is None:
        impl = ("interpret" if interpret else "pallas") if use_pallas \
            else "ref"
    if impl not in _IMPLS:
        raise ValueError(f"impl={impl!r}; expected one of {_IMPLS}")
    if chunk is None or block_h is None:
        bsz, s, n, p = xh.shape
        tc, th = autotune.blocks_for("ssd_scan", (bsz, s, n, p,
                                                  b_ssm.shape[-1]),
                                     str(xh.dtype),
                                     interpret=(impl != "pallas"))
        chunk = tc if chunk is None else chunk
        block_h = th if block_h is None else block_h
    bsz, s, n, p = xh.shape
    chunk = min(chunk, s)
    block_h = min(block_h, n)
    return ssd_scan_vjp(chunk, block_h, impl, xh, dt, a_log, b_ssm, c_ssm)
