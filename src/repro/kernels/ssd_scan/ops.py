"""Jit'd public wrapper for the SSD scan kernel."""
from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels import autotune
from repro.kernels.ssd_scan.kernel import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_ref


@functools.partial(jax.jit, static_argnames=("chunk", "block_h", "interpret",
                                             "use_pallas"))
def ssd(xh, dt, a_log, b_ssm, c_ssm, *, chunk: Optional[int] = None,
        block_h: Optional[int] = None, interpret: bool = False,
        use_pallas: bool = True):
    """Chunked SSD scan; ``chunk``/``block_h`` default to the
    kernel-selection table (``repro.kernels.autotune.blocks_for`` on the
    (B, S, n, p, ds) shape; clamped heuristic on a miss) — pass them
    explicitly to override."""
    if not use_pallas:
        return ssd_ref(xh, dt, a_log, b_ssm, c_ssm)
    if chunk is None or block_h is None:
        bsz, s, n, p = xh.shape
        tc, th = autotune.blocks_for("ssd_scan", (bsz, s, n, p,
                                                  b_ssm.shape[-1]),
                                     str(xh.dtype), interpret=interpret)
        chunk = tc if chunk is None else chunk
        block_h = th if block_h is None else block_h
    return ssd_scan(xh, dt, a_log, b_ssm, c_ssm, chunk=chunk,
                    block_h=block_h, interpret=interpret)
