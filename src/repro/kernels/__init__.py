"""Pallas kernel stack — the compute hot-spots of the split-training path.

Each subpackage is a (kernel.py, ops.py, ref.py) triple:

* ``kernel.py`` — the Pallas TPU kernels themselves (grid/BlockSpec level);
* ``ref.py`` — pure-jnp oracles with the same contraction structure (the
  numerics baseline for tests and the CPU/GPU fallback);
* ``ops.py`` — the differentiable public entry point that routes between
  them (shape-alignment predicate, ``jax.custom_vjp``, impl selection).

``fused_linear`` is the one the FL engines train through: forward
``act(x @ w + b)`` plus a dedicated backward subsystem — a transposed-
operand ``dz @ wᵀ`` kernel and an ``xᵀ @ dz`` kernel with the ``db``
column-reduction fused in, both applying the relu activation mask inline
from the saved output so ``dz``/``w.T``/``x.T`` are never materialized in
HBM (design notes: ``docs/architecture.md``, "The kernel stack"). One
shared ``kernel.tile_plan`` gates pallas-vs-ref routing for forward and
both backward contractions. Set ``REPRO_FUSED_LINEAR_IMPL=interpret`` to
execute the kernel bodies on CPU (CI does, for tests/test_kernels.py).

``autotune`` is the cross-cutting module: a block-shape autotuner and a
persistent per-op selection table (``artifacts/autotune/*.json``, keyed
``op|shape|dtype|backend``) that every ops layer consults through
``autotune.blocks_for`` — exact autotuned match when one exists, the
clamped-128 heuristic otherwise; cold keys never sweep. Regenerate with
``benchmarks/kernel_bench.py --autotune``; validate with
``python -m repro.kernels.autotune --check``. The kernels run f32 VMEM
accumulation for every operand dtype, which is what makes the bf16
mixed-precision data plane (``Scenario.dtype="bf16"``) safe.

Add new subpackages only for compute the paper itself optimizes with a
custom kernel.
"""
