from repro.optim.optimizers import (
    Optimizer, adamw, sgd, cosine_schedule, clip_by_global_norm, global_norm,
)

__all__ = ["Optimizer", "adamw", "sgd", "cosine_schedule",
           "clip_by_global_norm", "global_norm"]
