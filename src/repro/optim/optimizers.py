"""Minimal pytree optimizers (no external deps).

API mirrors optax: ``opt.init(params) -> state``;
``opt.update(grads, state, params) -> (updates, state)``; apply with
``jax.tree.map(lambda p, u: p + u, params, updates)``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), tree), norm


def cosine_schedule(base_lr: float, warmup: int, total: int) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / jnp.maximum(1, warmup)
        frac = jnp.clip((step - warmup) / jnp.maximum(1, total - warmup), 0, 1)
        cos = 0.5 * base_lr * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)
    return lr


def sgd(lr, momentum: float = 0.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        state = {"step": jnp.zeros((), jnp.int32)}
        if momentum:
            state["mu"] = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return state

    def update(grads, state, params=None):
        step = state["step"] + 1
        lr_t = lr_fn(step)
        if momentum:
            mu = jax.tree.map(lambda m, g: momentum * m + g.astype(jnp.float32),
                              state["mu"], grads)
            upd = jax.tree.map(lambda m, g: (-lr_t * m).astype(g.dtype), mu, grads)
            return upd, {"step": step, "mu": mu}
        upd = jax.tree.map(lambda g: (-lr_t * g.astype(jnp.float32)).astype(g.dtype), grads)
        return upd, {"step": step}

    return Optimizer(init, update)


def adamw(lr, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0, moment_dtype=jnp.float32) -> Optimizer:
    """``moment_dtype=bfloat16`` halves optimizer-state HBM (the standard
    large-model memory move; update math still runs in fp32)."""
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, moment_dtype)
        return {"step": jnp.zeros((), jnp.int32),
                "m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params)}

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = lr_fn(step)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        m = jax.tree.map(
            lambda m_, g: (b1 * m_.astype(jnp.float32)
                           + (1 - b1) * g.astype(jnp.float32)).astype(moment_dtype),
            state["m"], grads)
        v = jax.tree.map(
            lambda v_, g: (b2 * v_.astype(jnp.float32)
                           + (1 - b2) * jnp.square(g.astype(jnp.float32))).astype(moment_dtype),
            state["v"], grads)

        def upd(m_, v_, p):
            u = ((m_.astype(jnp.float32) / bc1)
                 / (jnp.sqrt(v_.astype(jnp.float32) / bc2) + eps))
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (-lr_t * u).astype(p.dtype)

        return (jax.tree.map(upd, m, v, params),
                {"step": step, "m": m, "v": v})

    return Optimizer(init, update)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: p + u, params, updates)
