"""Fault injection for asynchronous FL: churn, stragglers, mid-round dropout.

Real IIoT fleets are intermittently connected: a device that the scheduler
selects may be offline when the dispatch lands (churn), may train but lose
its update on the way back (mid-round dropout), or may report late (a
straggler with a heavy-tailed extra delay). This module is the *model* of
those faults — a frozen per-scenario :class:`FaultModel` plus one
fixed-shape draw per round (:func:`draw_round_faults`) — consumed by the
buffered :class:`~repro.fl.async_engine.AsyncCohortEngine`.

RNG contract (the PR 2 fair-sweep contract): fault draws come from the
simulation's **network RNG stream** (``Simulation.net.rng``), the same
stream the per-round channel states are drawn from, so ``reset()`` replays
identical faults for every policy and ``save()``/``resume()`` restore them
bit-identically. Two invariants keep sweeps fair and parity exact:

* an **inactive** model (every rate 0) consumes **zero** draws — the
  degenerate async configuration therefore advances the network stream
  exactly like the synchronous engines, which is what pins the
  async==cohort parity oracle;
* an **active** model always consumes the same number of draws per round
  (four fixed-shape vectors) regardless of its rates, so runs differing
  only in fault *rates* still see identical channel-state sequences.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class FaultModel:
    """Per-round device fault probabilities (new ``Scenario`` axes).

    ``churn``: probability a scheduled device is offline at dispatch — it
    never trains and contributes nothing. ``dropout``: probability a device
    that did train loses its update mid-round (compute spent, nothing
    lands). ``straggler_frac``/``straggler_scale``: each device straggles
    with probability ``straggler_frac``; a straggler's extra delay is an
    ``Exp(mean=straggler_scale)`` *multiplicative* factor on its gateway's
    scheduled round delay (scale-free heavy tail: ``scale=1`` roughly
    doubles the delay in expectation, larger scales grow the tail).
    """
    churn: float = 0.0
    dropout: float = 0.0
    straggler_frac: float = 0.0
    straggler_scale: float = 0.0

    def __post_init__(self):
        for name in ("churn", "dropout", "straggler_frac"):
            p = getattr(self, name)
            if not 0.0 <= p < 1.0:
                raise ValueError(f"FaultModel.{name}={p}: need 0 <= p < 1")
        if self.straggler_scale < 0.0:
            raise ValueError(
                f"FaultModel.straggler_scale={self.straggler_scale}: "
                "need >= 0")

    @property
    def active(self) -> bool:
        """True when any fault can actually fire (controls whether a round
        consumes RNG draws — see the module docstring's RNG contract)."""
        return (self.churn > 0.0 or self.dropout > 0.0
                or (self.straggler_frac > 0.0 and self.straggler_scale > 0.0))

    @classmethod
    def from_scenario(cls, sc) -> "FaultModel":
        """Build from a :class:`repro.fl.sim.Scenario`'s fault axes."""
        return cls(churn=sc.churn, dropout=sc.dropout,
                   straggler_frac=sc.straggler_frac,
                   straggler_scale=sc.straggler_scale)


@dataclasses.dataclass
class RoundFaults:
    """One round's realized per-device faults.

    ``dropped[n]``: offline at dispatch (churn) — device n trains nothing.
    ``lost[n]``: trained, but the update vanished mid-round (disjoint from
    ``dropped``). ``straggle[n]``: extra multiplicative delay factor
    (``0.0`` for non-stragglers); a gateway's realized completion delay is
    its scheduled delay times ``1 + max(straggle)`` over its surviving
    devices.
    """
    dropped: np.ndarray      # (N,) bool
    lost: np.ndarray         # (N,) bool
    straggle: np.ndarray     # (N,) float >= 0

    @classmethod
    def clear(cls, n_devices: int) -> "RoundFaults":
        """The all-clear draw (what an inactive model realizes)."""
        return cls(np.zeros(n_devices, bool), np.zeros(n_devices, bool),
                   np.zeros(n_devices, float))


def draw_round_faults(rng: np.random.Generator, model: FaultModel,
                      n_devices: int) -> RoundFaults:
    """Draw one round of per-device faults from ``rng``.

    An inactive model returns :meth:`RoundFaults.clear` without touching
    ``rng``; an active model always draws exactly four ``(N,)`` vectors —
    churn gate, dropout gate, straggler gate, straggler magnitude — in that
    fixed order, so the stream advance per round is constant across fault
    rates (see the module docstring's RNG contract).
    """
    if not model.active:
        return RoundFaults.clear(n_devices)
    u_churn = rng.uniform(size=n_devices)
    u_lost = rng.uniform(size=n_devices)
    u_straggle = rng.uniform(size=n_devices)
    # mean-1 magnitudes scaled afterwards: the draw itself is rate-invariant
    magnitude = rng.exponential(1.0, size=n_devices)
    dropped = u_churn < model.churn
    lost = ~dropped & (u_lost < model.dropout)
    straggling = (~dropped & (u_straggle < model.straggler_frac)
                  & (model.straggler_scale > 0.0))
    straggle = np.where(straggling, model.straggler_scale * magnitude, 0.0)
    return RoundFaults(dropped, lost, straggle)
