"""Sharded 100+-device cohort engine: ``jax.shard_map`` over the slot axis.

The fused cohort engine (``repro.fl.cohort``) compiles one XLA program per
FL round, but executes the whole packed slot axis on a single accelerator —
fine for the paper's 12-device topology, a ceiling for the 100+-device
cohorts resource-constrained FL deployments target. This module removes
that ceiling by mapping the *same* fused round body over a 1-D ``"cohort"``
device mesh (``repro.sharding.cohort_mesh``):

* **device slots are sharded** — every tier's ``(S_k, W_k, ...)`` batch
  arrays split their slot axis evenly across mesh devices (the
  ``CohortLayout`` rounds each tier's slot count up to a mesh multiple);
* **model parameters are replicated** — each mesh device broadcasts the
  global model to its local slots and trains them exactly as the
  single-host engine would (same ``_local_train`` code);
* **two-tier FedAvg = masked ``psum`` s inside the mapped body** — each
  device reduces its local slots to weighted partial sums, one
  ``psum`` over the ``"cohort"`` axis completes the gateway-level and
  BS-level averages, so the per-gateway shop-floor models *and* the global
  model come out of the same program with no host round-trip.

The stats pass (``repro.fl.cohort.cohort_stats``) shards the same way: only
the global mixed gradient (for delta_n) needs a ``psum``; sigma_n and L_n
are per-device and run on the local shard.

Numerically the sharded round equals the single-host cohort round up to
reduction order (parity pinned at atol 1e-5 in ``tests/test_shard.py``,
including on a forced 8-device CPU mesh via
``XLA_FLAGS=--xla_force_host_platform_device_count=8``). On a 1-device
mesh — the CPU dev box default — it degrades gracefully to a plain fused
program.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map

from repro.fl import cohort as cohort_lib
from repro.fl import sim as sim_lib
from repro.models.split_model import Params, SplitModel
from repro.sharding import (COHORT_AXIS, REPLICATED, SLOT_SPEC,
                            STACKED_SLOT_SPEC, cohort_mesh)

# Trace-time counters (Python side effects run only while tracing), so tests
# and benchmarks can assert "exactly one compile across rounds".
# "train_scan" counts traces of the whole-run fused loop (fused_sim).
TRACE_COUNTS = {"round": 0, "stats": 0, "train_scan": 0}


def _psum(v):
    return jax.lax.psum(v, COHORT_AXIS)


def _fedavg_psum(final, w, losses, gw):
    """The two-tier FedAvg + per-gateway loss reduction as masked psums
    over the cohort axis — the reduction core shared by the per-round
    sharded program and the whole-run fused loop. ``final``/``w``/
    ``losses``/``gw`` are local-shard slot-major values; returns the
    replicated (new_global, gw_loss, gw_count, w_sum)."""
    w_sum = _psum(jnp.sum(w))
    new_global = jax.tree.map(
        lambda s: _psum(jnp.tensordot(w, s, axes=1))
        / jnp.maximum(w_sum, 1e-12), final)
    active = (w > 0).astype(jnp.float32)
    gw_count = _psum(gw.T @ active)                                 # (M,)
    gw_loss = _psum(gw.T @ (losses * active)) / jnp.maximum(gw_count, 1.0)
    return new_global, gw_loss, gw_count, w_sum


@functools.lru_cache(maxsize=None)
def _round_program(mesh, model: SplitModel, k_iters: int, n_tiers: int,
                   with_boundary: bool, with_gateway_models: bool,
                   compute_dtype: str = "f32"):
    """Compile-once sharded round: slots tiled over the mesh, params
    replicated, FedAvg as masked psums inside the mapped body.
    ``compute_dtype`` selects the mixed-precision data plane (part of the
    lru_cache key, so f32 and bf16 rounds compile separate programs)."""

    def body(params, xs, ys, masks, ls, ws, gws, lr):
        TRACE_COUNTS["round"] += 1
        xs = cohort_lib._maybe_flatten(model, xs)
        final_t, loss_t = cohort_lib._local_train(
            model, params, xs, ys, masks, k_iters, lr, compute_dtype)
        final = cohort_lib._concat_tiers(final_t)       # local slots only
        w = jnp.concatenate(ws)
        losses = jnp.concatenate(loss_t)
        gw = jnp.concatenate(gws)

        # BS-level FedAvg: local weighted partial sums -> one psum. The
        # gateway-level + BS-level averaging telescopes to a single weighted
        # average over participating slots, as in the single-host engine.
        # Per-gateway losses: masked psums over the slot->gateway incidence.
        new_global, gw_loss, gw_count, _ = _fedavg_psum(final, w, losses, gw)

        if with_boundary:
            boundary = cohort_lib._boundary_tiers(model, final_t, xs, masks, ls)
        else:
            boundary = tuple(jnp.zeros_like(wt) for wt in ws)

        if with_gateway_models:
            # gateway-level (shop-floor) FedAvg before the global mix, also
            # as masked psums: numerator and denominator per gateway column.
            gw_w = gw * w[:, None]                                  # (s, M)
            den = _psum(jnp.sum(gw_w, axis=0))                      # (M,)

            def col_avg(s):
                num = _psum(jnp.tensordot(gw_w.T, s, axes=1))       # (M, ...)
                return num / jnp.maximum(den, 1e-12).reshape(
                    (-1,) + (1,) * (num.ndim - 1))

            gw_models = jax.tree.map(col_avg, final)
        else:
            gw_models = None

        return new_global, gw_loss, gw_count, loss_t, boundary, gw_models

    tile, rep = SLOT_SPEC, REPLICATED
    fn = shard_map(body, mesh=mesh,
                   in_specs=(rep, tile, tile, tile, tile, tile, tile, rep),
                   out_specs=(rep, rep, rep, tile, tile, rep),
                   check_rep=False)
    return jax.jit(fn)


@functools.lru_cache(maxsize=None)
def _train_scan_program(mesh, model: SplitModel, k_iters: int, n_tiers: int,
                        compute_dtype: str = "f32"):
    """Compile-once sharded whole-run loop: ``shard_map(lax.scan(round))``.

    The sharded twin of ``repro.fl.cohort.train_scan``: per-round slot
    tensors arrive stacked with a leading round axis (sharded on axis 1,
    ``repro.sharding.STACKED_SLOT_SPEC``), the scan runs *inside* the
    mapped body so each mesh device sweeps its own slot shard through all
    rounds and the per-round FedAvg is the same masked-psum reduction the
    per-round program uses (:func:`_fedavg_psum`). Carries (params,
    per-gateway losses), applies the same no-trainer/trained-only guards as
    the single-host scan, returns (params, losses, (T, M) loss history,
    (T,) in-scan test hits — see ``repro.fl.cohort._eval_hits``), all
    replicated (every mesh device evaluates the replicated params on the
    replicated test set; identical math, identical hits).
    """

    def body(params, losses0, xs, ys, masks, ws, gws, trained, lr,
             eval_mask, x_test, y_test):
        TRACE_COUNTS["train_scan"] += 1
        x_eval = model.prepare_inputs(x_test)

        def step(carry, x):
            params, losses = carry
            xs_t, ys_t, masks_t, w_t, gw_t, tr_t, ev_t = x
            xs_t = cohort_lib._maybe_flatten(model, xs_t)
            final_t, loss_t = cohort_lib._local_train(
                model, params, xs_t, ys_t, masks_t, k_iters, lr,
                compute_dtype)
            final = cohort_lib._concat_tiers(final_t)   # local slots only
            new_global, gw_loss, _, w_sum = _fedavg_psum(
                final, jnp.concatenate(w_t), jnp.concatenate(loss_t),
                jnp.concatenate(gw_t))
            any_trained = w_sum > 0
            params = jax.tree.map(
                lambda new, old: jnp.where(any_trained, new, old),
                new_global, params)
            losses = jnp.where(tr_t, gw_loss, losses)
            hits = cohort_lib._eval_hits(model, params, x_eval, y_test,
                                         ev_t)
            return (params, losses), (losses, hits)

        (params, losses), (loss_hist, hits) = jax.lax.scan(
            step, (params, losses0),
            (xs, ys, masks, ws, gws, trained, eval_mask))
        return params, losses, loss_hist, hits

    stk, rep = STACKED_SLOT_SPEC, REPLICATED
    fn = shard_map(body, mesh=mesh,
                   in_specs=(rep, rep, stk, stk, stk, stk, stk, rep, rep,
                             rep, rep, rep),
                   out_specs=(rep, rep, rep, rep),
                   check_rep=False)
    return jax.jit(fn)


@functools.lru_cache(maxsize=None)
def _train_scan_program_traced(mesh, model: SplitModel, k_iters: int,
                               n_tiers: int, compute_dtype: str,
                               tier_widths: Tuple[int, ...]):
    """The sharded twin of ``repro.fl.cohort.train_scan_traced``: the data
    plane lives inside the mapped body.

    The device-resident shard stacks (``x_all``/``y_all``) and the data key
    are replicated; only each round's slot->device assignment (a few int32s
    per slot) is sharded over the mesh, and every mesh device gathers its
    own slots' batches in-scan via the counter-based draw
    (``repro.fl.data.traced_batch_indices``) — so the host ships decision
    tensors, never ``(T, S_k, W_k, ...)`` sample stacks.
    """

    def body(params, losses0, x_all, y_all, pool_lens, batch_lens, data_key,
             ts, slot_devs, ws, gws, trained, lr, eval_mask, x_test,
             y_test):
        TRACE_COUNTS["train_scan"] += 1
        x_eval = model.prepare_inputs(x_test)
        l_max = x_all.shape[1]

        def gather_tier(t, devs, width):
            def one(dev):
                d = jnp.maximum(dev, 0)
                idx = cohort_lib._traced_indices(data_key, t, d,
                                                 pool_lens[d], width, l_max)
                mb = ((jnp.arange(width) < batch_lens[d]) & (dev >= 0)
                      ).astype(jnp.float32)
                return x_all[d][idx], y_all[d][idx], mb
            return jax.vmap(one)(devs)

        def step(carry, x):
            params, losses = carry
            t, sd_t, w_t, gw_t, tr_t, ev_t = x
            gathered = [gather_tier(t, devs, width)
                        for devs, width in zip(sd_t, tier_widths)]
            xs_t = cohort_lib._maybe_flatten(
                model, tuple(g[0] for g in gathered))
            ys_t = tuple(g[1] for g in gathered)
            masks_t = tuple(g[2] for g in gathered)
            final_t, loss_t = cohort_lib._local_train(
                model, params, xs_t, ys_t, masks_t, k_iters, lr,
                compute_dtype)
            final = cohort_lib._concat_tiers(final_t)   # local slots only
            new_global, gw_loss, _, w_sum = _fedavg_psum(
                final, jnp.concatenate(w_t), jnp.concatenate(loss_t),
                jnp.concatenate(gw_t))
            any_trained = w_sum > 0
            params = jax.tree.map(
                lambda new, old: jnp.where(any_trained, new, old),
                new_global, params)
            losses = jnp.where(tr_t, gw_loss, losses)
            hits = cohort_lib._eval_hits(model, params, x_eval, y_test,
                                         ev_t)
            return (params, losses), (losses, hits)

        (params, losses), (loss_hist, hits) = jax.lax.scan(
            step, (params, losses0),
            (ts, slot_devs, ws, gws, trained, eval_mask))
        return params, losses, loss_hist, hits

    stk, rep = STACKED_SLOT_SPEC, REPLICATED
    fn = shard_map(body, mesh=mesh,
                   in_specs=(rep, rep, rep, rep, rep, rep, rep, rep, stk,
                             stk, stk, rep, rep, rep, rep, rep),
                   out_specs=(rep, rep, rep, rep),
                   check_rep=False)
    return jax.jit(fn)


@functools.lru_cache(maxsize=None)
def _stats_program(mesh, model: SplitModel, sigma_samples: int):
    """Compile-once sharded stats pass: device rows tiled over the mesh;
    only the globally-mixed gradient (for delta_n) crosses shards."""

    def body(params, x, y, mask, mix_w, lr):
        TRACE_COUNTS["stats"] += 1
        x = model.prepare_inputs(x)
        grads, sigma, lips = cohort_lib._grads_sigma_lips(
            model, params, x, y, mask, lr, sigma_samples)
        global_g = _psum(jnp.tensordot(mix_w, grads, axes=1))
        delta = jnp.linalg.norm(grads - global_g[None], axis=1)
        return sigma, delta, lips

    tile, rep = SLOT_SPEC, REPLICATED
    fn = shard_map(body, mesh=mesh,
                   in_specs=(rep, tile, tile, tile, tile, rep),
                   out_specs=(tile, tile, tile),
                   check_rep=False)
    return jax.jit(fn)


def _pad_rows(a: np.ndarray, rows: int) -> np.ndarray:
    """Zero-pad the leading axis of ``a`` up to ``rows``."""
    if a.shape[0] == rows:
        return a
    pad = np.zeros((rows - a.shape[0],) + a.shape[1:], a.dtype)
    return np.concatenate([a, pad])


def sharded_cohort_round(mesh, model: SplitModel, params: Params, batch, l_slot,
                         w_slot, gw_onehot, k_iters: int, lr,
                         with_boundary: bool = True,
                         with_gateway_models: bool = False,
                         compute_dtype: str = "f32") -> Tuple:
    """Run one fused FL round sharded over ``mesh``'s ``"cohort"`` axis.

    Same contract and return convention as
    ``repro.fl.cohort.cohort_round`` (5-tuple, or 6-tuple with the gateway
    models when ``with_gateway_models`` is set); ``batch`` may be a
    ``CohortBatch`` or a ``TieredCohortBatch``. Tiers whose slot count does
    not divide the mesh size are transparently zero-padded (empty slots are
    masked out of every reduction) and the per-slot outputs are trimmed
    back, so all-device layouts work unchanged on any mesh.
    """
    n_mesh = mesh.shape[COHORT_AXIS]
    xs, ys, masks = cohort_lib._batch_tiers(batch)
    sizes = tuple(x.shape[0] for x in xs)
    padded = tuple(-(-s // n_mesh) * n_mesh for s in sizes)

    l_t = cohort_lib._split_tiers(np.asarray(l_slot), sizes)
    w_t = cohort_lib._split_tiers(np.asarray(w_slot), sizes)
    gw_t = cohort_lib._split_tiers(np.asarray(gw_onehot), sizes)

    def pad_all(arrs, dtype=None):
        return tuple(jnp.asarray(_pad_rows(np.asarray(a, dtype), p))
                     for a, p in zip(arrs, padded))

    xs = pad_all(xs)
    ys = pad_all(ys)
    masks = pad_all(masks, np.float32)
    l_t = pad_all(l_t, np.int32)
    w_t = pad_all(w_t, np.float32)
    gw_t = pad_all(gw_t, np.float32)

    fn = _round_program(mesh, model, k_iters, len(sizes),
                        with_boundary, with_gateway_models, compute_dtype)
    new_global, gw_loss, gw_count, loss_t, boundary_t, gw_models = fn(
        params, xs, ys, masks, l_t, w_t, gw_t, jnp.float32(lr))

    # trim the per-tier padding back off the per-slot outputs
    dev_losses = jnp.concatenate([v[:s] for v, s in zip(loss_t, sizes)])
    boundary = jnp.concatenate([v[:s] for v, s in zip(boundary_t, sizes)])
    out = (new_global, gw_loss, gw_count, dev_losses, boundary)
    return (*out, gw_models) if with_gateway_models else out


def sharded_cohort_stats(mesh, model: SplitModel, params: Params, batch,
                         mix_weights, lr, sigma_samples: int):
    """sigma/delta/Lipschitz for every device, sharded over ``mesh``.

    Mirrors ``repro.fl.cohort.cohort_stats``: ``batch`` uses the
    all-devices layout (row n = device n); rows are zero-padded to a mesh
    multiple and the padding is trimmed from the outputs.
    """
    n_mesh = mesh.shape[COHORT_AXIS]
    n_dev = batch.x.shape[0]
    rows = -(-n_dev // n_mesh) * n_mesh
    fn = _stats_program(mesh, model, sigma_samples)
    sigma, delta, lips = fn(
        params,
        jnp.asarray(_pad_rows(np.asarray(batch.x), rows)),
        jnp.asarray(_pad_rows(np.asarray(batch.y), rows)),
        jnp.asarray(_pad_rows(np.asarray(batch.mask, np.float32), rows)),
        jnp.asarray(_pad_rows(np.asarray(mix_weights, np.float32), rows)),
        jnp.float32(lr))
    return sigma[:n_dev], delta[:n_dev], lips[:n_dev]


@sim_lib.register_engine("sharded")
class ShardedCohortEngine(sim_lib.CohortEngine):
    """Cohort engine sharded over a 1-D ``"cohort"`` device mesh.

    Drop-in replacement for :class:`repro.fl.sim.CohortEngine` for
    100+-device cohorts: identical packing/telemetry logic, but the fused
    round and stats programs run under ``jax.shard_map`` with device slots
    sharded, parameters replicated, and the two-tier FedAvg reduced via
    masked psums (see the module docstring). ``Scenario.mesh_shape`` picks
    the mesh size (``None`` = every addressable device); on a single-device
    host it falls back to a 1-device mesh with identical numerics.
    """

    def _mesh(self, sim: "sim_lib.Simulation"):
        """The (cached) cohort mesh this simulation's scenario asked for."""
        return cohort_mesh(sim.scenario.mesh_shape)

    def _shard_count(self, sim: "sim_lib.Simulation") -> int:
        """Tier slot counts must divide the cohort mesh size."""
        return int(self._mesh(sim).shape[COHORT_AXIS])

    def _fused_round(self, sim: "sim_lib.Simulation", params, batch, l_slot,
                     w_slot, gw_slot, *, with_boundary: bool,
                     with_gateway_models: bool):
        """Run the round under shard_map instead of on a single device."""
        sc = sim.scenario
        out = sharded_cohort_round(
            self._mesh(sim), sim.plan, params, batch, l_slot, w_slot,
            gw_slot, sc.k_iters, sc.lr, with_boundary=with_boundary,
            with_gateway_models=with_gateway_models,
            compute_dtype=sc.dtype)
        return out if with_gateway_models else (*out, None)

    def _fused_stats(self, sim: "sim_lib.Simulation", params, batch, mix):
        """Run the sigma/delta/L_n program under shard_map (same rng draws
        and DataStats post-processing as the single-host cohort engine, so
        engines stay swappable)."""
        sc = sim.scenario
        return sharded_cohort_stats(self._mesh(sim), sim.plan, params,
                                    batch, mix, sc.lr, sc.sigma_samples)

    def fused_train(self, sim: "sim_lib.Simulation", params, losses0, xs,
                    ys, masks, ls, ws, gws, trained, eval_mask=None):
        """All rounds as one sharded program: ``shard_map(lax.scan)`` with
        each tier's slot axis split over the cohort mesh (the engine's
        layout already rounds tier slot counts to mesh multiples, so the
        stacked arrays shard evenly — no padding pass needed). ``ls`` is
        unused (no boundary telemetry inside the scan)."""
        sc = sim.scenario
        if eval_mask is None:
            eval_mask = np.zeros(trained.shape[0], bool)
        fn = _train_scan_program(self._mesh(sim), sim.plan, sc.k_iters,
                                 len(xs), sc.dtype)
        x_test, y_test = self._eval_arrays(sim)
        return fn(params, jnp.asarray(np.asarray(losses0), jnp.float32),
                  xs, ys, masks, ws, gws, trained, jnp.float32(sc.lr),
                  jnp.asarray(np.asarray(eval_mask, bool)),
                  x_test, y_test)

    def fused_train_traced(self, sim: "sim_lib.Simulation", params, losses0,
                           ts, slot_devs, ls, ws, gws, trained, eval_mask,
                           layout):
        """The traced-data-plane whole-run program, sharded: replicated
        shard stacks + mesh-sharded slot assignments (see
        :func:`_train_scan_program_traced`). ``ls`` is unused, as in
        :meth:`fused_train`."""
        sc = sim.scenario
        x_all, y_all, pool = self._data_stacks(sim)
        batch_lens = np.minimum(
            np.asarray(sim.d_tilde, np.int32), pool).astype(np.int32)
        fn = _train_scan_program_traced(
            self._mesh(sim), sim.plan, sc.k_iters, len(slot_devs), sc.dtype,
            tuple(layout.tier_widths))
        x_test, y_test = self._eval_arrays(sim)
        return fn(params, jnp.asarray(np.asarray(losses0), jnp.float32),
                  x_all, y_all, jnp.asarray(pool),
                  jnp.asarray(batch_lens), sim.data_key,
                  jnp.asarray(np.asarray(ts, np.int32)), slot_devs, ws, gws,
                  trained, jnp.float32(sc.lr),
                  jnp.asarray(np.asarray(eval_mask, bool)),
                  x_test, y_test)
