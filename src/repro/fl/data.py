"""Synthetic SVHN/CIFAR-like non-IID data pipeline.

Container is offline, so we synthesize a 10-class 32x32x3 task whose class
structure is learnable by VGG/MLP: each class has a smooth random template;
samples are template + noise + random brightness. Non-IID partitioning
follows the paper/[50]: device n holds data points from ``q`` classes only
("q_m-class non-IID"), with non-IID degree ``chi`` (proportion of q-class
points; the rest is IID spillover).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class FLDataset:
    x_dev: List[np.ndarray]     # per-device images (D_n, 32, 32, 3)
    y_dev: List[np.ndarray]
    x_test: np.ndarray
    y_test: np.ndarray
    classes_of: List[np.ndarray]


def _class_templates(rng: np.random.Generator, classes: int, size: int = 32):
    """Smooth random template per class (low-freq Fourier pattern)."""
    t = []
    coords = np.linspace(0, 2 * np.pi, size)
    xx, yy = np.meshgrid(coords, coords)
    for _ in range(classes):
        img = np.zeros((size, size, 3))
        for c in range(3):
            for _ in range(4):
                fx, fy = rng.integers(1, 4, 2)
                ph = rng.uniform(0, 2 * np.pi, 2)
                img[:, :, c] += rng.normal() * np.sin(fx * xx + ph[0]) * np.cos(fy * yy + ph[1])
        t.append(img / np.abs(img).max())
    return np.stack(t)


def _sample(rng, templates, cls: np.ndarray, noise: float = 0.35):
    base = templates[cls]
    jitter = rng.normal(0, noise, base.shape)
    bright = rng.uniform(0.7, 1.3, (len(cls), 1, 1, 1))
    return (base * bright + jitter).astype(np.float32)


def make_fl_dataset(n_devices: int, sizes: np.ndarray, q_classes: np.ndarray,
                    chi: float = 1.0, classes: int = 10, test_size: int = 1000,
                    seed: int = 0) -> FLDataset:
    """sizes: (N,) local dataset sizes D_n; q_classes: (N,) classes per device."""
    rng = np.random.default_rng(seed)
    templates = _class_templates(rng, classes)
    x_dev, y_dev, cls_of = [], [], []
    for n in range(n_devices):
        own = rng.choice(classes, size=min(int(q_classes[n]), classes), replace=False)
        cls_of.append(own)
        d = int(sizes[n])
        n_noniid = int(round(chi * d))
        y = np.concatenate([
            rng.choice(own, size=n_noniid),
            rng.integers(0, classes, size=d - n_noniid),
        ]).astype(np.int32)
        rng.shuffle(y)
        x_dev.append(_sample(rng, templates, y))
        y_dev.append(y)
    y_test = np.tile(np.arange(classes), test_size // classes).astype(np.int32)
    x_test = _sample(rng, templates, y_test)
    return FLDataset(x_dev, y_dev, x_test, y_test, cls_of)


def sample_batch(rng: np.random.Generator, ds: FLDataset, n: int,
                 batch: int) -> Tuple[np.ndarray, np.ndarray]:
    idx = rng.choice(len(ds.y_dev[n]), size=min(batch, len(ds.y_dev[n])),
                     replace=False)
    return ds.x_dev[n][idx], ds.y_dev[n][idx]


@dataclasses.dataclass
class CohortBatch:
    """Fixed-shape padded per-device batches for the cohort engine.

    Every round produces the SAME array shapes regardless of which devices
    participate — (N, B_pad, ...) with a validity mask — so the jitted cohort
    step compiles exactly once. Non-participating devices keep all-zero
    rows and an all-zero mask.
    """
    x: np.ndarray        # (N, B_pad, ...) float32
    y: np.ndarray        # (N, B_pad) int32
    mask: np.ndarray     # (N, B_pad) float32, 1.0 on valid rows


def sample_cohort_batch(rng: np.random.Generator, ds: FLDataset,
                        device_ids, batch_sizes: np.ndarray,
                        pad_to: int, capacity: Optional[int] = None,
                        ) -> CohortBatch:
    """Sample one padded batch per device in ``device_ids``.

    Draws from ``rng`` in the order given by ``device_ids`` with exactly the
    same calls as the sequential ``sample_batch`` loop, so a cohort round and
    the seed per-device loop see identical data for identical rng states.

    Without ``capacity`` the leading axis indexes *all* devices (row n =
    device n). With ``capacity`` the participating devices are packed into
    ``capacity`` slots in ``device_ids`` order — the scheduler can select at
    most (channels x shop-floor size) devices per round, so a fixed slot
    count keeps shapes static while skipping compute for absent devices.
    """
    device_ids = [int(n) for n in device_ids]
    packed = capacity is not None
    rows = capacity if packed else len(ds.y_dev)
    assert len(device_ids) <= rows, "more participants than cohort slots"
    sample_shape = ds.x_dev[0].shape[1:]
    x = np.zeros((rows, pad_to) + sample_shape, np.float32)
    y = np.zeros((rows, pad_to), np.int32)
    mask = np.zeros((rows, pad_to), np.float32)
    for slot, n in enumerate(device_ids):
        xb, yb = sample_batch(rng, ds, n, int(batch_sizes[n]))
        b = len(yb)
        row = slot if packed else n
        x[row, :b] = xb
        y[row, :b] = yb
        mask[row, :b] = 1.0
    return CohortBatch(x, y, mask)
