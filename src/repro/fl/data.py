"""Synthetic SVHN/CIFAR-like non-IID data pipeline.

Container is offline, so we synthesize a 10-class 32x32x3 task whose class
structure is learnable by VGG/MLP: each class has a smooth random template;
samples are template + noise + random brightness. Non-IID partitioning
follows the paper/[50]: device n holds data points from ``q`` classes only
("q_m-class non-IID"), with non-IID degree ``chi`` (proportion of q-class
points; the rest is IID spillover).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class FLDataset:
    """Synthetic non-IID FL dataset: one private shard per device plus a
    shared IID test set (see the module docstring for how it is generated)."""
    x_dev: List[np.ndarray]     # per-device images (D_n, 32, 32, 3)
    y_dev: List[np.ndarray]
    x_test: np.ndarray
    y_test: np.ndarray
    classes_of: List[np.ndarray]


def _class_templates(rng: np.random.Generator, classes: int, size: int = 32):
    """Smooth random template per class (low-freq Fourier pattern)."""
    t = []
    coords = np.linspace(0, 2 * np.pi, size)
    xx, yy = np.meshgrid(coords, coords)
    for _ in range(classes):
        img = np.zeros((size, size, 3))
        for c in range(3):
            for _ in range(4):
                fx, fy = rng.integers(1, 4, 2)
                ph = rng.uniform(0, 2 * np.pi, 2)
                img[:, :, c] += rng.normal() * np.sin(fx * xx + ph[0]) * np.cos(fy * yy + ph[1])
        t.append(img / np.abs(img).max())
    return np.stack(t)


def _sample(rng, templates, cls: np.ndarray, noise: float = 0.35):
    base = templates[cls]
    jitter = rng.normal(0, noise, base.shape)
    bright = rng.uniform(0.7, 1.3, (len(cls), 1, 1, 1))
    return (base * bright + jitter).astype(np.float32)


def make_fl_dataset(n_devices: int, sizes: np.ndarray, q_classes: np.ndarray,
                    chi: float = 1.0, classes: int = 10, test_size: int = 1000,
                    seed: int = 0) -> FLDataset:
    """sizes: (N,) local dataset sizes D_n; q_classes: (N,) classes per device."""
    rng = np.random.default_rng(seed)
    templates = _class_templates(rng, classes)
    x_dev, y_dev, cls_of = [], [], []
    for n in range(n_devices):
        own = rng.choice(classes, size=min(int(q_classes[n]), classes), replace=False)
        cls_of.append(own)
        d = int(sizes[n])
        n_noniid = int(round(chi * d))
        y = np.concatenate([
            rng.choice(own, size=n_noniid),
            rng.integers(0, classes, size=d - n_noniid),
        ]).astype(np.int32)
        rng.shuffle(y)
        x_dev.append(_sample(rng, templates, y))
        y_dev.append(y)
    y_test = np.tile(np.arange(classes), test_size // classes).astype(np.int32)
    x_test = _sample(rng, templates, y_test)
    return FLDataset(x_dev, y_dev, x_test, y_test, cls_of)


# ---------------------------------------------------------------------------
# Token corpora for the sequence model zoo (next-token prediction)
# ---------------------------------------------------------------------------


def _markov_steps(rng: np.random.Generator, succ_dev: np.ndarray,
                  succ_glob: np.ndarray, chi: float, vocab: int,
                  n_seq: int, length: int) -> np.ndarray:
    """Walk ``n_seq`` Markov chains of ``length`` tokens at once.

    Each token's successors are one of ``branching`` table entries; every
    step mixes the device's private table with the shared global one by
    ``chi`` (the token twin of the q-class non-IID mixing). Vectorized over
    all sequences, so generation is O(length) table lookups.
    """
    seq = np.empty((n_seq, length), np.int32)
    tok = rng.integers(0, vocab, size=n_seq).astype(np.int32)
    seq[:, 0] = tok
    branching = succ_glob.shape[1]
    for t in range(1, length):
        branch = rng.integers(0, branching, size=n_seq)
        use_dev = rng.random(n_seq) < chi
        tok = np.where(use_dev, succ_dev[tok, branch],
                       succ_glob[tok, branch]).astype(np.int32)
        seq[:, t] = tok
    return seq


def make_token_fl_dataset(n_devices: int, sizes: np.ndarray, vocab: int = 128,
                          seq_len: int = 32, chi: float = 1.0,
                          branching: int = 4, test_size: int = 256,
                          seed: int = 0) -> FLDataset:
    """Synthetic non-IID token corpora for next-token prediction.

    Device ``n`` holds ``sizes[n]`` sequences of ``seq_len`` tokens drawn
    from a Markov chain: a *shared* global successor table (the learnable
    structure every device agrees on) chi-mixed with a *private* per-device
    table (the non-IID component — each device speaks its own dialect).
    ``x_dev[n]`` is ``(D_n, seq_len)`` int32 tokens, ``y_dev[n]`` the
    shifted next-token labels of the same shape; the shared test set is
    drawn from the global table alone. The :class:`FLDataset` shape
    contract (per-device shards + common test set) is unchanged — only the
    sample rank/dtype differ, which the cohort packing reads off the data.
    """
    rng = np.random.default_rng(seed)
    succ_glob = rng.integers(0, vocab, size=(vocab, branching))
    x_dev, y_dev, cls_of = [], [], []
    for n in range(n_devices):
        succ_dev = rng.integers(0, vocab, size=(vocab, branching))
        cls_of.append(np.unique(succ_dev))
        seq = _markov_steps(rng, succ_dev, succ_glob, chi, vocab,
                            int(sizes[n]), seq_len + 1)
        x_dev.append(seq[:, :-1].copy())
        y_dev.append(seq[:, 1:].copy())
    seq = _markov_steps(rng, succ_glob, succ_glob, 0.0, vocab,
                        test_size, seq_len + 1)
    return FLDataset(x_dev, y_dev, seq[:, :-1].copy(), seq[:, 1:].copy(),
                     cls_of)


def sample_batch(rng: np.random.Generator, ds: FLDataset, n: int,
                 batch: int) -> Tuple[np.ndarray, np.ndarray]:
    """Draw one training batch (without replacement) from device ``n``'s
    private shard; the batch shrinks to the shard size when it is smaller."""
    idx = rng.choice(len(ds.y_dev[n]), size=min(batch, len(ds.y_dev[n])),
                     replace=False)
    return ds.x_dev[n][idx], ds.y_dev[n][idx]


@dataclasses.dataclass
class CohortBatch:
    """Fixed-shape padded per-device batches for the cohort engine.

    Every round produces the SAME array shapes regardless of which devices
    participate — (N, B_pad, ...) with a validity mask — so the jitted cohort
    step compiles exactly once. Non-participating devices keep all-zero
    rows and an all-zero mask.
    """
    x: np.ndarray        # (N, B_pad, ...) float32
    y: np.ndarray        # (N, B_pad) int32
    mask: np.ndarray     # (N, B_pad) float32, 1.0 on valid rows


@dataclasses.dataclass(frozen=True)
class CohortLayout:
    """Tiered slot layout for the cohort engines (fixed across all rounds).

    The single-width contract pads every slot to the *global* maximum
    training batch ``max(d_tilde)``, wasting up to ~2x the samples actually
    trained on. A tiered layout instead pads slot *i* to (roughly) the i-th
    largest global ``d_tilde``: the slots are split into ``len(tier_widths)``
    contiguous tiers, every slot in tier *k* is ``tier_widths[k]`` samples
    wide, and the fused round runs one ``vmap`` segment per tier inside the
    same jitted program. Widths are derived from the global (all-device)
    ``d_tilde`` vector, so the layout — and therefore every array shape —
    never changes across rounds, device subsets or partition decisions.

    **Fit guarantee.** Widths descend tier over tier and devices are packed
    into slots in decreasing batch-size order, so the k-th largest
    participating batch always lands in a slot at least as wide as the k-th
    largest global ``d_tilde`` — every participant fits, for every subset of
    at most ``capacity`` devices.

    ``shard_count`` rounds each tier's slot count up to a multiple of the
    cohort-mesh size so `jax.shard_map` can split every tier evenly across
    mesh devices; the extra slots stay permanently empty (zero mask/weight).
    """
    tier_widths: Tuple[int, ...]    # padded batch width per tier (descending)
    tier_slots: Tuple[int, ...]     # number of slots per tier

    #: candidate tier counts scanned by ``tiers="auto"`` (bounds the number
    #: of vmap segments — and therefore compile time — the fused round pays)
    AUTO_MAX_TIERS = 8

    @classmethod
    def build(cls, d_tilde: np.ndarray, capacity: Optional[int] = None,
              tiers=1, shard_count: int = 1) -> "CohortLayout":
        """Derive a layout from the global per-device batch sizes.

        ``capacity``: number of (pre-padding) slots — the most devices a
        round can schedule (defaults to all devices). ``tiers``: how many
        distinct widths to use (1 reproduces the single-width contract), or
        ``"auto"`` to pick the count from the d_tilde histogram (see
        :meth:`auto_tiers`). ``shard_count``: round every tier's slot count
        up to this multiple.
        """
        widths = np.sort(np.asarray(d_tilde, dtype=int))[::-1]
        capacity = len(widths) if capacity is None else int(capacity)
        assert 1 <= capacity <= len(widths), (capacity, len(widths))
        if tiers == "auto":
            tiers = cls.auto_tiers(d_tilde, capacity, shard_count)
        tiers = max(1, min(int(tiers), capacity))
        groups = np.array_split(np.arange(capacity), tiers)
        tier_widths, tier_slots = [], []
        for g in groups:
            tier_widths.append(int(widths[g[0]]))     # widest in the group
            n_slots = -(-len(g) // shard_count) * shard_count
            tier_slots.append(int(n_slots))
        return cls(tuple(tier_widths), tuple(tier_slots))

    @classmethod
    def auto_tiers(cls, d_tilde: np.ndarray, capacity: Optional[int] = None,
                   shard_count: int = 1) -> int:
        """Pick a tier count from the padded-samples curve.

        Evaluates ``padded_samples`` for every candidate tier count
        ``1..min(capacity, AUTO_MAX_TIERS)`` and returns the smallest count
        reaching the curve's floor — the elbow where extra tiers stop
        paying for their extra vmap segments. ``array_split`` groupings are
        not nested, so the curve is *not* monotone (and ``shard_count``
        rounding can make more tiers strictly worse); taking the argmin of
        the realized curve (ties -> fewest tiers) both rides the elbow and
        guarantees auto never pads more than any manual choice among the
        candidates — in particular the {1, 4}-tier baselines.
        """
        widths = np.asarray(d_tilde, dtype=int)
        capacity = len(widths) if capacity is None else int(capacity)
        candidates = range(1, min(capacity, cls.AUTO_MAX_TIERS) + 1)
        padded = [cls.build(widths, capacity, t, shard_count).padded_samples
                  for t in candidates]
        return 1 + int(np.argmin(padded))

    @property
    def n_slots(self) -> int:
        """Total slot count (after any shard_count rounding)."""
        return sum(self.tier_slots)

    @property
    def slot_widths(self) -> np.ndarray:
        """(n_slots,) padded width of every slot, in tier-major order."""
        return np.repeat(self.tier_widths, self.tier_slots)

    @property
    def padded_samples(self) -> int:
        """Samples the fused round computes on per epoch (the whole padded
        slot area — empty and partially-filled slots included)."""
        return int(np.dot(self.tier_widths, self.tier_slots))

    def locate(self, slot: int) -> Tuple[int, int]:
        """Map a tier-major global slot index to its (tier, row) pair."""
        for k, s in enumerate(self.tier_slots):
            if slot < s:
                return k, slot
            slot -= s
        raise IndexError(slot)


@dataclasses.dataclass
class TieredCohortBatch:
    """Per-tier padded batches + the device->slot assignment of one round.

    ``tiers[k]`` holds tier *k*'s arrays with shape
    ``(layout.tier_slots[k], layout.tier_widths[k], ...)``; ``slot_of[i]``
    is the tier-major global slot that ``device_ids[i]``'s samples landed
    in. Per-slot engine outputs (losses, boundary RMS) use the same
    tier-major indexing, so ``out[slot_of]`` scatters them back to devices.
    """
    tiers: Tuple[CohortBatch, ...]
    slot_of: np.ndarray              # (len(device_ids),) int
    layout: CohortLayout


def zero_slot_rows(batch: "TieredCohortBatch", slots) -> "TieredCohortBatch":
    """Return a copy of ``batch`` with the given tier-major slots zeroed.

    The per-row validity mask doubles as a **completion mask**: a slot whose
    mask is all-zero contributes an exact-zero loss and exact-zero gradients
    to the fused round (``masked_xent_loss`` sums over valid rows only), so
    zeroing a slot models a device that never executed its dispatch — e.g.
    one that churned offline — without changing any array shape. The fused
    program still runs the slot (shapes are the compile contract), but its
    parameters stay at the broadcast global model and its zero FedAvg weight
    keeps it out of every aggregate. ``batch`` is not mutated; with no
    ``slots`` it is returned as-is.
    """
    slots = list(slots)
    if not slots:
        return batch
    tiers = [CohortBatch(t.x.copy(), t.y.copy(), t.mask.copy())
             for t in batch.tiers]
    for s in slots:
        k, row = batch.layout.locate(int(s))
        tiers[k].x[row] = 0.0
        tiers[k].y[row] = 0
        tiers[k].mask[row] = 0.0
    return TieredCohortBatch(tuple(tiers), batch.slot_of, batch.layout)


def sample_cohort_batch(rng: np.random.Generator, ds: FLDataset,
                        device_ids, batch_sizes: np.ndarray,
                        pad_to: Optional[int] = None,
                        capacity: Optional[int] = None,
                        layout: Optional[CohortLayout] = None,
                        ):
    """Sample one padded batch per device in ``device_ids``.

    This function owns the cohort packing contract. Draws always come from
    ``rng`` in the order given by ``device_ids`` with exactly the same calls
    as the sequential ``sample_batch`` loop, so every engine (sequential,
    cohort, sharded) sees identical data for identical rng states.

    Three layouts, one sampling order:

    * default — the leading axis indexes *all* devices (row n = device n),
      every row padded to ``pad_to``; returns a :class:`CohortBatch`.
    * ``capacity`` — participants are packed into ``capacity``
      ``pad_to``-wide slots in ``device_ids`` order — the scheduler can
      select at most (channels x shop-floor size) devices per round, so a
      fixed slot count keeps shapes static while skipping compute for
      absent devices; returns a :class:`CohortBatch`.
    * ``layout`` — tiered slot widths (:class:`CohortLayout`): after
      sampling, devices are assigned to slots in decreasing batch-size
      order (tier-major), which the layout's fit guarantee makes always
      succeed; returns a :class:`TieredCohortBatch` carrying the
      device->slot assignment.
    """
    device_ids = [int(n) for n in device_ids]
    if layout is not None:
        assert len(device_ids) <= layout.n_slots, \
            "more participants than cohort slots"
        draws = [sample_batch(rng, ds, n, int(batch_sizes[n]))
                 for n in device_ids]                  # rng order preserved
        lens = np.array([len(yb) for _, yb in draws], dtype=int)
        sample_shape = ds.x_dev[0].shape[1:]
        label_shape = ds.y_dev[0].shape[1:]
        tiers = [CohortBatch(
            np.zeros((s, w) + sample_shape, ds.x_dev[0].dtype),
            np.zeros((s, w) + label_shape, ds.y_dev[0].dtype),
            np.zeros((s, w), np.float32))
            for s, w in zip(layout.tier_slots, layout.tier_widths)]
        slot_of = np.empty(len(device_ids), dtype=int)
        # largest batches first: rank r goes to global slot r, whose width
        # is >= the r-th largest global d_tilde >= this batch (fit guarantee)
        for rank, di in enumerate(np.argsort(-lens, kind="stable")):
            k, row = layout.locate(rank)
            xb, yb = draws[di]
            b = len(yb)
            assert b <= layout.tier_widths[k], (b, layout.tier_widths[k])
            tiers[k].x[row, :b] = xb
            tiers[k].y[row, :b] = yb
            tiers[k].mask[row, :b] = 1.0
            slot_of[di] = rank
        return TieredCohortBatch(tuple(tiers), slot_of, layout)

    assert pad_to is not None, "pad_to is required without a layout"
    packed = capacity is not None
    rows = capacity if packed else len(ds.y_dev)
    assert len(device_ids) <= rows, "more participants than cohort slots"
    sample_shape = ds.x_dev[0].shape[1:]
    label_shape = ds.y_dev[0].shape[1:]
    x = np.zeros((rows, pad_to) + sample_shape, ds.x_dev[0].dtype)
    y = np.zeros((rows, pad_to) + label_shape, ds.y_dev[0].dtype)
    mask = np.zeros((rows, pad_to), np.float32)
    for slot, n in enumerate(device_ids):
        xb, yb = sample_batch(rng, ds, n, int(batch_sizes[n]))
        b = len(yb)
        row = slot if packed else n
        x[row, :b] = xb
        y[row, :b] = yb
        mask[row, :b] = 1.0
    return CohortBatch(x, y, mask)


# ---------------------------------------------------------------------------
# the traced data plane: counter-based draws + device-resident shard stacks
# ---------------------------------------------------------------------------


def traced_batch_indices(data_key, t, dev, pool_len, width: int, l_max: int):
    """(width,) sample indices for device ``dev`` at round ``t`` — the
    traced twin of :func:`sample_batch`'s without-replacement draw.

    The draw is *counter-based*: the key folds in the absolute round index
    and the device id, so any consumer — the eager host oracle
    (:func:`sample_cohort_batch_traced`), the fused cohort scan
    (``repro.fl.cohort.train_scan_traced``) and its sharded twin — derives
    bit-identical indices with no stream state to thread. ``u`` weights the
    ``l_max`` padded pool positions, invalid rows (``>= pool_len``) are
    pushed to ``+inf``, and the ``width`` smallest in ascending order are
    the draw — so a wider slot's draw extends a narrower one's
    (prefix-consistency across tier widths).

    The selection is ``lax.top_k(-u, width)``, not a full
    ``argsort(u)[:width]``: both order ascending-by-``u`` with ties broken
    by lower index (XLA top_k's documented tie rule == stable argsort), so
    the indices are identical — but the partial selection is ~10x cheaper
    inside the fused train scan, where it runs once per slot per round.
    """
    k = jax.random.fold_in(jax.random.fold_in(data_key, t), dev)
    u = jax.random.uniform(k, (l_max,))
    u = jnp.where(jnp.arange(l_max) < pool_len, u, jnp.inf)
    _, idx = jax.lax.top_k(-u, width)
    return idx


def device_resident_stacks(ds: FLDataset):
    """Pad every device's private shard into one device-resident stack.

    Returns ``(x_all (N, L_max, *feat), y_all (N, L_max, *lab),
    pool_lens (N,) int32)`` with zero padding past each device's shard —
    the arrays the traced data plane gathers training batches from inside
    the fused scan (padding rows are only ever gathered masked-out).
    """
    pool = np.array([len(y) for y in ds.y_dev], np.int32)
    l_max = int(pool.max())
    n = len(ds.y_dev)
    x_all = np.zeros((n, l_max) + ds.x_dev[0].shape[1:], ds.x_dev[0].dtype)
    y_all = np.zeros((n, l_max) + ds.y_dev[0].shape[1:], ds.y_dev[0].dtype)
    for i, (xd, yd) in enumerate(zip(ds.x_dev, ds.y_dev)):
        x_all[i, :len(yd)] = xd
        y_all[i, :len(yd)] = yd
    return x_all, y_all, pool


def sample_cohort_batch_traced(data_key, t: int, ds: FLDataset, device_ids,
                               batch_sizes: np.ndarray,
                               layout: CohortLayout) -> TieredCohortBatch:
    """The traced data plane's host oracle: :func:`sample_cohort_batch`'s
    tiered packing with every draw taken from the counter-based jax stream
    (:func:`traced_batch_indices`) instead of the numpy generator.

    Consumes NO host RNG — draws are a pure function of (data_key, round,
    device) — so the stepwise loop under ``Scenario.data_plane="traced"``
    stays bit-identical to the fused scan's in-program gathers: identical
    indices into identical shards give byte-identical valid rows (masked
    rows differ only in padding content, which the masked loss zeroes).
    """
    device_ids = [int(n) for n in device_ids]
    assert len(device_ids) <= layout.n_slots, \
        "more participants than cohort slots"
    l_max = max(len(y) for y in ds.y_dev)
    pools = np.array([len(ds.y_dev[n]) for n in device_ids], dtype=int)
    lens = np.minimum(np.asarray(batch_sizes)[device_ids], pools) \
        if device_ids else np.zeros(0, dtype=int)
    sample_shape = ds.x_dev[0].shape[1:]
    label_shape = ds.y_dev[0].shape[1:]
    tiers = [CohortBatch(
        np.zeros((s, w) + sample_shape, ds.x_dev[0].dtype),
        np.zeros((s, w) + label_shape, ds.y_dev[0].dtype),
        np.zeros((s, w), np.float32))
        for s, w in zip(layout.tier_slots, layout.tier_widths)]
    slot_of = np.empty(len(device_ids), dtype=int)
    for rank, di in enumerate(np.argsort(-lens, kind="stable")):
        k, row = layout.locate(rank)
        n, b = device_ids[di], int(lens[di])
        assert b <= layout.tier_widths[k], (b, layout.tier_widths[k])
        idx = np.asarray(traced_batch_indices(
            data_key, t, n, int(pools[di]), b, l_max))
        tiers[k].x[row, :b] = ds.x_dev[n][idx]
        tiers[k].y[row, :b] = ds.y_dev[n][idx]
        tiers[k].mask[row, :b] = 1.0
        slot_of[di] = rank
    return TieredCohortBatch(tuple(tiers), slot_of, layout)
