"""Churn-aware buffered asynchronous aggregation: the ``"async"`` engine.

Synchronous FedAvg rounds are hostage to their slowest participant: one
straggling device stalls the whole cohort, and a churned device silently
shrinks it. :class:`AsyncCohortEngine` decouples dispatch from aggregation
the FedBuff way — scheduled gateways still train through the *same* fused
cohort program as :class:`~repro.fl.sim.CohortEngine`, but their shop-floor
models travel independently to the server and land in a bounded staleness
buffer. Once ``Scenario.buffer_k`` updates have arrived the server
aggregates them with staleness-discounted FedAvg weights
``d_tilde * (1 + tau)^(-staleness_alpha)`` (``tau`` = how many aggregations
happened since the update was dispatched) and advances the global model;
everything still in flight keeps flying across round boundaries.

Time is simulated: "now" is ``Simulation.delay_sum``, a gateway's update
arrives ``gw_delay[m] * (1 + max straggle factor)`` after dispatch, and a
round's realized delay is only the time the server actually waited for its
aggregation event — so a heavy straggler tail delays *one update*, not the
fleet. Faults (churn / mid-round dropout / stragglers, drawn per round from
the network RNG stream — see ``repro.fl.faults``) zero individual devices
out of their gateway's shop-floor average via the completion-mask trick
(``repro.fl.data.zero_slot_rows``): exact-zero loss, exact-zero gradients,
zero FedAvg weight, unchanged compiled shapes.

Two contracts anchor the subsystem:

* **Degenerate parity** — with every fault axis 0 and ``buffer_k=None``
  (the barrier sentinel: drain the round's whole dispatched cohort, then
  flush), the engine replays :class:`~repro.fl.sim.CohortEngine` exactly —
  same RNG streams, same queue trajectory, params equal to the fused
  round's two-tier FedAvg up to float re-association.
* **Realized feedback** — the Lyapunov virtual queues are driven by which
  updates actually *landed* (``lyapunov.update_queues_realized``), not by
  what the scheduler hoped for, so DDSRA re-prioritizes unreliable
  gateways automatically.
"""
from __future__ import annotations

import dataclasses
import heapq
import pathlib
from typing import Any, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint import store
from repro.core.ddsra import RoundDecision
from repro.fl import cohort as cohort_lib
from repro.fl.data import zero_slot_rows
from repro.fl.faults import draw_round_faults
from repro.fl.sim import CohortEngine, RoundOutcome, register_engine


@dataclasses.dataclass
class BufferedUpdate:
    """One gateway's shop-floor model in flight to (or parked at) the server.

    ``version`` is the aggregation counter at dispatch time; staleness at
    aggregation is the server's counter minus it. ``arrival`` is simulated
    server-clock time; ``seq`` breaks arrival ties deterministically (heap
    order must not depend on pytree identity). ``weight`` is the surviving
    sample mass (sum of ``d_tilde`` over devices that actually contributed
    to ``model``).
    """
    gateway: int
    version: int
    arrival: float
    seq: int
    weight: float
    model: Any = dataclasses.field(repr=False, default=None)


@register_engine("async")
class AsyncCohortEngine(CohortEngine):
    """Buffered asynchronous aggregation over the fused cohort round.

    Subclasses :class:`~repro.fl.sim.CohortEngine` for everything compiled —
    layout, packing, the fused round, stats estimation — and overrides only
    *when updates meet the global model*. See the module docstring for the
    semantics and the parity/feedback contracts.
    """

    supports_faults = True
    supports_fused = False   # buffered aggregation is stateful across rounds

    def __init__(self):
        # (arrival, seq, BufferedUpdate) min-heap: dispatched, not yet landed
        self._pending: List = []
        self._buffer: List[BufferedUpdate] = []   # landed, not yet aggregated
        self._version = 0                         # completed aggregations
        self._seq = 0                             # dispatch counter (ties)

    def fused_train(self, sim, params, losses0, xs, ys, masks, ls, ws, gws,
                    trained):
        """Refuse the fused scan path (inherited from CohortEngine): the
        buffered aggregation's cross-round state — the in-flight heap,
        staleness buffer and realized-arrival clock — cannot be carried
        through a synchronous per-round scan; a fused run would silently
        replay barrier semantics and falsify the staleness telemetry."""
        raise NotImplementedError(
            "engine 'async' has no fused scan path (buffered aggregation "
            "is stateful across rounds); use Simulation.rounds()")

    def reset(self, sim) -> None:
        """Drop every in-flight and parked update and rewind the counters.

        ``Simulation.restart()`` (hence ``run()`` and the fair-sweep
        ``reset()``) rewinds the simulated clock to 0; an update dispatched
        under the old clock carries a stale arrival time and a stale
        version, so letting it land would aggregate a previous run's models
        into this one and corrupt both params and staleness telemetry."""
        self._pending = []
        self._buffer = []
        self._version = 0
        self._seq = 0

    # -- the round -------------------------------------------------------

    def run_round(self, sim, dec: RoundDecision, trained: List[int],
                  l_n: np.ndarray, gw_delay: Dict[int, float],
                  boundary: bool = False) -> RoundOutcome:
        """Dispatch the scheduled cohort, land due arrivals, maybe aggregate.

        One simulated round: draw this round's faults, train the surviving
        cohort through the fused program, push each gateway's shop-floor
        model onto the in-flight heap with its realized arrival time, then
        pop arrivals in time order until the buffer holds ``buffer_k``
        updates (or, under the ``buffer_k=None`` barrier, until the round's
        own cohort has fully landed) and aggregate. The realized
        participation indicator covers exactly the gateways whose updates
        were aggregated this round, plus scheduled-but-infeasible gateways
        (which keep their scheduled queue credit — the oracle contract).
        """
        sc = sim.scenario
        now = float(sim.delay_sum)
        faults = draw_round_faults(sim.net.rng, sim.faults,
                                   sim.net.cfg.n_devices)

        landed_gw = np.zeros(sim.net.cfg.n_gateways, bool)
        boundary_rms = None
        dropped = lost = stragglers = 0
        if trained:
            boundary_rms, dropped, lost, stragglers = self._dispatch(
                sim, trained, l_n, gw_delay, faults, now, boundary)

        agg_delay, aggregated, staleness, discarded = self._land_and_aggregate(
            sim, barrier=sc.buffer_k is None, buffer_k=sc.buffer_k, now=now)
        for upd in aggregated:
            landed_gw[upd.gateway] = True

        # scheduled-but-infeasible gateways keep their scheduled credit: the
        # policy already charged their queues, and no update of theirs can
        # ever land, so realized participation mirrors the schedule there.
        realized = landed_gw | (dec.selected & ~np.isin(
            np.arange(sim.net.cfg.n_gateways), list(gw_delay)))
        return RoundOutcome(
            delay=agg_delay, boundary_rms=boundary_rms, realized=realized,
            aggregations=1 if aggregated else 0,
            staleness_mean=float(np.mean(staleness)) if staleness else 0.0,
            staleness_max=int(max(staleness)) if staleness else 0,
            stale_discarded=discarded, dropped_devices=dropped,
            lost_devices=lost, straggler_devices=stragglers,
            buffer_fill=len(self._buffer), inflight=len(self._pending))

    def _dispatch(self, sim, trained: List[int], l_n: np.ndarray,
                  gw_delay: Dict[int, float], faults, now: float,
                  boundary: bool):
        """Train the surviving cohort and push per-gateway updates in flight.

        Churned devices are zeroed out of the batch entirely (no compute,
        completion-mask trick); mid-round-lost devices train but their
        slot weight is zeroed so nothing of theirs aggregates. A gateway
        with no surviving contributor dispatches nothing.
        """
        device_ids, batch, layout, l_slot, w_slot, slot_gw = \
            self._pack_round(sim, trained, l_n)
        dead_slots = []
        for di, n in enumerate(device_ids):
            if faults.dropped[n] or faults.lost[n]:
                s = int(batch.slot_of[di])
                w_slot[s] = 0.0
                if faults.dropped[n]:
                    dead_slots.append(s)
        batch = zero_slot_rows(batch, dead_slots)

        _, gw_loss, gw_count, _, bnd, gw_models = self._fused_round(
            sim, sim.params, batch, l_slot, w_slot, slot_gw,
            with_boundary=boundary, with_gateway_models=True)
        sim.padding_stats["real_samples"] += float(
            sum(t.mask.sum() for t in batch.tiers))
        sim.padding_stats["padded_samples"] += float(layout.padded_samples)

        gw_loss, gw_count = np.asarray(gw_loss), np.asarray(gw_count)
        dropped = lost = stragglers = 0
        for m in trained:
            devs = [d.idx for d in sim.gateways[m].devices]
            dropped += int(np.sum(faults.dropped[devs]))
            lost += int(np.sum(faults.lost[devs]))
            surviving = [n for n in devs
                         if not (faults.dropped[n] or faults.lost[n])]
            if gw_count[m] > 0:      # someone computed: the loss is real
                sim.losses[m] = float(gw_loss[m])
            if not surviving:
                continue             # nothing of this gateway ever lands
            straggle = float(np.max(faults.straggle[surviving]))
            stragglers += int(np.sum(faults.straggle[surviving] > 0.0))
            self._pending_push(BufferedUpdate(
                gateway=m, version=self._version,
                arrival=now + gw_delay[m] * (1.0 + straggle), seq=self._seq,
                weight=float(np.sum(sim.d_tilde[surviving])),
                model=jax.tree.map(lambda x, m_=m: x[m_], gw_models)))

        if boundary:
            rms = np.zeros(sim.net.cfg.n_devices)
            rms[device_ids] = np.asarray(bnd)[batch.slot_of]
            return rms, dropped, lost, stragglers
        return None, dropped, lost, stragglers

    def _pending_push(self, upd: BufferedUpdate) -> None:
        heapq.heappush(self._pending, (upd.arrival, upd.seq, upd))
        self._seq += 1

    def _land_and_aggregate(self, sim, *, barrier: bool,
                            buffer_k: Optional[int], now: float):
        """Pop arrivals in time order, fill the buffer, aggregate at most
        one event, and return (delay, aggregated, staleness, discarded).

        Under the barrier sentinel the round's *entire* in-flight set is
        drained and flushed (synchronous semantics in buffered form: the
        server waits for the slowest arrival). Under ``buffer_k`` the
        server waits only until the buffer reaches K, aggregates, and
        leaves the rest in flight; a round whose buffer never fills costs
        zero realized delay (dispatch is instantaneous on the server
        clock). Arrivals earlier than ``now`` land free of charge.

        The aggregation time is the max *arrival* over the whole aggregated
        batch (clamped to ``now``) — arrivals are retained on each
        :class:`BufferedUpdate` precisely so that an update parked in the
        buffer across rounds (a heavy straggler landing into an under-full
        buffer) still charges its full realized delay when an aggregation
        finally consumes it, instead of only the arrivals popped this round.
        """
        if barrier:
            while self._pending:
                _, _, upd = heapq.heappop(self._pending)
                self._buffer.append(upd)
            if not self._buffer:
                return 0.0, [], [], 0
        else:
            while self._pending and len(self._buffer) < buffer_k:
                _, _, upd = heapq.heappop(self._pending)
                self._buffer.append(upd)
            if len(self._buffer) < buffer_k:
                return 0.0, [], [], 0       # keep waiting across rounds

        batch, self._buffer = self._buffer, []
        t_end = max([now] + [u.arrival for u in batch])
        max_stale = sim.scenario.max_staleness
        fresh = [u for u in batch
                 if max_stale is None
                 or (self._version - u.version) <= max_stale]
        discarded = len(batch) - len(fresh)
        if not fresh:
            return t_end - now, [], [], discarded
        staleness = [self._version - u.version for u in fresh]
        weights = [u.weight * (1.0 + tau) ** (-sim.scenario.staleness_alpha)
                   for u, tau in zip(fresh, staleness)]
        sim.params = cohort_lib.buffer_fedavg([u.model for u in fresh],
                                              weights)
        self._version += 1
        return t_end - now, fresh, staleness, discarded

    # -- policy/telemetry hooks -----------------------------------------

    def inflight_counts(self, sim) -> Optional[np.ndarray]:
        """(M,) dispatched-but-not-landed update counts per gateway."""
        counts = np.zeros(sim.net.cfg.n_gateways, int)
        for _, _, upd in self._pending:
            counts[upd.gateway] += 1
        return counts

    # -- checkpointing ---------------------------------------------------

    def state_dict(self, sim):
        """Serialize the heap, buffer and counters for ``Simulation.save``.

        Entries are flattened in (arrival, seq) order — the exact pop order
        — so a resumed heap replays identically; models travel as one
        list-valued pytree in the ``engine_*`` side-car file.
        """
        pending = sorted(self._pending)
        ups = [u for _, _, u in pending] + list(self._buffer)
        meta = {
            "version": self._version, "seq": self._seq,
            "n_pending": len(pending),
            "updates": [{"gateway": u.gateway, "version": u.version,
                         "arrival": u.arrival, "seq": u.seq,
                         "weight": u.weight} for u in ups],
        }
        return meta, {"models": [u.model for u in ups]}

    def load_state_dict(self, sim, meta: dict, path, step: int) -> None:
        """Restore what :meth:`state_dict` captured (inverse order)."""
        self._version = meta["version"]
        self._seq = meta["seq"]
        ups = meta["updates"]
        models = []
        if ups:
            like = {"models": [sim.params] * len(ups)}
            models = store.load_pytree(
                pathlib.Path(path) / f"engine_{step:08d}.npz", like)["models"]
        restored = [BufferedUpdate(gateway=d["gateway"], version=d["version"],
                                   arrival=d["arrival"], seq=d["seq"],
                                   weight=d["weight"], model=mdl)
                    for d, mdl in zip(ups, models)]
        n_pend = meta["n_pending"]
        self._pending = [(u.arrival, u.seq, u) for u in restored[:n_pend]]
        heapq.heapify(self._pending)
        self._buffer = list(restored[n_pend:])
