from repro.fl.data import (CohortBatch, FLDataset, make_fl_dataset,
                           sample_batch, sample_cohort_batch)
from repro.fl.sim import (ENGINES, CohortEngine, Engine, FLResult,
                          RoundRecord, Scenario, SequentialEngine, Simulation,
                          make_engine, register_engine)
from repro.fl.trainer import FLConfig, FLTrainer

__all__ = ["CohortBatch", "FLDataset", "make_fl_dataset", "sample_batch",
           "sample_cohort_batch", "FLConfig", "FLResult", "FLTrainer",
           "Scenario", "Simulation", "RoundRecord", "Engine", "CohortEngine",
           "SequentialEngine", "ENGINES", "make_engine", "register_engine"]
