from repro.fl.data import (CohortBatch, FLDataset, make_fl_dataset,
                           sample_batch, sample_cohort_batch)
from repro.fl.trainer import FLConfig, FLResult, FLTrainer

__all__ = ["CohortBatch", "FLDataset", "make_fl_dataset", "sample_batch",
           "sample_cohort_batch", "FLConfig", "FLResult", "FLTrainer"]
