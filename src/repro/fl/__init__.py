from repro.fl.data import FLDataset, make_fl_dataset, sample_batch
from repro.fl.trainer import FLConfig, FLResult, FLTrainer

__all__ = ["FLDataset", "make_fl_dataset", "sample_batch",
           "FLConfig", "FLResult", "FLTrainer"]
