"""Two-tier split federated learning: data pipeline, engines, simulation.

Public surface (see ``README.md`` in this directory and
``docs/architecture.md`` for the design):

* :class:`Scenario` / :class:`Simulation` — the composable simulation API
  (``repro.fl.sim``).
* Engines — ``CohortEngine`` (one fused XLA program per round),
  ``ShardedCohortEngine`` (the same round ``shard_map``-ed over a
  ``"cohort"`` device mesh), ``AsyncCohortEngine`` (churn-aware buffered
  asynchronous aggregation over the fused round, ``repro.fl.async_engine``),
  ``SequentialEngine`` (seed per-device loop).
* Fault axes — ``FaultModel`` / ``draw_round_faults`` (``repro.fl.faults``):
  churn, mid-round dropout and straggler tails drawn from the network RNG
  stream, honored by the async engine.
* Fused simulation loop — ``RoundTelemetry`` / ``SweepResult``
  (``repro.fl.fused_sim``): the whole simulate → decide → train loop as
  compiled scans behind ``Simulation.fused_rounds()`` /
  ``Simulation.sweep()``.
* Packing contract — ``sample_cohort_batch`` + ``CohortLayout`` /
  ``TieredCohortBatch`` (tiered slot widths) in ``repro.fl.data``.
* ``FLTrainer`` / ``FLConfig`` — deprecated shim over ``Simulation``.
"""
from repro.fl.data import (CohortBatch, CohortLayout, FLDataset,
                           TieredCohortBatch, make_fl_dataset, sample_batch,
                           sample_cohort_batch)
from repro.fl.faults import FaultModel, RoundFaults, draw_round_faults
from repro.fl.sim import (ENGINES, CohortEngine, Engine, FLResult,
                          RoundRecord, Scenario, SequentialEngine, Simulation,
                          make_engine, register_engine)
from repro.fl.async_engine import AsyncCohortEngine
from repro.fl.fused_sim import RoundTelemetry, SweepResult
from repro.fl.shard import ShardedCohortEngine
from repro.fl.trainer import FLConfig, FLTrainer

__all__ = ["CohortBatch", "CohortLayout", "TieredCohortBatch", "FLDataset",
           "make_fl_dataset", "sample_batch", "sample_cohort_batch",
           "FLConfig", "FLResult", "FLTrainer", "Scenario", "Simulation",
           "RoundRecord", "Engine", "CohortEngine", "SequentialEngine",
           "ShardedCohortEngine", "AsyncCohortEngine", "FaultModel",
           "RoundFaults", "draw_round_faults", "RoundTelemetry",
           "SweepResult", "ENGINES", "make_engine", "register_engine"]
