"""The three tiers of the paper's framework as explicit roles.

``Device`` owns a private shard and the bottom layers; ``Gateway`` trains the
offloaded top layers, combines halves and aggregates its shop floor;
``BaseStation`` aggregates globally and runs the scheduler. Heavy numerics
run in jitted JAX (repro.fl.split); these classes own state + data flow.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.fl import split as split_lib
from repro.fl.data import FLDataset, sample_batch
from repro.models.split_model import Params, SplitModel


@dataclasses.dataclass
class Device:
    idx: int
    gateway: int
    d_size: int           # |D_n|
    d_tilde: int          # training batch size

    def local_round(self, model: SplitModel, global_params: Params,
                    ds: FLDataset, l_split: int, k_iters: int, lr: float,
                    rng: np.random.Generator):
        """One device's local training at partition point l (with its
        gateway co-executing the top blocks)."""
        x, y = sample_batch(rng, ds, self.idx, self.d_tilde)
        return split_lib.local_train(model, global_params, x, y, l_split,
                                     k_iters, lr)


@dataclasses.dataclass
class Gateway:
    idx: int
    devices: List[Device]

    def shop_floor_round(self, model: SplitModel, global_params: Params,
                         ds: FLDataset, l_splits: np.ndarray, k_iters: int,
                         lr: float, rng: np.random.Generator):
        """Run all associated devices, combine halves, FedAvg the shop floor."""
        results, weights, losses = [], [], []
        for i, dev in enumerate(self.devices):
            w_n, loss = dev.local_round(model, global_params, ds,
                                        int(l_splits[i]), k_iters, lr, rng)
            results.append(w_n)
            weights.append(dev.d_tilde)
            losses.append(loss)
        combined = fedavg(results, np.asarray(weights, float))
        return combined, float(np.mean(losses)), float(np.sum(weights))


class BaseStation:
    def __init__(self, model: SplitModel, params: Params):
        self.plan = model       # the SplitModel handle (legacy attr name)
        self.params = params

    def aggregate(self, models: List[Params], weights: np.ndarray):
        if models:
            self.params = fedavg(models, np.asarray(weights, float))
        return self.params


@jax.jit
def _fedavg_stacked(stacked: Params, w: jax.Array) -> Params:
    """Weighted average over the leading model axis of a stacked pytree."""
    return jax.tree.map(lambda s: jnp.tensordot(w, s, axes=1), stacked)


def fedavg(models: List[Params], weights: np.ndarray) -> Params:
    """FedAvg over a list of layer-list params.

    Stacks the models and reduces with one jitted tensordot per leaf (the
    seed built a Python ``sum`` of scaled leaves, one XLA op per model per
    leaf, retraced on every call).
    """
    w = jnp.asarray(weights / weights.sum(), jnp.float32)
    stacked = jax.tree.map(lambda *leaves: jnp.stack(leaves), *models)
    return _fedavg_stacked(stacked, w)
