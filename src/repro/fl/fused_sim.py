"""Whole-simulation fusion: the round loop as compiled scans.

The stepwise :meth:`repro.fl.sim.Simulation.rounds` loop crosses the host
every round — repackage the jitted DDSRA solve into a
:class:`~repro.core.ddsra.RoundDecision`, resolve it in Python, launch one
fused training program, sync the loss. This module runs the same
simulate → decide → train trajectory as (up to) two compiled programs plus
one host replay pass:

* **Fused decide** — for traced policies the whole decide trajectory is
  ONE program: ``lax.scan`` of the traced round over the stacked channel
  states, resolving each round into the pytree-typed
  :class:`~repro.core.ddsra_jax.RoundDecisionT` *inside* the scan.
  ``ddsra_jax`` scans the full Algorithm 1 solve
  (:meth:`repro.core.ddsra_jax.DDSRAPlan.decide_scan`); the
  fixed-resource ``round_robin``/``random`` baselines scan the
  feasibility/delay evaluation of ``repro.core.baseline_jax`` with their
  gateway picks fed in as data. Remaining host policies (the numpy
  oracle, loss/delay-driven) decide via a host loop instead — still
  exact, just not fused.
* **Batch replay** — :meth:`CohortEngine._pack_round` runs per round on the
  host, consuming ``sim.rng`` with exactly the draws the stepwise loop
  would make (the packing contract), so the fused path is RNG-bit-identical
  to stepwise. The packed per-round tensors stack into per-tier arrays
  with a leading round axis.
* **Fused train** — ONE program scans the fused cohort round over all
  rounds (``repro.fl.cohort.train_scan``; the sharded engine's twin wraps
  the scan in ``shard_map``), threading (params, losses) as the carry and
  the stacked decision tensors straight from the decide scan. The
  precision contract survives inside the pipeline: the decide program runs
  x64 (``jax.experimental.enable_x64``), the train program f32/bf16.

Why decide and train can be phase-separated at all: every fusable policy's
decisions depend only on channel draws and the queue recursion — never on
training outputs. The one feedback-coupled policy (``loss_driven``,
``reads_losses = True``) is refused. Channel streams stay exact because
states are pre-drawn host-side from the same ``net.rng`` before the batch
replay touches ``sim.rng`` — two independent generators, each consumed in
stepwise order.

Telemetry crosses back to the host once, after the scans, as a stacked
:class:`RoundTelemetry` pytree (one leaf per :class:`RoundRecord` field,
leading round axis) and is streamed into the familiar per-round records by
:meth:`RoundTelemetry.to_records`. Parity with the stepwise loop —
bit-identical queues and RNG streams, params at 1e-5 — is pinned across
{cohort, sharded} x {ddsra_jax, round_robin} x {f32, bf16} in
``tests/test_fused_sim.py``.
"""
from __future__ import annotations

import dataclasses
from typing import List, NamedTuple, Optional, Sequence

import jax
import numpy as np

from repro.core.network import ChannelState, stack_states
from repro.core.schedulers import RoundContext
from repro.fl.sim import (RoundRecord, Simulation, resolve_decision)


class RoundTelemetry(NamedTuple):
    """Stacked per-round telemetry as a pytree: one leaf per (array-like)
    :class:`~repro.fl.sim.RoundRecord` field, every leaf carrying a leading
    ``(rounds,)`` axis.

    This is the side-channel the fused loop streams telemetry through:
    scan outputs land here as stacked device arrays, cross the host
    boundary once, and fan back out into per-round records via
    :meth:`to_records`. ``boundary_rms`` and ``accuracy`` are not leaves —
    they are optional per-round host artifacts (``None`` inside the fused
    loop) and would force ragged shapes.

    ``flatten -> unflatten`` is the identity (NamedTuples are JAX pytrees)
    and :meth:`from_records` / :meth:`to_records` round-trip exactly —
    both pinned by the Hypothesis property test in
    ``tests/test_fused_sim.py``.
    """
    t: np.ndarray                  # (T,) int
    selected: np.ndarray           # (T, M) bool
    trained: np.ndarray            # (T, M) bool (records carry id lists)
    l_n: np.ndarray                # (T, N) int
    delay: np.ndarray              # (T,) float64
    cum_delay: np.ndarray          # (T,) float64
    queues: np.ndarray             # (T, M) float64
    losses: np.ndarray             # (T, M) float64
    failures: np.ndarray           # (T,) int
    aggregations: np.ndarray       # (T,) int
    staleness_mean: np.ndarray     # (T,) float64 (0.0 when no aggregation)
    staleness_max: np.ndarray      # (T,) int
    stale_discarded: np.ndarray    # (T,) int
    dropped_devices: np.ndarray    # (T,) int
    lost_devices: np.ndarray       # (T,) int
    straggler_devices: np.ndarray  # (T,) int
    buffer_fill: np.ndarray        # (T,) int
    inflight: np.ndarray           # (T,) int

    @classmethod
    def from_records(cls, records: Sequence[RoundRecord]
                     ) -> "RoundTelemetry":
        """Stack per-round records into one pytree (trained id lists become
        the (T, M) bool mask; ``boundary_rms``/``accuracy`` are dropped)."""
        m_gw = len(records[0].queues)
        trained = np.zeros((len(records), m_gw), bool)
        for i, r in enumerate(records):
            trained[i, list(r.trained)] = True
        pick = {
            "t": (int, None), "selected": (bool, None),
            "l_n": (int, None), "delay": (np.float64, None),
            "cum_delay": (np.float64, None), "queues": (np.float64, None),
            "losses": (np.float64, None), "failures": (int, None),
            "aggregations": (int, None),
            "staleness_mean": (np.float64, None), "staleness_max": (int, None),
            "stale_discarded": (int, None), "dropped_devices": (int, None),
            "lost_devices": (int, None), "straggler_devices": (int, None),
            "buffer_fill": (int, None), "inflight": (int, None)}
        cols = {k: np.asarray([getattr(r, k) for r in records], dtype=dt)
                for k, (dt, _) in pick.items()}
        return cls(trained=trained, **cols)

    def to_records(self) -> List[RoundRecord]:
        """Fan the stacked leaves back out into per-round records (host
        streaming after the scan). Every value is concretized to host
        numpy/Python — a traced leaf here would be a leak, which the
        property test rejects."""
        out = []
        for i in range(len(np.asarray(self.t))):
            out.append(RoundRecord(
                t=int(self.t[i]),
                selected=np.asarray(self.selected[i]).copy(),
                trained=[int(m) for m in np.where(self.trained[i])[0]],
                l_n=np.asarray(self.l_n[i]).copy(),
                delay=float(self.delay[i]),
                cum_delay=float(self.cum_delay[i]),
                queues=np.asarray(self.queues[i], np.float64).copy(),
                losses=np.asarray(self.losses[i], np.float64).copy(),
                failures=int(self.failures[i]),
                aggregations=int(self.aggregations[i]),
                staleness_mean=float(self.staleness_mean[i]),
                staleness_max=int(self.staleness_max[i]),
                stale_discarded=int(self.stale_discarded[i]),
                dropped_devices=int(self.dropped_devices[i]),
                lost_devices=int(self.lost_devices[i]),
                straggler_devices=int(self.straggler_devices[i]),
                buffer_fill=int(self.buffer_fill[i]),
                inflight=int(self.inflight[i])))
        return out


@dataclasses.dataclass
class SweepResult:
    """Outcome of a seeds x V scheduling sweep run as one compiled program
    (:meth:`repro.fl.sim.Simulation.sweep`). Row (s, v) matches a stepwise
    ``reset(seeds[s])`` run of the same scenario at ``v_values[v]``
    row-for-row: ``taus[s, v, t]`` is round t's realized delay,
    ``selected``/``queues`` its participation and post-update queue state
    (the seed-determinism test pins this, cross-process)."""
    seeds: List[int]
    v_values: List[float]
    taus: np.ndarray       # (S, V, T)
    selected: np.ndarray   # (S, V, T, M) bool
    queues: np.ndarray     # (S, V, T, M)


# ---------------------------------------------------------------------------
# phase A: decide
# ---------------------------------------------------------------------------


def _check_fusable(sim: Simulation, policy) -> None:
    if getattr(policy, "reads_losses", False):
        raise ValueError(
            f"policy {getattr(policy, 'name', policy)!r} reads training "
            "losses (reads_losses=True): decide and train cannot be "
            "phase-separated; use Simulation.rounds()")
    if not getattr(sim.engine, "supports_fused", False):
        # surface the engine's own refusal (async explains its buffer state)
        sim.engine.fused_train(sim, None, None, None, None, None, None,
                               None, None, None)


def _decide(sim: Simulation, policy, states: List[ChannelState], t0: int):
    """Run the decide trajectory over pre-drawn channel states.

    Traced policies (``traced_decide``) go through
    :meth:`DDSRAPlan.decide_scan` — one compiled program for all rounds;
    everything else replays the stepwise host loop (same ``schedule(ctx)``
    calls, same queue handoff, so queues/policy-RNG stay bit-identical).
    Returns host numpy arrays: (selected (T, M), trained (T, M),
    l_n (T, N), delay (T,), failures (T,), queues (T, M)).
    """
    sc = sim.scenario
    n_dev = sim.net.cfg.n_devices
    if getattr(policy, "traced_decide", False):
        plan = policy.plan_for(sim.workload, sim.net)
        kwargs = {}
        if hasattr(policy, "traced_chosen"):
            # fixed-resource baselines: gateway picks are data — drawn /
            # computed host-side (preserving the stepwise policy-RNG
            # stream) and fed to the scan as its round axis
            kwargs["chosen"] = policy.traced_chosen(t0, len(states),
                                                    sim.net)
        dec = plan.decide_scan(stack_states(states), sim.queues,
                               sim.gamma, sc.v, **kwargs)
        return (np.asarray(dec.selected), np.asarray(dec.trained),
                np.asarray(dec.l_dev).astype(int),
                np.asarray(dec.delay, np.float64),
                np.asarray(dec.failures).astype(int),
                np.asarray(dec.queues, np.float64))

    m_gw = sim.net.cfg.n_gateways
    T = len(states)
    selected = np.zeros((T, m_gw), bool)
    trained_mask = np.zeros((T, m_gw), bool)
    l_rounds = np.zeros((T, n_dev), int)
    delay = np.zeros(T)
    failures = np.zeros(T, int)
    queues_out = np.zeros((T, m_gw))
    queues = sim.queues
    for k, st in enumerate(states):
        ctx = RoundContext(t0 + k, sim.workload, sim.net, st, queues,
                           sim.gamma, sc.v, losses=sim.losses.copy(),
                           inflight=None)
        dec = policy.schedule(ctx)
        queues = dec.queues
        trained, l_n, gw_delay, fails = resolve_decision(
            dec, sim.gateways, n_dev)
        selected[k] = dec.selected
        trained_mask[k, trained] = True
        l_rounds[k] = l_n
        delay[k] = max(gw_delay.values(), default=0.0)
        failures[k] = fails
        queues_out[k] = queues
    return selected, trained_mask, l_rounds, delay, failures, queues_out


# ---------------------------------------------------------------------------
# phase B: host batch replay (exact RNG parity with the stepwise loop)
# ---------------------------------------------------------------------------


def _replay_batches(sim: Simulation, trained_mask: np.ndarray,
                    l_rounds: np.ndarray):
    """Pack every round through the engine's ``_pack_round`` — consuming
    ``sim.rng`` with exactly the stepwise draws — and stack the packed
    tensors into per-tier arrays with a leading round axis.

    Returns per-tier tuples (xs, ys, masks, ls, ws, gws): tier k carries
    ``(T, S_k, ...)`` arrays, ready for the fused training scan. Rounds
    where nobody trains still pack (zero draws, zero masks/weights), so
    shapes stay fixed. Each packed tensor is written straight into row k
    of a preallocated stacked buffer — the replay pays exactly one copy
    per tensor, the same as the stepwise loop's per-round conversion.
    """
    T = trained_mask.shape[0]
    layout0 = None
    stacked = None
    for k in range(T):
        trained = [int(m) for m in np.where(trained_mask[k])[0]]
        _, batch, layout, l_slot, w_slot, slot_gw = \
            sim.engine._pack_round(sim, trained, l_rounds[k])
        if layout0 is None:
            layout0 = layout
        elif layout is not layout0:
            raise RuntimeError(
                "cohort layout changed across rounds (capacity fallback); "
                "the fused scan needs fixed shapes — use "
                "Simulation.rounds()")
        if trained:  # stepwise accounting only touches training rounds
            sim.padding_stats["real_samples"] += float(
                sum(t.mask.sum() for t in batch.tiers))
            sim.padding_stats["padded_samples"] += float(
                layout.padded_samples)
        sizes = tuple(t.x.shape[0] for t in batch.tiers)
        if stacked is None:  # round 0 fixes every tier's shape
            stacked = (
                tuple(np.empty((T,) + t.x.shape, t.x.dtype)
                      for t in batch.tiers),
                tuple(np.empty((T,) + t.y.shape, t.y.dtype)
                      for t in batch.tiers),
                tuple(np.empty((T,) + t.mask.shape, np.float32)
                      for t in batch.tiers),
                tuple(np.empty((T, s), np.int32) for s in sizes),
                tuple(np.empty((T, s), np.float32) for s in sizes),
                tuple(np.empty((T, s) + np.shape(slot_gw)[1:], np.float32)
                      for s in sizes))
        xs, ys, masks, ls, ws, gws = stacked
        off = 0
        for i, t in enumerate(batch.tiers):
            xs[i][k] = t.x
            ys[i][k] = t.y
            masks[i][k] = t.mask
            ls[i][k] = l_slot[off:off + sizes[i]]
            ws[i][k] = w_slot[off:off + sizes[i]]
            gws[i][k] = slot_gw[off:off + sizes[i]]
            off += sizes[i]
    return stacked


# ---------------------------------------------------------------------------
# the fused round loop
# ---------------------------------------------------------------------------


def fused_rounds(sim: Simulation, policy, *,
                 rounds: Optional[int] = None) -> List[RoundRecord]:
    """Advance ``sim`` by (up to) ``rounds`` rounds through the fused
    pipeline (decide scan / host decide -> batch replay -> train scan) and
    return the same :class:`RoundRecord` stream the stepwise loop yields.

    End state (params, losses, queues, t, delay_sum, both RNG streams)
    matches stepwise exactly, so fused and stepwise blocks interleave — a
    checkpoint saved after a fused block resumes into either path.
    """
    sc = sim.scenario
    t0 = sim.t
    T = sc.rounds - t0 if rounds is None else min(rounds, sc.rounds - t0)
    if T <= 0:
        return []
    _check_fusable(sim, policy)

    # phase A: channel states from the SAME numpy stream as stepwise
    states = [sim.net.draw() for _ in range(T)]
    selected, trained_mask, l_rounds, delay, failures, queues = _decide(
        sim, policy, states, t0)

    # phase B: exact-RNG batch replay + stacking
    xs, ys, masks, ls, ws, gws = _replay_batches(sim, trained_mask,
                                                 l_rounds)

    # phase C: one training program for all rounds
    params, losses, loss_hist = sim.engine.fused_train(
        sim, sim.params, sim.losses, xs, ys, masks, ls, ws, gws,
        trained_mask)

    cum = sim.delay_sum + np.cumsum(np.asarray(delay, np.float64))
    tel = RoundTelemetry(
        t=t0 + np.arange(T),
        selected=np.asarray(selected, bool),
        trained=np.asarray(trained_mask, bool),
        l_n=np.asarray(l_rounds, int),
        delay=np.asarray(delay, np.float64),
        cum_delay=cum,
        queues=np.asarray(queues, np.float64),
        losses=np.asarray(loss_hist, np.float64),
        failures=np.asarray(failures, int),
        aggregations=np.asarray(trained_mask.any(axis=1), int),
        staleness_mean=np.zeros(T), staleness_max=np.zeros(T, int),
        stale_discarded=np.zeros(T, int), dropped_devices=np.zeros(T, int),
        lost_devices=np.zeros(T, int), straggler_devices=np.zeros(T, int),
        buffer_fill=np.zeros(T, int), inflight=np.zeros(T, int))
    records = tel.to_records()

    # commit the end state to the Simulation (stepwise-compatible)
    sim.params = params
    sim.losses = np.asarray(losses, np.float64)
    sim.queues = np.asarray(queues[-1], np.float64).copy()
    sim.t = t0 + T
    sim.delay_sum = float(cum[-1])

    # final-round eval only: intermediate accuracies would need param
    # snapshots inside the scan (records keep accuracy=None elsewhere).
    last_t = records[-1].t
    if (last_t + 1) % sc.eval_every == 0 or last_t == sc.rounds - 1:
        records[-1].accuracy = sim.plan.accuracy(
            sim.params, sim.ds.x_test, sim.ds.y_test)
    return records


# ---------------------------------------------------------------------------
# seeds x V sweep
# ---------------------------------------------------------------------------


def _seed_states(sim: Simulation, seed: int, rounds: int
                 ) -> List[ChannelState]:
    """The channel trajectory a stepwise ``reset(seed)`` run would draw,
    without disturbing the live ``sim.net.rng`` stream (the reset(seed)
    fairness contract: scenario seed replays the pristine stream, any
    other seed reseeds it)."""
    if seed == sim.scenario.seed:
        rng = np.random.default_rng()
        rng.bit_generator.state = sim._net_rng_state0
    else:
        rng = np.random.default_rng(seed)
    saved = sim.net.rng
    sim.net.rng = rng
    try:
        return [sim.net.draw() for _ in range(rounds)]
    finally:
        sim.net.rng = saved


def sweep(sim: Simulation, v_values, seeds=None, *,
          rounds: Optional[int] = None) -> SweepResult:
    """Run a seeds x V scheduling sweep as ONE compiled program.

    Resolves the scenario policy, which must be traced-decide
    (``ddsra_jax``); draws each seed's channel trajectory host-side under
    the reset(seed) contract; stacks them (S, T, ...) and hands off to
    :meth:`DDSRAPlan.sweep_states` — vmap(seeds) o vmap(V) o scan(rounds).
    All V lanes of a seed share its channel draws (fair-sweep contract).
    """
    policy = sim._resolve_policy(None)
    if not getattr(policy, "traced_decide", False):
        raise ValueError(
            f"Simulation.sweep() needs a traced-decide policy; scenario "
            f"policy {sim.scenario.policy!r} decides on the host — set "
            "Scenario.policy='ddsra_jax'")
    plan = policy.plan_for(sim.workload, sim.net)
    if not hasattr(plan, "sweep_states"):
        raise ValueError(
            f"policy {sim.scenario.policy!r} has no V-sweep (fixed-resource "
            "baselines ignore V); set Scenario.policy='ddsra_jax'")
    T = sim.scenario.rounds if rounds is None else rounds
    seeds = [sim.scenario.seed] if seeds is None else [int(s) for s in seeds]
    per_seed = [stack_states(_seed_states(sim, s, T)) for s in seeds]
    stacked = jax.tree.map(lambda *a: np.stack(a), *per_seed)
    taus, sel, queues = plan.sweep_states(stacked, sim.gamma,
                                          list(map(float, v_values)))
    return SweepResult(seeds=seeds, v_values=[float(v) for v in v_values],
                       taus=taus, selected=sel, queues=queues)
