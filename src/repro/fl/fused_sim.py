"""Whole-simulation fusion: the round loop as compiled scans.

The stepwise :meth:`repro.fl.sim.Simulation.rounds` loop crosses the host
every round — repackage the jitted DDSRA solve into a
:class:`~repro.core.ddsra.RoundDecision`, resolve it in Python, launch one
fused training program, sync the loss. This module runs the same
simulate → decide → train trajectory as (up to) two compiled programs plus
one host replay pass:

* **Fused decide** — for traced policies the whole decide trajectory is
  ONE program: ``lax.scan`` of the traced round over the stacked channel
  states, resolving each round into the pytree-typed
  :class:`~repro.core.ddsra_jax.RoundDecisionT` *inside* the scan.
  ``ddsra_jax`` scans the full Algorithm 1 solve
  (:meth:`repro.core.ddsra_jax.DDSRAPlan.decide_scan`); the
  fixed-resource ``round_robin``/``random`` baselines scan the
  feasibility/delay evaluation of ``repro.core.baseline_jax`` with their
  gateway picks fed in as data. Remaining host policies (the numpy
  oracle, loss/delay-driven) decide via a host loop instead — still
  exact, just not fused.
* **Batch replay** — :meth:`CohortEngine._pack_round` runs per round on the
  host, consuming ``sim.rng`` with exactly the draws the stepwise loop
  would make (the packing contract), so the fused path is RNG-bit-identical
  to stepwise. The packed per-round tensors stack into per-tier arrays
  with a leading round axis. Under ``Scenario.data_plane="traced"`` this
  phase shrinks to *metadata only* (:func:`_pack_rounds_traced`): batches
  are gathered in-scan from device-resident shard stacks via counter-based
  jax draws (``repro.fl.data.traced_batch_indices``), so no per-round
  sample copies cross the host at all.
* **Fused train** — ONE program scans the fused cohort round over all
  rounds (``repro.fl.cohort.train_scan`` / ``train_scan_traced``; the
  sharded engine's twins wrap the scan in ``shard_map``), threading
  (params, losses) as the carry and the stacked decision tensors straight
  from the decide scan. ``eval_every`` accuracy snapshots run
  ``lax.cond``-gated *inside* the scan and cross back as per-round hit
  counts. The precision contract survives inside the pipeline: the decide
  program runs x64 (``jax.experimental.enable_x64``), the train program
  f32/bf16.

Why decide and train can be phase-separated at all: every fusable policy's
decisions depend only on channel draws and the queue recursion — never on
training outputs. The one feedback-coupled policy (``loss_driven``,
``reads_losses = True``) is refused. Channel streams stay exact because
states are pre-drawn host-side from the same ``net.rng`` before the batch
replay touches ``sim.rng`` — two independent generators, each consumed in
stepwise order.

Telemetry crosses back to the host once, after the scans, as a stacked
:class:`RoundTelemetry` pytree (one leaf per :class:`RoundRecord` field,
leading round axis) and is streamed into the familiar per-round records by
:meth:`RoundTelemetry.to_records`. Parity with the stepwise loop —
bit-identical queues and RNG streams, params at 1e-5 — is pinned across
{cohort, sharded} x {ddsra_jax, round_robin, delay_driven} x {f32, bf16}
x {host, traced} data planes in ``tests/test_fused_sim.py``.
"""
from __future__ import annotations

import dataclasses
from typing import List, NamedTuple, Optional, Sequence

import jax
import numpy as np

from repro.core.network import ChannelState, stack_states
from repro.core.schedulers import RoundContext, make_policy
from repro.fl.sim import (RoundRecord, Simulation, resolve_decision)


class RoundTelemetry(NamedTuple):
    """Stacked per-round telemetry as a pytree: one leaf per (array-like)
    :class:`~repro.fl.sim.RoundRecord` field, every leaf carrying a leading
    ``(rounds,)`` axis.

    This is the side-channel the fused loop streams telemetry through:
    scan outputs land here as stacked device arrays, cross the host
    boundary once, and fan back out into per-round records via
    :meth:`to_records`. ``boundary_rms`` and ``accuracy`` are not leaves —
    they are optional per-round host artifacts (``None`` inside the fused
    loop) and would force ragged shapes.

    ``flatten -> unflatten`` is the identity (NamedTuples are JAX pytrees)
    and :meth:`from_records` / :meth:`to_records` round-trip exactly —
    both pinned by the Hypothesis property test in
    ``tests/test_fused_sim.py``.
    """
    t: np.ndarray                  # (T,) int
    selected: np.ndarray           # (T, M) bool
    trained: np.ndarray            # (T, M) bool (records carry id lists)
    l_n: np.ndarray                # (T, N) int
    delay: np.ndarray              # (T,) float64
    cum_delay: np.ndarray          # (T,) float64
    queues: np.ndarray             # (T, M) float64
    losses: np.ndarray             # (T, M) float64
    failures: np.ndarray           # (T,) int
    aggregations: np.ndarray       # (T,) int
    staleness_mean: np.ndarray     # (T,) float64 (0.0 when no aggregation)
    staleness_max: np.ndarray      # (T,) int
    stale_discarded: np.ndarray    # (T,) int
    dropped_devices: np.ndarray    # (T,) int
    lost_devices: np.ndarray       # (T,) int
    straggler_devices: np.ndarray  # (T,) int
    buffer_fill: np.ndarray        # (T,) int
    inflight: np.ndarray           # (T,) int

    @classmethod
    def from_records(cls, records: Sequence[RoundRecord]
                     ) -> "RoundTelemetry":
        """Stack per-round records into one pytree (trained id lists become
        the (T, M) bool mask; ``boundary_rms``/``accuracy`` are dropped)."""
        m_gw = len(records[0].queues)
        trained = np.zeros((len(records), m_gw), bool)
        for i, r in enumerate(records):
            trained[i, list(r.trained)] = True
        pick = {
            "t": (int, None), "selected": (bool, None),
            "l_n": (int, None), "delay": (np.float64, None),
            "cum_delay": (np.float64, None), "queues": (np.float64, None),
            "losses": (np.float64, None), "failures": (int, None),
            "aggregations": (int, None),
            "staleness_mean": (np.float64, None), "staleness_max": (int, None),
            "stale_discarded": (int, None), "dropped_devices": (int, None),
            "lost_devices": (int, None), "straggler_devices": (int, None),
            "buffer_fill": (int, None), "inflight": (int, None)}
        cols = {k: np.asarray([getattr(r, k) for r in records], dtype=dt)
                for k, (dt, _) in pick.items()}
        return cls(trained=trained, **cols)

    def to_records(self) -> List[RoundRecord]:
        """Fan the stacked leaves back out into per-round records (host
        streaming after the scan). Every value is concretized to host
        numpy/Python — a traced leaf here would be a leak, which the
        property test rejects."""
        out = []
        for i in range(len(np.asarray(self.t))):
            out.append(RoundRecord(
                t=int(self.t[i]),
                selected=np.asarray(self.selected[i]).copy(),
                trained=[int(m) for m in np.where(self.trained[i])[0]],
                l_n=np.asarray(self.l_n[i]).copy(),
                delay=float(self.delay[i]),
                cum_delay=float(self.cum_delay[i]),
                queues=np.asarray(self.queues[i], np.float64).copy(),
                losses=np.asarray(self.losses[i], np.float64).copy(),
                failures=int(self.failures[i]),
                aggregations=int(self.aggregations[i]),
                staleness_mean=float(self.staleness_mean[i]),
                staleness_max=int(self.staleness_max[i]),
                stale_discarded=int(self.stale_discarded[i]),
                dropped_devices=int(self.dropped_devices[i]),
                lost_devices=int(self.lost_devices[i]),
                straggler_devices=int(self.straggler_devices[i]),
                buffer_fill=int(self.buffer_fill[i]),
                inflight=int(self.inflight[i])))
        return out


@dataclasses.dataclass
class SweepResult:
    """Outcome of a scheduling sweep run as one compiled program
    (:meth:`repro.fl.sim.Simulation.sweep`).

    Single-policy (``policies is None``): row (s, v) matches a stepwise
    ``reset(seeds[s])`` run of the same scenario at ``v_values[v]``
    row-for-row — ``taus[s, v, t]`` is round t's realized delay,
    ``selected``/``queues`` its participation and post-update queue state
    (the seed-determinism test pins this, cross-process). Arrays carry
    (S, V, T[, M]) axes.

    Multi-policy (``policies`` a list of traced-decide policy names): the
    whole policies x seeds x V grid ran as ONE program
    (``repro.core.policy_sweep``) and every array gains a leading policy
    axis — (P, S, V, T[, M]); row (p, s, v) matches a stepwise
    ``reset(seeds[s])`` run with ``Scenario.policy=policies[p]`` at
    ``v_values[v]``. Fixed-resource baseline lanes ignore V, so their
    rows repeat across the V axis (the flat curves of Figs. 4-6)."""
    seeds: List[int]
    v_values: List[float]
    taus: np.ndarray       # ([P,] S, V, T)
    selected: np.ndarray   # ([P,] S, V, T, M) bool
    queues: np.ndarray     # ([P,] S, V, T, M)
    policies: Optional[List[str]] = None


# ---------------------------------------------------------------------------
# phase A: decide
# ---------------------------------------------------------------------------


def _check_fusable(sim: Simulation, policy) -> None:
    if getattr(policy, "reads_losses", False):
        raise ValueError(
            f"policy {getattr(policy, 'name', policy)!r} reads training "
            "losses (reads_losses=True): decide and train cannot be "
            "phase-separated; use Simulation.rounds()")
    if not getattr(sim.engine, "supports_fused", False):
        # surface the engine's own refusal (async explains its buffer state)
        sim.engine.fused_train(sim, None, None, None, None, None, None,
                               None, None, None)


def _decide(sim: Simulation, policy, states: List[ChannelState], t0: int):
    """Run the decide trajectory over pre-drawn channel states.

    Traced policies (``traced_decide``) go through
    :meth:`DDSRAPlan.decide_scan` — one compiled program for all rounds;
    everything else replays the stepwise host loop (same ``schedule(ctx)``
    calls, same queue handoff, so queues/policy-RNG stay bit-identical).
    Returns host numpy arrays: (selected (T, M), trained (T, M),
    l_n (T, N), delay (T,), failures (T,), queues (T, M)).
    """
    sc = sim.scenario
    n_dev = sim.net.cfg.n_devices
    if getattr(policy, "traced_decide", False):
        plan = policy.plan_for(sim.workload, sim.net)
        kwargs = {}
        if hasattr(policy, "traced_chosen"):
            # fixed-resource baselines: gateway picks are data — drawn /
            # computed host-side (preserving the stepwise policy-RNG
            # stream) and fed to the scan as its round axis. delay_driven
            # returns None (its pick depends on the round's channel draws)
            # and decide_scan computes the greedy pick in-scan instead.
            chosen = policy.traced_chosen(t0, len(states), sim.net)
            if chosen is not None:
                kwargs["chosen"] = chosen
        dec = plan.decide_scan(stack_states(states), sim.queues,
                               sim.gamma, sc.v, **kwargs)
        return (np.asarray(dec.selected), np.asarray(dec.trained),
                np.asarray(dec.l_dev).astype(int),
                np.asarray(dec.delay, np.float64),
                np.asarray(dec.failures).astype(int),
                np.asarray(dec.queues, np.float64))

    m_gw = sim.net.cfg.n_gateways
    T = len(states)
    selected = np.zeros((T, m_gw), bool)
    trained_mask = np.zeros((T, m_gw), bool)
    l_rounds = np.zeros((T, n_dev), int)
    delay = np.zeros(T)
    failures = np.zeros(T, int)
    queues_out = np.zeros((T, m_gw))
    queues = sim.queues
    for k, st in enumerate(states):
        ctx = RoundContext(t0 + k, sim.workload, sim.net, st, queues,
                           sim.gamma, sc.v, losses=sim.losses.copy(),
                           inflight=None)
        dec = policy.schedule(ctx)
        queues = dec.queues
        trained, l_n, gw_delay, fails = resolve_decision(
            dec, sim.gateways, n_dev)
        selected[k] = dec.selected
        trained_mask[k, trained] = True
        l_rounds[k] = l_n
        delay[k] = max(gw_delay.values(), default=0.0)
        failures[k] = fails
        queues_out[k] = queues
    return selected, trained_mask, l_rounds, delay, failures, queues_out


# ---------------------------------------------------------------------------
# phase B: host batch replay (exact RNG parity with the stepwise loop)
# ---------------------------------------------------------------------------


def _replay_batches(sim: Simulation, trained_mask: np.ndarray,
                    l_rounds: np.ndarray):
    """Pack every round through the engine's ``_pack_round`` — consuming
    ``sim.rng`` with exactly the stepwise draws — and stack the packed
    tensors into per-tier arrays with a leading round axis.

    Returns per-tier tuples (xs, ys, masks, ls, ws, gws): tier k carries
    ``(T, S_k, ...)`` arrays, ready for the fused training scan. Rounds
    where nobody trains still pack (zero draws, zero masks/weights), so
    shapes stay fixed. Each packed tensor is written straight into row k
    of a preallocated stacked buffer — the replay pays exactly one copy
    per tensor, the same as the stepwise loop's per-round conversion.
    """
    T = trained_mask.shape[0]
    layout0 = None
    stacked = None
    for k in range(T):
        trained = [int(m) for m in np.where(trained_mask[k])[0]]
        _, batch, layout, l_slot, w_slot, slot_gw = \
            sim.engine._pack_round(sim, trained, l_rounds[k])
        if layout0 is None:
            layout0 = layout
        elif layout is not layout0:
            raise RuntimeError(
                "cohort layout changed across rounds (capacity fallback); "
                "the fused scan needs fixed shapes — use "
                "Simulation.rounds()")
        if trained:  # stepwise accounting only touches training rounds
            sim.padding_stats["real_samples"] += float(
                sum(t.mask.sum() for t in batch.tiers))
            sim.padding_stats["padded_samples"] += float(
                layout.padded_samples)
        sizes = tuple(t.x.shape[0] for t in batch.tiers)
        if stacked is None:  # round 0 fixes every tier's shape
            stacked = (
                tuple(np.empty((T,) + t.x.shape, t.x.dtype)
                      for t in batch.tiers),
                tuple(np.empty((T,) + t.y.shape, t.y.dtype)
                      for t in batch.tiers),
                tuple(np.empty((T,) + t.mask.shape, np.float32)
                      for t in batch.tiers),
                tuple(np.empty((T, s), np.int32) for s in sizes),
                tuple(np.empty((T, s), np.float32) for s in sizes),
                tuple(np.empty((T, s) + np.shape(slot_gw)[1:], np.float32)
                      for s in sizes))
        xs, ys, masks, ls, ws, gws = stacked
        off = 0
        for i, t in enumerate(batch.tiers):
            xs[i][k] = t.x
            ys[i][k] = t.y
            masks[i][k] = t.mask
            ls[i][k] = l_slot[off:off + sizes[i]]
            ws[i][k] = w_slot[off:off + sizes[i]]
            gws[i][k] = slot_gw[off:off + sizes[i]]
            off += sizes[i]
    return stacked


def _pack_rounds_traced(sim: Simulation, trained_mask: np.ndarray,
                        l_rounds: np.ndarray):
    """The traced data plane's phase B: pack only round *metadata*.

    ``_pack_round_meta`` assigns slots without drawing a single sample —
    the fused scan gathers every batch in-program from the device-resident
    shard stacks via the counter-based draws — so this stacks a few int32/
    float32 per slot per round instead of ``(T, S_k, W_k, ...)`` sample
    buffers (the copy the host data plane pays per round disappears).

    Returns (slot_devs, ls, ws, gws, layout): per-tier tuples of
    ``(T, S_k[, M])`` arrays plus the (fixed) layout.
    """
    T = trained_mask.shape[0]
    layout0 = None
    stacked = None
    for k in range(T):
        trained = [int(m) for m in np.where(trained_mask[k])[0]]
        _, layout, slot_dev, l_slot, w_slot, slot_gw, real = \
            sim.engine._pack_round_meta(sim, trained, l_rounds[k])
        if layout0 is None:
            layout0 = layout
        elif layout is not layout0:
            raise RuntimeError(
                "cohort layout changed across rounds (capacity fallback); "
                "the fused scan needs fixed shapes — use "
                "Simulation.rounds()")
        if trained:  # stepwise accounting only touches training rounds
            sim.padding_stats["real_samples"] += float(real)
            sim.padding_stats["padded_samples"] += float(
                layout.padded_samples)
        sizes = tuple(layout.tier_slots)
        if stacked is None:
            stacked = (
                tuple(np.empty((T, s), np.int32) for s in sizes),
                tuple(np.empty((T, s), np.int32) for s in sizes),
                tuple(np.empty((T, s), np.float32) for s in sizes),
                tuple(np.empty((T, s) + np.shape(slot_gw)[1:], np.float32)
                      for s in sizes))
        sds, ls, ws, gws = stacked
        off = 0
        for i, s in enumerate(sizes):
            sds[i][k] = slot_dev[off:off + s]
            ls[i][k] = l_slot[off:off + s]
            ws[i][k] = w_slot[off:off + s]
            gws[i][k] = slot_gw[off:off + s]
            off += s
    return stacked + (layout0,)


# ---------------------------------------------------------------------------
# the fused round loop
# ---------------------------------------------------------------------------


def fused_rounds(sim: Simulation, policy, *,
                 rounds: Optional[int] = None) -> List[RoundRecord]:
    """Advance ``sim`` by (up to) ``rounds`` rounds through the fused
    pipeline (decide scan / host decide -> batch replay -> train scan) and
    return the same :class:`RoundRecord` stream the stepwise loop yields.

    End state (params, losses, queues, t, delay_sum, both RNG streams)
    matches stepwise exactly, so fused and stepwise blocks interleave — a
    checkpoint saved after a fused block resumes into either path.
    """
    sc = sim.scenario
    t0 = sim.t
    T = sc.rounds - t0 if rounds is None else min(rounds, sc.rounds - t0)
    if T <= 0:
        return []
    _check_fusable(sim, policy)

    # phase A: channel states from the SAME numpy stream as stepwise
    states = [sim.net.draw() for _ in range(T)]
    selected, trained_mask, l_rounds, delay, failures, queues = _decide(
        sim, policy, states, t0)

    # the stepwise eval_every schedule, evaluated lax.cond-gated *inside*
    # the train scan (repro.fl.cohort._eval_hits)
    ts = t0 + np.arange(T)
    eval_mask = ((ts + 1) % sc.eval_every == 0) | (ts == sc.rounds - 1)

    if sc.data_plane == "traced":
        # phases B+C, traced plane: pack metadata only; the scan gathers
        # every round's batches in-program via the counter-based draws
        slot_devs, ls, ws, gws, layout = _pack_rounds_traced(
            sim, trained_mask, l_rounds)
        params, losses, loss_hist, hits = sim.engine.fused_train_traced(
            sim, sim.params, sim.losses, ts, slot_devs, ls, ws, gws,
            trained_mask, eval_mask, layout)
    else:
        # phase B: exact-RNG batch replay + stacking
        xs, ys, masks, ls, ws, gws = _replay_batches(sim, trained_mask,
                                                     l_rounds)

        # phase C: one training program for all rounds
        params, losses, loss_hist, hits = sim.engine.fused_train(
            sim, sim.params, sim.losses, xs, ys, masks, ls, ws, gws,
            trained_mask, eval_mask)

    cum = sim.delay_sum + np.cumsum(np.asarray(delay, np.float64))
    tel = RoundTelemetry(
        t=t0 + np.arange(T),
        selected=np.asarray(selected, bool),
        trained=np.asarray(trained_mask, bool),
        l_n=np.asarray(l_rounds, int),
        delay=np.asarray(delay, np.float64),
        cum_delay=cum,
        queues=np.asarray(queues, np.float64),
        losses=np.asarray(loss_hist, np.float64),
        failures=np.asarray(failures, int),
        aggregations=np.asarray(trained_mask.any(axis=1), int),
        staleness_mean=np.zeros(T), staleness_max=np.zeros(T, int),
        stale_discarded=np.zeros(T, int), dropped_devices=np.zeros(T, int),
        lost_devices=np.zeros(T, int), straggler_devices=np.zeros(T, int),
        buffer_fill=np.zeros(T, int), inflight=np.zeros(T, int))
    records = tel.to_records()

    # commit the end state to the Simulation (stepwise-compatible)
    sim.params = params
    sim.losses = np.asarray(losses, np.float64)
    sim.queues = np.asarray(queues[-1], np.float64).copy()
    sim.t = t0 + T
    sim.delay_sum = float(cum[-1])

    # in-scan eval: hit counts crossed the host with the telemetry; turn
    # them into the stepwise loop's accuracy numbers (hits / test size —
    # exact, SplitModel.accuracy's chunking does not change integer hits)
    n_test = max(int(np.size(np.asarray(sim.ds.y_test))), 1)
    for r, h in zip(records, np.asarray(hits)):
        if h >= 0:
            r.accuracy = float(int(h)) / n_test
    return records


# ---------------------------------------------------------------------------
# seeds x V sweep
# ---------------------------------------------------------------------------


def _seed_states(sim: Simulation, seed: int, rounds: int
                 ) -> List[ChannelState]:
    """The channel trajectory a stepwise ``reset(seed)`` run would draw,
    without disturbing the live ``sim.net.rng`` stream (the reset(seed)
    fairness contract: scenario seed replays the pristine stream, any
    other seed reseeds it)."""
    if seed == sim.scenario.seed:
        rng = np.random.default_rng()
        rng.bit_generator.state = sim._net_rng_state0
    else:
        rng = np.random.default_rng(seed)
    saved = sim.net.rng
    sim.net.rng = rng
    try:
        return [sim.net.draw() for _ in range(rounds)]
    finally:
        sim.net.rng = saved


def sweep(sim: Simulation, v_values, seeds=None, *,
          rounds: Optional[int] = None,
          policies: Optional[List[str]] = None) -> SweepResult:
    """Run a scheduling sweep as ONE compiled program.

    ``policies=None`` (the classic V-sweep): resolves the scenario policy,
    which must be traced-decide (``ddsra_jax``); draws each seed's channel
    trajectory host-side under the reset(seed) contract; stacks them
    (S, T, ...) and hands off to :meth:`DDSRAPlan.sweep_states` —
    vmap(seeds) o vmap(V) o scan(rounds). All V lanes of a seed share its
    channel draws (fair-sweep contract).

    ``policies=[...]`` (the Figs. 4-6 grid): every named traced-decide
    policy becomes a lane of one ``lax.switch`` branch axis and the whole
    policies x seeds x V grid runs as a single XLA program
    (``repro.core.policy_sweep``). All policy lanes of a seed share its
    channel draws, and ``random``'s picks are pre-drawn per seed from the
    same policy-RNG stream a stepwise ``reset(seed)`` run would consume.
    """
    T = sim.scenario.rounds if rounds is None else rounds
    seeds = [sim.scenario.seed] if seeds is None else [int(s) for s in seeds]

    if policies is not None:
        from repro.core import policy_sweep as ps
        from repro.core.baseline_jax import BaselinePlan
        bad = [p for p in policies if p not in ps.POLICY_KINDS]
        if bad:
            raise ValueError(
                f"policies {bad!r} cannot ride the fused sweep (host-loop "
                f"decide); traced-decide policies: "
                f"{sorted(ps.POLICY_KINDS)} — use Simulation.rounds() for "
                "the rest")
        plan = BaselinePlan.build(sim.workload, sim.net)
        per_seed = [stack_states(_seed_states(sim, s, T)) for s in seeds]
        stacked = jax.tree.map(lambda *a: np.stack(a), *per_seed)
        kinds = np.array([ps.POLICY_KINDS[p] for p in policies], np.int32)
        j_ch = sim.net.cfg.n_channels
        chosen = np.zeros((len(policies), len(seeds), T, j_ch), np.int32)
        for pi, name in enumerate(policies):
            if ps.POLICY_KINDS[name] != 1:
                continue
            for si, s in enumerate(seeds):
                # fresh per-seed policy instance == the stepwise
                # reset(seed) contract (make_policy reseeds from run_seed)
                pol = make_policy(name, seed=s)
                chosen[pi, si] = pol.traced_chosen(0, T, sim.net)
        taus, sel, queues = ps.sweep_policies(
            plan.statics, stacked, sim.gamma, list(map(float, v_values)),
            kinds, chosen, l0=plan.l0, n_devices=plan.n_devices,
            n_gateways=plan.n_gateways)
        return SweepResult(seeds=seeds,
                           v_values=[float(v) for v in v_values],
                           taus=taus, selected=sel, queues=queues,
                           policies=list(policies))

    policy = sim._resolve_policy(None)
    if not getattr(policy, "traced_decide", False):
        raise ValueError(
            f"Simulation.sweep() needs a traced-decide policy; scenario "
            f"policy {sim.scenario.policy!r} decides on the host — set "
            "Scenario.policy='ddsra_jax'")
    plan = policy.plan_for(sim.workload, sim.net)
    if not hasattr(plan, "sweep_states"):
        raise ValueError(
            f"policy {sim.scenario.policy!r} has no V-sweep (fixed-resource "
            "baselines ignore V); set Scenario.policy='ddsra_jax' or pass "
            "policies=[...] to sweep them on the policy axis")
    per_seed = [stack_states(_seed_states(sim, s, T)) for s in seeds]
    stacked = jax.tree.map(lambda *a: np.stack(a), *per_seed)
    taus, sel, queues = plan.sweep_states(stacked, sim.gamma,
                                          list(map(float, v_values)))
    return SweepResult(seeds=seeds, v_values=[float(v) for v in v_values],
                       taus=taus, selected=sel, queues=queues)
