"""Composable FL simulation API: Scenario / Policy / Engine protocols.

The simulation surface is built from three explicit, independently pluggable
protocols:

* **Scenario** — a frozen, JSON-serializable spec of everything that defines
  an experiment: network config, data distribution, model (resolved through
  ``repro.models.registry.build_fl_model``), local-training hyperparameters
  and the default policy/engine names.
* **Policy** — any object with ``schedule(ctx) -> RoundDecision``; named
  policies come from the decorator registry in ``repro.core.schedulers``
  (``make_policy`` threads registry-declared kwargs such as ``seed``).
* **Engine** — how a scheduled round is physically executed:
  ``CohortEngine`` (one fused XLA program per round, ``repro.fl.cohort``),
  ``ShardedCohortEngine`` (the same fused round mapped over a 1-D
  ``"cohort"`` device mesh via ``jax.shard_map``, ``repro.fl.shard``) or
  ``SequentialEngine`` (the seed per-device loop, kept as the parity
  reference). All implement ``estimate_stats`` + ``train_round``.

On top sits :class:`Simulation`: a streaming ``rounds()`` generator yielding
one :class:`RoundRecord` per round (decision, delay, gateway losses, queue
state, optional boundary-activation RMS), with ``run()`` as a thin consumer
returning the classic :class:`FLResult`, ``reset(seed)`` restoring params,
batch RNG **and** network channel-state RNG together (fair multi-policy
sweeps), and ``save()``/``Simulation.resume()`` wired through
``repro.checkpoint.store`` for bit-identical checkpoint-resume.
"""
from __future__ import annotations

import atexit
import dataclasses
import json
import pathlib
import queue
import re
import threading
import time
import warnings
from typing import Dict, Iterator, List, Optional, Tuple, Type, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import store
from repro.core import costmodel as cm
from repro.core.ddsra import RoundDecision, Workload
from repro.core.lyapunov import update_queues_realized
from repro.core.network import Network, NetworkConfig
from repro.core.participation import (DataStats, divergence_bound,
                                      participation_rates)
from repro.core.schedulers import (POLICIES, RoundContext, make_policy,
                                   policy_state, set_policy_state)
from repro.fl import cohort as cohort_lib
from repro.fl import split as split_lib
from repro.fl.data import (CohortLayout, device_resident_stacks,
                           make_fl_dataset, make_token_fl_dataset,
                           sample_batch, sample_cohort_batch,
                           sample_cohort_batch_traced)
from repro.fl.faults import FaultModel
from repro.fl.roles import BaseStation, Device, Gateway
from repro.models import registry as model_registry


# ---------------------------------------------------------------------------
# Scenario
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Scenario:
    """Frozen, JSON-serializable spec of one FL experiment.

    Everything that defines a run lives here: the network/topology config
    (``net``), the data distribution (``alpha``/``chi``/``max_dataset``),
    the model (a ``repro.models.registry`` name), local-training
    hyperparameters, the default policy/engine names, and the execution
    layout for the cohort engines (``tiers`` tiered slot widths — an int
    or ``"auto"``; ``mesh_shape`` for the sharded engine's cohort mesh).
    ``to_json``/``from_json`` round-trip exactly, and checkpoints written
    before a field existed load with its default.
    """
    model: str = "vgg"                 # repro.models.registry.FL_MODELS key
    width_mult: float = 0.25
    classes: int = 10
    mlp_hidden: Tuple[int, ...] = (128, 64)
    seq_len: int = 32                  # sequence length for token models
    k_iters: int = 5                   # local epochs K
    lr: float = 0.01                   # step size beta
    alpha: float = 0.05                # training data sampling ratio
    rounds: int = 50
    v: float = 0.01                    # Lyapunov control parameter
    policy: str = "ddsra"              # default scheduling policy name
    seed: int = 0
    eval_every: int = 5
    max_dataset: int = 2000
    chi: float = 1.0                   # non-IID degree
    sigma_samples: int = 8             # per-sample grads for sigma estimation
    engine: str = "cohort"             # ENGINES key
    # tiered slot widths: an int (1 = single width) or "auto" to pick the
    # tier count from the d_tilde histogram (CohortLayout.auto_tiers —
    # smallest count reaching the padded-samples curve's floor)
    tiers: Union[int, str] = 1
    mesh_shape: Optional[Tuple[int, ...]] = None   # cohort mesh (None = all)
    keep_last: Optional[int] = None    # checkpoint rotation (None = keep all)
    # mixed-precision data plane: "f32" (default) or "bf16" (bf16 storage/
    # GEMMs with f32 master params + f32 accumulation; cohort engines only)
    dtype: str = "f32"
    # where training batches are drawn: "host" (numpy RNG draws replayed /
    # pre-packed per round) or "traced" (counter-based jax draws gathered
    # from device-resident shard stacks — inside the scan on the fused
    # path; cohort engines only, see repro.fl.data.traced_batch_indices)
    data_plane: str = "host"
    # model-upload compression: bits per parameter priced into the DDSRA
    # upload-delay/energy terms (None = the model's native precision;
    # dtype="bf16" implies 16 unless overridden — e.g. 8 for int8 uploads)
    upload_bits: Optional[float] = None
    # fault-injection axes (engine="async" only; see repro.fl.faults):
    # per-round, per-device probabilities of being offline at dispatch
    # (churn), of losing the trained update mid-round (dropout), and of
    # straggling — an Exp(mean=straggler_scale) multiplicative extra delay
    # factor fires with probability straggler_frac. All zero = no faults.
    churn: float = 0.0
    dropout: float = 0.0
    straggler_frac: float = 0.0
    straggler_scale: float = 0.0
    # FedBuff-style buffered aggregation (engine="async"): aggregate once
    # buffer_k gateway updates have landed; None = drain the round's whole
    # dispatched cohort first (the synchronous barrier expressed in
    # buffered form — the degenerate-parity oracle against CohortEngine).
    buffer_k: Optional[int] = None
    # staleness weighting s(tau) = (1 + tau)^(-alpha) applied to buffered
    # updates tau aggregation-versions old (0.5 = FedBuff's 1/sqrt(1+tau));
    # updates older than max_staleness versions are discarded (None = keep).
    staleness_alpha: float = 0.5
    max_staleness: Optional[int] = None
    net: NetworkConfig = dataclasses.field(default_factory=NetworkConfig)

    @property
    def effective_upload_bits(self) -> Optional[float]:
        """Bits per parameter the cost model prices the model upload at:
        ``upload_bits`` when set, else 16 for the bf16 data plane, else
        ``None`` — the model's native precision
        (``costmodel.upload_bytes(layers, None)`` = ``model_size_bytes``)."""
        if self.upload_bits is not None:
            return float(self.upload_bits)
        return 16.0 if self.dtype == "bf16" else None

    def to_json(self) -> dict:
        """Serialize to a plain-JSON dict (tuples become lists)."""
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: dict) -> "Scenario":
        """Rebuild from :meth:`to_json` output, tolerating version skew in
        both directions: fields *missing* from ``d`` (checkpoints/sweep
        JSONs written before the field existed) take their dataclass
        defaults, and *unknown* fields (written by a newer version) are
        dropped with a warning instead of raising — so old artifacts keep
        loading after new axes land, and new artifacts degrade gracefully
        on old code. The same applies to the nested ``net`` config."""
        d = dict(d)
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = sorted(set(d) - known)
        if unknown:
            warnings.warn(
                f"Scenario.from_json: ignoring unknown fields {unknown} "
                "(written by a newer version?)", stacklevel=2)
            for k in unknown:
                d.pop(k)
        net = d.pop("net", {})
        if isinstance(net, dict):
            net = dict(net)
            net_known = {f.name for f in dataclasses.fields(NetworkConfig)}
            net_unknown = sorted(set(net) - net_known)
            if net_unknown:
                warnings.warn(
                    "Scenario.from_json: ignoring unknown net fields "
                    f"{net_unknown} (written by a newer version?)",
                    stacklevel=2)
                for k in net_unknown:
                    net.pop(k)
            for k in ("f_dev_range", "dist_range"):
                if k in net:
                    net[k] = tuple(net[k])
            net = NetworkConfig(**net)
        d["mlp_hidden"] = tuple(d.get("mlp_hidden", (128, 64)))
        if d.get("mesh_shape") is not None:
            d["mesh_shape"] = tuple(d["mesh_shape"])
        return cls(net=net, **d)


# ---------------------------------------------------------------------------
# RoundRecord / FLResult
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RoundRecord:
    """Telemetry for one simulated round (yielded by Simulation.rounds()).

    The staleness/fault fields are filled by the buffered async engine
    (``repro.fl.async_engine``); synchronous engines leave them at their
    barrier-semantics values (one aggregation per trained round, staleness
    0, no faults).
    """
    t: int
    selected: np.ndarray               # (M,) gateway participation this round
    trained: List[int]                 # gateways that actually trained
    l_n: np.ndarray                    # (N,) per-device partition points
    delay: float                       # realized round delay (time advanced)
    cum_delay: float
    queues: np.ndarray                 # (M,) virtual-queue backlog
    losses: np.ndarray                 # (M,) per-gateway local losses
    failures: int                      # resource-infeasible gateways
    boundary_rms: Optional[np.ndarray] = None   # (N,) when requested
    accuracy: Optional[float] = None   # test accuracy on eval rounds
    # -- staleness / fault telemetry (async engine) ----------------------
    aggregations: int = 0              # buffer flushes applied this round
    staleness_mean: float = 0.0        # mean tau over updates aggregated
    staleness_max: int = 0             # max tau over updates aggregated
    stale_discarded: int = 0           # updates dropped for tau > max_staleness
    dropped_devices: int = 0           # churned offline at dispatch
    lost_devices: int = 0              # trained, update lost mid-round
    straggler_devices: int = 0         # surviving devices that straggled
    buffer_fill: int = 0               # buffer occupancy at round end
    inflight: int = 0                  # updates still in flight at round end


def resolve_decision(dec: RoundDecision, gateways, n_devices: int):
    """Resolve a schedule into what actually trains this round.

    The host-side half of the decision contract: for each selected gateway,
    look up its assigned channel's solution, fail it (counted) when the
    solve is infeasible or non-finite, and scatter the per-lane partition
    points of surviving gateways into the dense (N,) vector. The traced
    twin is ``repro.core.ddsra_jax.resolve_decision_arrays`` — identical
    semantics over :class:`~repro.core.ddsra_jax.DecisionArrays`, pinned
    bit-identical by ``tests/test_fused_sim.py``.

    Returns ``(trained, l_n, gw_delay, failures)``: the trained gateway
    ids (ascending), the (N,) per-device partition points, the per-gateway
    realized delays and the infeasible-selection count.
    """
    trained, l_n = [], np.zeros(n_devices, int)
    gw_delay: Dict[int, float] = {}
    failures = 0
    for m in np.where(dec.selected)[0]:
        j = int(np.argmax(dec.assignment[m]))
        sol = dec.solutions.get((int(m), j))
        if sol is None:
            continue
        if not sol.feasible or not np.isfinite(sol.delay):
            failures += 1     # energy/memory violation: round fails
            continue
        gw_delay[int(m)] = float(sol.delay)
        trained.append(int(m))
        for i, dev in enumerate(gateways[m].devices):
            l_n[dev.idx] = int(sol.l_split[i])
    return trained, l_n, gw_delay, failures


@dataclasses.dataclass
class FLResult:
    """Aggregate outcome of a full run (built by ``Simulation.result_of``)."""
    accuracy: List[float]
    acc_rounds: List[int]
    cum_delay: List[float]
    participation: np.ndarray          # (T, M)
    gamma_targets: np.ndarray
    losses: List[float]
    phi: np.ndarray
    failures: int


# ---------------------------------------------------------------------------
# Engines
# ---------------------------------------------------------------------------

ENGINES: Dict[str, Type["Engine"]] = {}


def register_engine(name: str):
    """Class decorator: register an :class:`Engine` under ``name`` (the
    value a ``Scenario.engine`` field refers to). Duplicate names raise."""
    def deco(cls):
        if name in ENGINES:
            raise ValueError(f"engine {name!r} already registered")
        ENGINES[name] = cls
        cls.name = name
        return cls
    return deco


def make_engine(name: str) -> "Engine":
    """Instantiate a registered engine by name (see ``ENGINES``)."""
    if name not in ENGINES:
        raise ValueError(f"unknown engine {name!r}: "
                         f"expected one of {sorted(ENGINES)}")
    return ENGINES[name]()


@dataclasses.dataclass
class RoundOutcome:
    """What actually happened when an engine executed a scheduled round.

    Synchronous engines realize exactly what was scheduled (``realized``
    stays ``None`` — the policy's own queue update stands); the buffered
    async engine reports realized completion instead: the time actually
    advanced (straggler tails included), which gateways' updates actually
    landed, and the staleness/fault telemetry threaded into
    :class:`RoundRecord`.
    """
    delay: float                       # realized time advanced this round
    boundary_rms: Optional[np.ndarray] = None
    # (M,) bool realized participation indicator for the Lyapunov queue
    # update (lyapunov.update_queues_realized); None = as scheduled.
    realized: Optional[np.ndarray] = None
    aggregations: int = 0
    staleness_mean: float = 0.0
    staleness_max: int = 0
    stale_discarded: int = 0
    dropped_devices: int = 0
    lost_devices: int = 0
    straggler_devices: int = 0
    buffer_fill: int = 0
    inflight: int = 0


class Engine:
    """Protocol: how a scheduled round is executed on the model."""
    name: str
    # compute dtypes this engine can run the data plane in; Simulation
    # rejects a Scenario whose ``dtype`` the chosen engine can't honor
    # (silently training in f32 would falsify the priced upload_bits).
    supported_dtypes: Tuple[str, ...] = ("f32",)
    # whether the engine honors the Scenario fault axes (churn/dropout/
    # stragglers) and buffer_k; Simulation rejects active fault axes on
    # engines that would silently train fault-free (falsified sweeps).
    supports_faults: bool = False
    # whether :meth:`fused_train` runs the whole-trajectory scan (the fused
    # simulation loop, ``repro.fl.fused_sim``); engines without it are
    # refused up front, before any RNG stream is consumed.
    supports_fused: bool = False
    # whether the engine honors ``Scenario.data_plane="traced"`` (counter-
    # based jax batch draws instead of the host numpy stream); Simulation
    # rejects traced-plane scenarios on engines that would silently keep
    # sampling host-side (the two planes draw different batches).
    supports_traced_data: bool = False

    def estimate_stats(self, sim: "Simulation", params) -> DataStats:
        """Estimate the per-device sigma_n/delta_n/L_n statistics the
        divergence bound (paper Sec. VII-A) needs."""
        raise NotImplementedError

    def train_round(self, sim: "Simulation", trained: List[int],
                    l_n: np.ndarray,
                    with_boundary: bool = False) -> Optional[np.ndarray]:
        """Train one round in-place on ``sim`` (params + per-gateway losses);
        returns the (N,) boundary-activation RMS when requested/supported."""
        raise NotImplementedError

    def run_round(self, sim: "Simulation", dec: RoundDecision,
                  trained: List[int], l_n: np.ndarray,
                  gw_delay: Dict[int, float],
                  boundary: bool = False) -> RoundOutcome:
        """Execute one scheduled round and report what actually happened.

        Default (synchronous) semantics: train the scheduled cohort via
        :meth:`train_round`, realize exactly the scheduled delays (the
        FedAvg barrier waits for the slowest gateway, ``max`` over
        ``gw_delay``), and leave the policy's queue update untouched. The
        async engine overrides this wholesale — buffered aggregation,
        fault injection, realized-delay accounting.
        """
        rms = self.train_round(sim, trained, l_n, with_boundary=boundary)
        return RoundOutcome(delay=max(gw_delay.values(), default=0.0),
                            boundary_rms=rms,
                            aggregations=1 if trained else 0)

    def inflight_counts(self, sim: "Simulation") -> Optional[np.ndarray]:
        """(M,) per-gateway count of dispatched-but-not-landed updates,
        offered to policies via ``RoundContext.inflight``; synchronous
        engines have none (``None``)."""
        return None

    def fused_train(self, sim: "Simulation", params, losses0, xs, ys,
                    masks, ls, ws, gws, trained, eval_mask=None):
        """Run a whole pre-packed training trajectory as one compiled
        program (the fused simulation loop, ``repro.fl.fused_sim``).

        ``xs/ys/masks/ls/ws/gws`` are per-tier tuples with a leading round
        axis (tier k: ``(T, S_k, ...)``), ``trained`` the (T, M) bool
        trained-gateway mask, ``eval_mask`` the (T,) bool ``eval_every``
        schedule (None = never evaluate). Returns (final params, final
        (M,) losses, (T, M) per-round loss history, (T,) in-scan test
        hits — -1 on non-eval rounds). Engines without a scan-compatible
        round (the sequential loop, the buffered async engine) raise —
        ``Simulation.rounds()`` is their only path.
        """
        raise NotImplementedError(
            f"engine {self.name!r} has no fused scan path; use "
            "Simulation.rounds()")

    def reset(self, sim: "Simulation") -> None:
        """Discard engine-internal *run* state (default: none).

        Called from :meth:`Simulation.restart` — and therefore from
        ``run()`` and ``reset()`` — so buffered engines drop in-flight and
        parked updates when the clock rewinds; a stale update from a
        previous run must never aggregate into a fresh one."""
        return None

    def state_dict(self, sim: "Simulation"):
        """Engine-internal state to checkpoint, as ``(meta, arrays)`` —
        ``meta`` a JSON-serializable dict stored in the ``sim_*.json``
        manifest, ``arrays`` a pytree written beside the params (prefix
        ``engine_``) — or ``None`` for stateless engines (the default)."""
        return None

    def load_state_dict(self, sim: "Simulation", meta: dict, path,
                        step: int) -> None:
        """Restore what :meth:`state_dict` captured (default: nothing)."""
        return None


@register_engine("cohort")
class CohortEngine(Engine):
    """One fused XLA program per round (see ``repro.fl.cohort``).

    Participants are packed into a fixed tier-major slot layout
    (``repro.fl.data.CohortLayout`` — ``Scenario.tiers`` controls how many
    distinct slot widths are used; 1 reproduces the historical single-width
    contract), so every round reuses one compiled executable regardless of
    which devices the policy schedules.
    """

    supported_dtypes = ("f32", "bf16")
    supports_fused = True
    supports_traced_data = True

    def _shard_count(self, sim: "Simulation") -> int:
        """Multiple each tier's slot count must divide into (the cohort
        mesh size for the sharded subclass; 1 on a single host)."""
        return 1

    def _layout(self, sim: "Simulation", capacity: int) -> CohortLayout:
        """The (cached) fixed slot layout for ``capacity``-slot rounds."""
        key = (capacity, sim.scenario.tiers, self._shard_count(sim))
        if key not in sim._layouts:
            sim._layouts[key] = CohortLayout.build(
                sim.d_tilde, capacity, sim.scenario.tiers,
                self._shard_count(sim))
        return sim._layouts[key]

    def _fused_round(self, sim: "Simulation", params, batch, l_slot, w_slot,
                     gw_slot, *, with_boundary: bool,
                     with_gateway_models: bool):
        """Execute one fused round; subclasses override this to change
        *where* it runs (e.g. sharded over a mesh) without touching the
        packing/telemetry logic above it. Always returns the 6-tuple
        (new_global, gw_loss, gw_count, slot_losses, boundary, gw_models)
        with ``gw_models=None`` when not requested."""
        sc = sim.scenario
        out = cohort_lib.cohort_round(
            sim.plan, params, batch, l_slot, w_slot, gw_slot,
            sc.k_iters, sc.lr, with_boundary=with_boundary,
            with_gateway_models=with_gateway_models,
            compute_dtype=sc.dtype)
        return out if with_gateway_models else (*out, None)

    def _fused_stats(self, sim: "Simulation", params, batch, mix):
        """Run the fused sigma/delta/L_n program; the sharded subclass
        overrides this (only) to run it under shard_map."""
        sc = sim.scenario
        return cohort_lib.cohort_stats(sim.plan, params, batch, mix, sc.lr,
                                       sc.sigma_samples)

    def estimate_stats(self, sim: "Simulation", params) -> DataStats:
        """sigma/delta/Lipschitz for every device in one fused program."""
        n_dev = sim.net.cfg.n_devices
        batch = sample_cohort_batch(sim.rng, sim.ds, range(n_dev),
                                    sim.d_tilde, int(sim.d_tilde.max()))
        mix = sim.d_sizes / sim.d_sizes.sum()
        sigma, delta, lips = self._fused_stats(sim, params, batch, mix)
        return DataStats(np.asarray(sigma), np.asarray(delta),
                         np.maximum(np.asarray(lips), 0.1),
                         sim.d_tilde.astype(float))

    def _pack_round(self, sim: "Simulation", trained: List[int],
                    l_n: np.ndarray):
        """Pack the scheduled devices into the fixed slot layout.

        Owns the batch-draw ordering contract (draws come from ``sim.rng``
        in gateway-major device order, identical for every engine built on
        this packing — the async engine reuses it verbatim so its degenerate
        configuration replays the cohort engine's exact RNG stream).
        Returns (device_ids, batch, layout, l_slot, w_slot, slot_gw).
        """
        device_ids: List[int] = []
        for m in trained:
            device_ids.extend(dev.idx for dev in sim.gateways[m].devices)
        # capacity always fits a schedulable round; fall back to the all-
        # devices layout (one extra compile, same numerics) if it ever won't
        cap = sim.cohort_capacity if len(device_ids) <= sim.cohort_capacity \
            else sim.net.cfg.n_devices
        layout = self._layout(sim, cap)
        if sim.scenario.data_plane == "traced":
            # counter-based jax draws (a pure function of (data_key, round,
            # device)) — no host RNG consumed, bit-identical to the fused
            # scan's in-program gathers
            batch = sample_cohort_batch_traced(sim.data_key, sim.t, sim.ds,
                                               device_ids, sim.d_tilde,
                                               layout=layout)
        else:
            batch = sample_cohort_batch(sim.rng, sim.ds, device_ids,
                                        sim.d_tilde, layout=layout)
        n_slots = layout.n_slots
        l_slot = np.zeros(n_slots, int)
        w_slot = np.zeros(n_slots, np.float32)
        slot_gw = np.zeros((n_slots, sim.net.cfg.n_gateways), np.float32)
        for di, n in enumerate(device_ids):
            s = int(batch.slot_of[di])
            l_slot[s] = l_n[n]
            w_slot[s] = sim.d_tilde[n]
            slot_gw[s, sim.net.assign[n]] = 1.0
        return device_ids, batch, layout, l_slot, w_slot, slot_gw

    def train_round(self, sim: "Simulation", trained: List[int],
                    l_n: np.ndarray,
                    with_boundary: bool = False) -> Optional[np.ndarray]:
        """Pack the scheduled devices into the fixed slot layout and run
        the fused round in-place on ``sim``."""
        if not trained:
            return None
        device_ids, batch, layout, l_slot, w_slot, slot_gw = \
            self._pack_round(sim, trained, l_n)
        new_global, gw_loss, _, _, boundary, _ = self._fused_round(
            sim, sim.params, batch, l_slot, w_slot, slot_gw,
            with_boundary=with_boundary, with_gateway_models=False)
        sim.params = new_global
        # padded-vs-real sample accounting (read by fl_round_bench)
        sim.padding_stats["real_samples"] += float(
            sum(t.mask.sum() for t in batch.tiers))
        sim.padding_stats["padded_samples"] += float(layout.padded_samples)
        gw_loss = np.asarray(gw_loss)
        for m in trained:
            sim.losses[m] = float(gw_loss[m])
        if with_boundary:
            rms = np.zeros(sim.net.cfg.n_devices)
            rms[device_ids] = np.asarray(boundary)[batch.slot_of]
            return rms
        return None

    def fused_train(self, sim: "Simulation", params, losses0, xs, ys,
                    masks, ls, ws, gws, trained, eval_mask=None):
        """All rounds as one program: ``lax.scan`` of the fused round
        (``repro.fl.cohort.train_scan``) over the stacked packed batches
        and decision tensors."""
        sc = sim.scenario
        if eval_mask is None:
            eval_mask = np.zeros(np.asarray(trained).shape[0], bool)
        x_test, y_test = self._eval_arrays(sim)
        return cohort_lib.train_scan(
            sim.plan, params, losses0, xs, ys, masks, ls, ws, gws, trained,
            np.float32(sc.lr), np.asarray(eval_mask, bool),
            x_test, y_test,
            k_iters=sc.k_iters, compute_dtype=sc.dtype)

    def _pack_round_meta(self, sim: "Simulation", trained: List[int],
                         l_n: np.ndarray):
        """:meth:`_pack_round`'s slot assignment WITHOUT sampling any data
        — the traced data plane's packing: the fused scan gathers each
        slot's batch in-program from its device id, so the host only ships
        this round's (slot -> device, l, weight, gateway) metadata.

        Slot ranks replicate ``sample_cohort_batch_traced``'s assignment
        exactly (same stable argsort over the same clipped batch lengths),
        so per-slot outputs scatter back to devices identically on both
        paths. Returns (device_ids, layout, slot_dev (-1 = empty slot),
        l_slot, w_slot, slot_gw, real_samples).
        """
        device_ids: List[int] = []
        for m in trained:
            device_ids.extend(dev.idx for dev in sim.gateways[m].devices)
        cap = sim.cohort_capacity if len(device_ids) <= sim.cohort_capacity \
            else sim.net.cfg.n_devices
        layout = self._layout(sim, cap)
        pools = np.array([len(sim.ds.y_dev[n]) for n in device_ids],
                         dtype=int)
        lens = np.minimum(sim.d_tilde[device_ids], pools) if device_ids \
            else np.zeros(0, dtype=int)
        n_slots = layout.n_slots
        slot_dev = np.full(n_slots, -1, np.int32)
        l_slot = np.zeros(n_slots, int)
        w_slot = np.zeros(n_slots, np.float32)
        slot_gw = np.zeros((n_slots, sim.net.cfg.n_gateways), np.float32)
        for rank, di in enumerate(np.argsort(-lens, kind="stable")):
            n = device_ids[di]
            slot_dev[rank] = n
            l_slot[rank] = l_n[n]
            w_slot[rank] = sim.d_tilde[n]
            slot_gw[rank, sim.net.assign[n]] = 1.0
        return (device_ids, layout, slot_dev, l_slot, w_slot, slot_gw,
                int(lens.sum()))

    def _data_stacks(self, sim: "Simulation"):
        """The (lazily-built, cached) device-resident shard stacks the
        traced data plane gathers from (``repro.fl.data
        .device_resident_stacks``); the dataset is fixed per Simulation,
        so the cache survives reset/restart. The x/y stacks are committed
        to device here — caching host arrays would re-transfer the full
        pool (tens of MB) on every fused call, a fixed cost that dwarfs
        the scan itself; ``pool`` stays numpy for host-side arithmetic."""
        if getattr(sim, "_resident_stacks", None) is None:
            x_all, y_all, pool = device_resident_stacks(sim.ds)
            sim._resident_stacks = (jnp.asarray(x_all), jnp.asarray(y_all),
                                    pool)
        return sim._resident_stacks

    def _eval_arrays(self, sim: "Simulation"):
        """Device-committed (x_test, y_test), cached for the same reason
        as :meth:`_data_stacks`."""
        if getattr(sim, "_resident_eval", None) is None:
            sim._resident_eval = (jnp.asarray(sim.ds.x_test),
                                  jnp.asarray(sim.ds.y_test))
        return sim._resident_eval

    def fused_train_traced(self, sim: "Simulation", params, losses0, ts,
                           slot_devs, ls, ws, gws, trained, eval_mask,
                           layout):
        """All rounds as one program with the data plane *inside* it:
        ``repro.fl.cohort.train_scan_traced`` gathers every round's batches
        in-scan from the device-resident shard stacks, so the host never
        materializes the ``(T, S_k, W_k, ...)`` sample stacks
        :meth:`fused_train` is fed. ``slot_devs/ls/ws/gws`` are per-tier
        tuples with a leading round axis; ``ts`` the absolute round
        indices the counter-based draws fold in."""
        sc = sim.scenario
        x_all, y_all, pool = self._data_stacks(sim)
        batch_lens = np.minimum(
            np.asarray(sim.d_tilde, np.int32), pool).astype(np.int32)
        x_test, y_test = self._eval_arrays(sim)
        return cohort_lib.train_scan_traced(
            sim.plan, params, losses0, x_all, y_all, pool, batch_lens,
            sim.data_key, np.asarray(ts, np.int32), slot_devs, ls, ws, gws,
            trained, np.float32(sc.lr), np.asarray(eval_mask, bool),
            x_test, y_test, k_iters=sc.k_iters,
            compute_dtype=sc.dtype, tier_widths=tuple(layout.tier_widths))

    def shop_floor_round(self, sim: "Simulation", device_ids: List[int],
                         l_n: np.ndarray, params=None,
                         rng: Optional[np.random.Generator] = None):
        """Fused round over ``device_ids`` that also surfaces the per-gateway
        shop-floor models (the intermediate the Fig. 2 divergence experiment
        compares against a centralized twin).

        Batches are drawn from ``rng`` in ``device_ids`` order — exactly the
        draws the sequential per-device loop would make — and returned so the
        caller can, e.g., pool them for a centralized-GD twin. This path
        keeps the all-devices layout (row n = device n) so ``l_n``/weights
        index devices directly.

        Returns (new_global, gateway_models (leading M axis), gateway_losses,
        CohortBatch).
        """
        rng = sim.rng if rng is None else rng
        params = sim.params if params is None else params
        weights = np.zeros(sim.net.cfg.n_devices, np.float32)
        weights[list(device_ids)] = sim.d_tilde[list(device_ids)]
        batch = sample_cohort_batch(rng, sim.ds, device_ids, sim.d_tilde,
                                    int(sim.d_tilde.max()))
        new_global, gw_loss, _, _, _, gw_models = self._fused_round(
            sim, params, batch, l_n, weights, sim.net.a,
            with_boundary=False, with_gateway_models=True)
        return new_global, gw_models, np.asarray(gw_loss), batch


@register_engine("sequential")
class SequentialEngine(Engine):
    """Seed per-device Python loop (kept as the parity/bench reference)."""

    def estimate_stats(self, sim: "Simulation", params) -> DataStats:
        """sigma/delta/Lipschitz estimated one device at a time (the seed
        O(devices x samples) loop of jitted calls)."""
        sc = sim.scenario
        n_dev = sim.net.cfg.n_devices
        grads, sigmas, lips = [], [], []
        for n in range(n_dev):
            x, y = sample_batch(sim.rng, sim.ds, n, sim.d_tilde[n])
            g = np.asarray(split_lib.flat_grad(sim.plan, params, x, y))
            grads.append(g)
            # sigma: per-sample gradient spread
            m_s = min(sc.sigma_samples, len(y))
            per = [np.asarray(split_lib.flat_grad(sim.plan, params,
                                                  x[i:i + 1], y[i:i + 1]))
                   for i in range(m_s)]
            mean_g = np.mean(per, axis=0)
            sigmas.append(float(np.mean([np.linalg.norm(p - mean_g)
                                         for p in per])))
            # L_n: two-point secant
            w0 = split_lib.flat_params(params)
            pert = jax.tree.map(
                lambda p_, gg: p_ - sc.lr * gg,
                params, jax.tree.unflatten(jax.tree.structure(params),
                                           _unflatten_like(g, params)))
            g2 = np.asarray(split_lib.flat_grad(sim.plan, pert, x, y))
            w1 = split_lib.flat_params(pert)
            dw = np.linalg.norm(np.asarray(w1) - np.asarray(w0))
            lips.append(float(np.linalg.norm(g2 - g) / max(dw, 1e-9)))
        weights = sim.d_sizes / sim.d_sizes.sum()
        global_g = np.sum([w * g for w, g in zip(weights, grads)], axis=0)
        deltas = [float(np.linalg.norm(g - global_g)) for g in grads]
        return DataStats(np.asarray(sigmas), np.asarray(deltas),
                         np.maximum(np.asarray(lips), 0.1),
                         sim.d_tilde.astype(float))

    def train_round(self, sim: "Simulation", trained: List[int],
                    l_n: np.ndarray,
                    with_boundary: bool = False) -> Optional[np.ndarray]:
        """One round as the seed ran it: a Python loop over gateways and
        devices with per-device jitted split-SGD steps."""
        sc = sim.scenario
        models, weights = [], []
        for m in trained:
            gw = sim.gateways[m]
            l_splits = np.asarray([l_n[d.idx] for d in gw.devices])
            combined, gw_loss, w_m = gw.shop_floor_round(
                sim.plan, sim.params, sim.ds, l_splits,
                sc.k_iters, sc.lr, sim.rng)
            models.append(combined)
            weights.append(w_m)
            sim.losses[m] = gw_loss
        sim.bs.aggregate(models, np.asarray(weights))
        return None


# ---------------------------------------------------------------------------
# Simulation
# ---------------------------------------------------------------------------

PolicyLike = Union[str, object, None]


class _CheckpointWriter:
    """One daemon thread draining checkpoint write jobs in FIFO order.

    ``submit`` returns immediately; ``flush`` blocks until every submitted
    job has fully finished and re-raises the first exception any job hit,
    so callers get one crisp completion/failure point instead of silent
    data loss. Jobs must close over *snapshots* — the caller's state may
    mutate while the write is in flight.

    The thread is a daemon, so an atexit hook drains the queue at
    interpreter shutdown: a process that exits without ever calling
    ``flush`` still lands every submitted checkpoint on disk (a swallowed
    background error is surfaced as a warning there, the best that can be
    done that late).
    """

    def __init__(self):
        self._q: "queue.Queue" = queue.Queue()
        self._err: Optional[BaseException] = None
        self._thread = threading.Thread(
            target=self._loop, daemon=True, name="ckpt-writer")
        self._thread.start()
        atexit.register(self._drain_at_exit)

    def _drain_at_exit(self) -> None:
        self._q.join()
        if self._err is not None:
            warnings.warn(f"background checkpoint write failed and was "
                          f"never flush()ed: {self._err!r}")

    def _loop(self):
        while True:
            job = self._q.get()
            try:
                job()
            except BaseException as e:      # surfaced at the next flush()
                if self._err is None:
                    self._err = e
            finally:
                self._q.task_done()

    def submit(self, job) -> None:
        self._q.put(job)

    def flush(self) -> None:
        self._q.join()
        err, self._err = self._err, None
        if err is not None:
            raise err


class Simulation:
    """Composable FL simulation over a :class:`Scenario`.

    State is resolved once at construction (topology, dataset, model, layer
    cost model, per-device statistics); ``rounds()`` then streams
    :class:`RoundRecord` telemetry one round at a time.
    """

    def __init__(self, scenario: Scenario,
                 _stats: Optional[DataStats] = None):
        self.scenario = sc = scenario
        self.engine: Engine = make_engine(sc.engine)
        if sc.dtype not in cohort_lib.COMPUTE_DTYPES:
            raise ValueError(
                f"Scenario.dtype={sc.dtype!r}: expected one of "
                f"{sorted(cohort_lib.COMPUTE_DTYPES)}")
        if sc.dtype not in self.engine.supported_dtypes:
            raise ValueError(
                f"engine {sc.engine!r} supports dtypes "
                f"{self.engine.supported_dtypes}, not {sc.dtype!r}")
        if sc.data_plane not in ("host", "traced"):
            raise ValueError(
                f"Scenario.data_plane={sc.data_plane!r}: expected 'host' "
                "or 'traced'")
        if sc.data_plane == "traced" and \
                not self.engine.supports_traced_data:
            raise ValueError(
                f"engine {sc.engine!r} samples batches host-side: it "
                "cannot honor data_plane='traced'; use a cohort engine")
        if sc.buffer_k is not None and sc.buffer_k < 1:
            raise ValueError(f"Scenario.buffer_k must be >= 1 or None, "
                             f"got {sc.buffer_k}")
        self.faults = FaultModel.from_scenario(sc)
        if ((self.faults.active or sc.buffer_k is not None)
                and not self.engine.supports_faults):
            raise ValueError(
                f"engine {sc.engine!r} is synchronous: it cannot honor "
                f"fault axes (churn/dropout/stragglers) or buffer_k; use "
                f"engine='async'")
        self.net = Network(sc.net, np.random.default_rng(sc.seed))
        self.rng = np.random.default_rng(sc.seed + 1)
        ncfg = self.net.cfg

        # local dataset sizes D_n ~ U(0, 2000]; training batch D~_n = alpha*D_n
        self.d_sizes = np.maximum(
            (self.rng.uniform(0, sc.max_dataset, ncfg.n_devices)).astype(int),
            40)
        self.d_tilde = np.maximum((sc.alpha * self.d_sizes).astype(int), 4)

        # model resolved through the registry + layer-level costs (Table II);
        # built *before* the dataset so its input_kind can pick the data
        # path (consumes only the jax PRNG — the numpy byte stream the
        # image dataset replays is untouched).
        key = jax.random.PRNGKey(sc.seed)
        self.plan, params, self.layers = model_registry.build_fl_model(
            sc.model, key, sc)
        self.bs = BaseStation(self.plan, params)

        if self.plan.input_kind == "tokens":
            # token models: per-device Markov-chain corpora whose transition
            # tables play the role of the class mixture (chi-mixed)
            self.ds = make_token_fl_dataset(
                ncfg.n_devices, self.d_sizes, vocab=self.plan.classes,
                seq_len=sc.seq_len, chi=sc.chi, seed=sc.seed)
        else:
            # non-IID classes: gateway 0's devices see the widest variety
            # (paper Sec. VII-B: "the 1-th gateway ... a wider variety")
            q = np.zeros(ncfg.n_devices, dtype=int)
            for n in range(ncfg.n_devices):
                gw = self.net.assign[n]
                q[n] = sc.classes if gw == 0 else int(self.rng.integers(1, 4))
            self.ds = make_fl_dataset(ncfg.n_devices, self.d_sizes, q,
                                      chi=sc.chi, classes=sc.classes,
                                      seed=sc.seed)

        o = cm.flops_vector(self.layers)
        g = cm.mem_vector(self.layers, batch=int(self.d_tilde.max()))
        # the model upload is priced at the scenario's compression level:
        # Workload.gamma feeds every uplink/downlink delay and energy term in
        # the DDSRA solvers, so quantized uploads shift the whole schedule.
        self.workload = Workload(
            o, g, cm.upload_bytes(self.layers, sc.effective_upload_bits),
            sc.k_iters, self.d_tilde.astype(float))

        self.gateways = [
            Gateway(m, [Device(int(n), m, int(self.d_sizes[n]),
                               int(self.d_tilde[n]))
                        for n in self.net.devices_of(m)])
            for m in range(ncfg.n_gateways)]

        # the scheduler can select at most n_channels gateways per round
        # (C2/C3), so this many slots always fit every round's participants;
        # packing into them skips compute for absent devices at fixed shapes.
        per_gw = int(np.bincount(self.net.assign,
                                 minlength=ncfg.n_gateways).max())
        self.cohort_capacity = min(ncfg.n_devices, ncfg.n_channels * per_gw)
        self._layouts: Dict = {}      # (capacity, tiers, shards) -> layout

        # ``_stats`` (resume fast path) skips the estimation pass entirely —
        # callers providing it are responsible for also restoring the batch
        # RNG state, since no estimation draws are consumed.
        t0 = time.perf_counter()
        self.stats = _stats if _stats is not None \
            else self.engine.estimate_stats(self, params)
        self.stats_seconds = time.perf_counter() - t0  # for fl_round_bench
        self.phi = divergence_bound(self.stats, self.net.assign,
                                    sc.lr, sc.k_iters)
        self.gamma = participation_rates(self.phi, ncfg.n_channels)

        # snapshots for reset(): fresh-Simulation replay of all three streams
        self._init_params = params
        self._rng_state0 = self.rng.bit_generator.state
        self._net_rng_state0 = self.net.rng.bit_generator.state

        self._policy = None
        self.run_seed = sc.seed   # threaded into stochastic policies
        self._ckpt_writer: Optional[_CheckpointWriter] = None
        self.restart()

    # -- state ----------------------------------------------------------

    @property
    def params(self):
        return self.bs.params

    @params.setter
    def params(self, value):
        self.bs.params = value

    @property
    def data_key(self):
        """Root key of the traced data plane's counter-based batch draws
        (``repro.fl.data.traced_batch_indices``). Derived from the run
        seed — one step past the batch-RNG seed (``seed + 1``) and the
        channel-RNG seed (``seed``) — so ``reset(seed)`` and checkpoint
        resume re-derive it with no extra state to save."""
        return jax.random.PRNGKey(self.run_seed + 2)

    def restart(self) -> None:
        """Reset the *run* state (round counter, queues, losses, delay) while
        keeping params and RNG streams — what a fresh ``run()`` call does.
        Engine-internal run state (the async engine's in-flight heap and
        staleness buffer) is discarded too: the clock rewinds, so updates
        from the previous run must not land in the next one."""
        ncfg = self.net.cfg
        self.t = 0
        self.queues = np.zeros(ncfg.n_gateways)
        self.losses = np.full(ncfg.n_gateways, self.plan.init_loss)
        self.delay_sum = 0.0
        # cumulative padded-vs-real sample counts (cohort engines fill this)
        self.padding_stats = {"real_samples": 0.0, "padded_samples": 0.0}
        self._policy = None
        self._policy_unresumable = False
        self.engine.reset(self)

    def reset(self, seed: Optional[int] = None) -> "Simulation":
        """Full reset for fair multi-policy sweeps.

        Restores the model parameters, the batch-sampling RNG **and** the
        network channel-state RNG together, so every policy run after a
        ``reset()`` faces the identical ChannelState sequence, data draws and
        initialization. With ``seed=None`` this replays a fresh
        ``Simulation(scenario)`` exactly; an explicit ``seed`` re-seeds the
        run-level streams — params init, batch RNG, channel RNG and the
        seed threaded into stochastic policies — while the scenario-level
        structure (topology, deployment, dataset) stays fixed.
        """
        if seed is None or seed == self.scenario.seed:
            self.bs.params = self._init_params
            self.rng.bit_generator.state = self._rng_state0
            self.net.rng.bit_generator.state = self._net_rng_state0
        else:
            key = jax.random.PRNGKey(seed)
            _, self.bs.params, _ = model_registry.build_fl_model(
                self.scenario.model, key, self.scenario)
            self.rng = np.random.default_rng(seed + 1)
            self.net.rng = np.random.default_rng(seed)
        self.run_seed = self.scenario.seed if seed is None else seed
        self.restart()
        return self

    # -- policies --------------------------------------------------------

    def _resolve_policy(self, policy: PolicyLike):
        if policy is None:
            policy = self.scenario.policy
        if isinstance(policy, str):
            return make_policy(policy, seed=self.run_seed)
        return policy

    # -- the round loop --------------------------------------------------

    def _ensure_policy(self, policy: PolicyLike):
        """Resolve/install the active policy (override > restored >
        scenario default), refusing to silently swap out an unresumable
        checkpointed custom policy."""
        if policy is not None:
            self._policy = self._resolve_policy(policy)
            self._policy_unresumable = False
        elif self._policy is None:
            if self._policy_unresumable:
                raise ValueError(
                    "this checkpoint was taken with an unregistered custom "
                    "policy; pass that policy explicitly to rounds()/run() "
                    "to continue")
            self._policy = self._resolve_policy(None)
        return self._policy

    def rounds(self, policy: PolicyLike = None, *,
               boundary: bool = False) -> Iterator[RoundRecord]:
        """Stream one RoundRecord per remaining round.

        ``policy`` (name or instance) overrides the scenario default; when
        resuming from a checkpoint the restored policy is kept unless a new
        one is passed. ``boundary=True`` adds per-device boundary-activation
        RMS telemetry to each record (one extra fused forward per round).
        """
        self._ensure_policy(policy)
        while self.t < self.scenario.rounds:
            yield self._step(self._policy, boundary)

    def _step(self, policy, boundary: bool) -> RoundRecord:
        sc = self.scenario
        ncfg = self.net.cfg
        t = self.t
        st = self.net.draw()
        prev_queues = self.queues
        ctx = RoundContext(t, self.workload, self.net, st, self.queues,
                           self.gamma, sc.v, losses=self.losses.copy(),
                           inflight=self.engine.inflight_counts(self))
        dec: RoundDecision = policy.schedule(ctx)
        self.queues = dec.queues

        # resolve the schedule into trained gateways + per-device cuts
        trained, l_n, gw_delay, failures = resolve_decision(
            dec, self.gateways, ncfg.n_devices)

        out = self.engine.run_round(self, dec, trained, l_n, gw_delay,
                                    boundary=boundary)
        # Asynchronous engines report *realized* participation: updates that
        # actually landed at the server this round (late arrivals included,
        # churned ones excluded). When it diverges from the schedule, redo
        # Eq. (14) from the pre-decision queues with the realized indicator;
        # when it matches (every synchronous engine, and fault-free async
        # rounds) keep the scheduler's own queues bit-identically.
        if out.realized is not None and \
                not np.array_equal(out.realized, dec.selected):
            self.queues = update_queues_realized(prev_queues, out.realized,
                                                 self.gamma)
        self.delay_sum += out.delay
        self.t = t + 1

        acc = None
        if (t + 1) % sc.eval_every == 0 or t == sc.rounds - 1:
            acc = self.plan.accuracy(self.params,
                                     self.ds.x_test, self.ds.y_test)
        return RoundRecord(t=t, selected=dec.selected.copy(),
                           trained=trained, l_n=l_n, delay=out.delay,
                           cum_delay=self.delay_sum,
                           queues=self.queues.copy(),
                           losses=self.losses.copy(), failures=failures,
                           boundary_rms=out.boundary_rms, accuracy=acc,
                           aggregations=out.aggregations,
                           staleness_mean=out.staleness_mean,
                           staleness_max=out.staleness_max,
                           stale_discarded=out.stale_discarded,
                           dropped_devices=out.dropped_devices,
                           lost_devices=out.lost_devices,
                           straggler_devices=out.straggler_devices,
                           buffer_fill=out.buffer_fill,
                           inflight=out.inflight)

    def run(self, policy: PolicyLike = None, *,
            boundary: bool = False) -> FLResult:
        """Consume the full round loop into an :class:`FLResult`.

        Restarts the run state (round counter, queues, losses) but keeps the
        current params/RNG streams, matching the historical ``FLTrainer.run``
        semantics; call :meth:`reset` first for a from-scratch fair run.
        """
        self.restart()
        records = list(self.rounds(policy, boundary=boundary))
        self.flush()     # any per-round save() has fully landed on return
        return self.result_of(records)

    # -- the fused round loop (repro.fl.fused_sim) -----------------------

    def fused_rounds(self, policy: PolicyLike = None, *,
                     rounds: Optional[int] = None) -> List[RoundRecord]:
        """Run the remaining rounds as fused scans instead of the stepwise
        loop: one compiled decide program (traced policies) or a host
        decide loop, plus ONE compiled training program scanning all
        rounds — same :class:`RoundRecord` stream, same end state
        (bit-identical queues/RNG, params to 1e-5; the parity matrix in
        ``tests/test_fused_sim.py`` pins this). ``rounds`` caps how many
        rounds this call advances (default: all remaining). Intermediate
        ``eval_every`` accuracies are not computed inside the scan — only
        a final-round eval is reported (records keep ``accuracy=None``
        elsewhere).
        """
        from repro.fl import fused_sim
        return fused_sim.fused_rounds(self, self._ensure_policy(policy),
                                      rounds=rounds)

    def run_fused(self, policy: PolicyLike = None) -> FLResult:
        """:meth:`run`, but through :meth:`fused_rounds` — restart the run
        state, execute every round in fused scans, fold the records into
        an :class:`FLResult`."""
        self.restart()
        records = self.fused_rounds(policy)
        self.flush()
        return self.result_of(records)

    def sweep(self, v_values, seeds=None, *,
              rounds: Optional[int] = None, policies=None):
        """Run a scheduling sweep as a single compiled program.

        Draws each seed's channel trajectory host-side under the
        ``reset(seed)`` fairness contract (so sweep lane (s, v) sees
        exactly the ChannelStates a stepwise ``reset(s)`` run at that V
        would), stacks them, and fuses the grid: with ``policies=None``
        runs ``repro.core.ddsra_jax.DDSRAPlan.sweep_states`` — vmap over
        seeds, vmap over V (lanes share a seed's draws), ``lax.scan`` over
        rounds — which requires a traced-decide scenario policy
        (``ddsra_jax``). With ``policies=[...]`` (traced-decide policy
        names) a one-hot policy axis joins the grid and the whole
        policies x seeds x V sweep runs as ONE program
        (``repro.core.policy_sweep`` — the Figs. 4-6 comparison). Returns
        a ``repro.fl.fused_sim.SweepResult``.
        """
        from repro.fl import fused_sim
        return fused_sim.sweep(self, v_values, seeds=seeds, rounds=rounds,
                               policies=policies)

    def result_of(self, records: List[RoundRecord]) -> FLResult:
        """Fold a list of streamed RoundRecords into an :class:`FLResult`."""
        acc = [r.accuracy for r in records if r.accuracy is not None]
        acc_rounds = [r.t + 1 for r in records if r.accuracy is not None]
        return FLResult(
            accuracy=acc, acc_rounds=acc_rounds,
            cum_delay=[r.cum_delay for r in records],
            participation=np.asarray([r.selected for r in records]),
            gamma_targets=self.gamma,
            losses=[float(np.mean(r.losses)) for r in records],
            phi=self.phi,
            failures=sum(r.failures for r in records))

    # -- statistics ------------------------------------------------------

    def estimate_stats(self, params=None,
                       engine: Optional[str] = None) -> DataStats:
        """Online estimators for sigma_n, delta_n, L_n (paper Sec. VII-A)."""
        eng = self.engine if engine is None else make_engine(engine)
        return eng.estimate_stats(
            self, self.params if params is None else params)

    # -- checkpointing ---------------------------------------------------

    def save(self, path, keep_last: Optional[int] = None, *,
             block: bool = False) -> pathlib.Path:
        """Checkpoint params + full run state at round ``self.t``.

        Non-blocking by default: the run state is *snapshotted* on the
        calling thread (cheap — references to immutable jax arrays plus
        small host copies), then a single background writer thread performs
        the actual serialization and atomic renames, so per-round
        checkpointing no longer stalls the round loop on disk I/O. The
        returned path may not exist yet — call :meth:`flush` before reading
        it (or pass ``block=True`` to write inline). Every file lands via
        tmp + ``os.replace``, so a concurrent :meth:`resume` only ever sees
        absent or complete checkpoints, never partial ones.

        ``keep_last`` (default: ``Scenario.keep_last``) rotates the
        checkpoint directory: after this save only the newest ``keep_last``
        round checkpoints survive — the ``step_*.npz`` param files (GC'd by
        ``store.save_pytree``), their ``sim_*.json`` run-state manifests and
        any ``engine_*`` side-cars alike — so per-round saving on long runs
        uses bounded disk.
        """
        if keep_last is None:
            keep_last = self.scenario.keep_last
        path = pathlib.Path(path)
        step = self.t
        params = self.params                       # immutable jax pytree
        pol = None
        if self._policy is not None:
            name = getattr(self._policy, "name", None)
            # only registered names can be reconstructed at resume time; a
            # custom instance is recorded as such so resume can refuse to
            # silently swap in the scenario default mid-experiment.
            pol = {"name": name if name in POLICIES else None,
                   "state": policy_state(self._policy)}
        eng = self.engine.state_dict(self)
        eng_meta, eng_arrays = eng if eng is not None else (None, None)
        state = {
            "scenario": self.scenario.to_json(),
            "t": step,
            "run_seed": self.run_seed,
            "queues": self.queues.tolist(),
            "losses": self.losses.tolist(),
            "delay_sum": self.delay_sum,
            "rng": self.rng.bit_generator.state,
            "net_rng": self.net.rng.bit_generator.state,
            # stats with exact dtypes: phi/gamma recomputation at resume is
            # then bit-identical, and the estimation pass can be skipped.
            "stats": {f.name: _arr_to_json(getattr(self.stats, f.name))
                      for f in dataclasses.fields(self.stats)},
            "policy": pol,
            "engine": eng_meta,
        }
        payload = json.dumps(state).encode()       # serialized pre-submit
        fname = path / f"sim_{step:08d}.json"

        def job():
            store.save_pytree(path, params, step=step, keep_last=keep_last)
            if eng_arrays is not None:
                store.save_pytree(path, eng_arrays, step=step,
                                  prefix="engine")
            store.atomic_write_bytes(fname, lambda f: f.write(payload))
            if keep_last is not None:
                kept = set(store.all_steps(path))  # post-GC param ckpts
                for fam in ("sim", "engine"):
                    for f in path.glob(f"{fam}_*.*"):
                        m = re.match(rf"{fam}_(\d+)\.(json|npz)", f.name)
                        if m and int(m.group(1)) not in kept:
                            f.unlink(missing_ok=True)

        if block:
            self.flush()      # keep FIFO order with pending async saves
            job()
        else:
            if self._ckpt_writer is None:
                self._ckpt_writer = _CheckpointWriter()
            self._ckpt_writer.submit(job)
        return fname

    def flush(self) -> None:
        """Block until every pending non-blocking :meth:`save` has fully
        landed on disk; re-raises the first error any background write hit.
        A no-op when nothing is pending."""
        if self._ckpt_writer is not None:
            self._ckpt_writer.flush()

    @classmethod
    def resume(cls, path) -> "Simulation":
        """Rebuild a Simulation from the latest checkpoint in ``path``.

        The scenario is re-resolved deterministically (topology, dataset;
        the per-device statistics come straight from the manifest, skipping
        the estimation pass), then params and every RNG/queue/loss/policy
        stream are restored, so the continued round loop is bit-identical
        to an uninterrupted run.
        """
        path = pathlib.Path(path)
        step = store.latest_step(path)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {path}")
        state = json.loads((path / f"sim_{step:08d}.json").read_text())
        stats = None
        if "stats" in state:
            stats = DataStats(**{k: _arr_from_json(v)
                                 for k, v in state["stats"].items()})
        sim = cls(Scenario.from_json(state["scenario"]), _stats=stats)
        sim.params = store.load_pytree(path / f"step_{step:08d}.npz",
                                       like=sim.params)
        sim.t = state["t"]
        sim.run_seed = state.get("run_seed", sim.scenario.seed)
        sim.queues = np.asarray(state["queues"])
        sim.losses = np.asarray(state["losses"])
        sim.delay_sum = state["delay_sum"]
        sim.rng.bit_generator.state = state["rng"]
        sim.net.rng.bit_generator.state = state["net_rng"]
        pol = state.get("policy")
        if pol:
            if pol.get("name"):
                sim._policy = make_policy(pol["name"], seed=sim.run_seed)
                set_policy_state(sim._policy, pol.get("state"))
            else:
                sim._policy_unresumable = True
        eng_meta = state.get("engine")
        if eng_meta is not None:
            sim.engine.load_state_dict(sim, eng_meta, path, step)
        return sim


def _arr_to_json(a: np.ndarray) -> dict:
    a = np.asarray(a)
    return {"data": a.tolist(), "dtype": str(a.dtype)}


def _arr_from_json(d: dict) -> np.ndarray:
    return np.asarray(d["data"], dtype=d["dtype"])


def _unflatten_like(flat: np.ndarray, tree):
    """Split a flat vector back into leaves shaped like ``tree``."""
    leaves = jax.tree.leaves(tree)
    out, i = [], 0
    for leaf in leaves:
        n = leaf.size
        out.append(np.asarray(flat[i:i + n]).reshape(leaf.shape)
                   .astype(leaf.dtype))
        i += n
    return out


# Registers ShardedCohortEngine under "sharded" and AsyncCohortEngine under
# "async" in ENGINES. Must stay at the bottom: both modules subclass
# CohortEngine from this module.
import repro.fl.shard  # noqa: E402,F401
import repro.fl.async_engine  # noqa: E402,F401
