"""End-to-end FL simulation: scheduler in the loop, real JAX training.

Wires together the network/energy environment (repro.core.network), the
DDSRA scheduler or a baseline (repro.core.schedulers), the layer-level cost
model (repro.core.costmodel) and real split training (repro.fl.split) into
the paper's two-tier FL loop.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import numpy as np

from repro.core import costmodel as cm
from repro.core.ddsra import Workload
from repro.core.network import Network, NetworkConfig
from repro.core.participation import (DataStats, divergence_bound,
                                      participation_rates)
from repro.core.schedulers import SCHEDULERS, RoundContext
from repro.fl import cohort as cohort_lib
from repro.fl import split as split_lib
from repro.fl.data import (FLDataset, make_fl_dataset, sample_batch,
                           sample_cohort_batch)
from repro.fl.roles import BaseStation, Device, Gateway, fedavg
from repro.models import vgg


@dataclasses.dataclass
class FLConfig:
    model: str = "vgg"            # vgg | mlp
    width_mult: float = 0.25
    classes: int = 10
    k_iters: int = 5              # local epochs K
    lr: float = 0.01              # step size beta
    alpha: float = 0.05           # training data sampling ratio
    rounds: int = 50
    v: float = 0.01               # Lyapunov control parameter
    scheduler: str = "ddsra"
    seed: int = 0
    eval_every: int = 5
    max_dataset: int = 2000
    chi: float = 1.0              # non-IID degree
    sigma_samples: int = 8        # per-sample grads for sigma estimation
    engine: str = "cohort"        # cohort (fused/jitted) | sequential (seed)
    boundary_telemetry: bool = False  # per-device boundary-activation RMS


@dataclasses.dataclass
class FLResult:
    accuracy: List[float]
    acc_rounds: List[int]
    cum_delay: List[float]
    participation: np.ndarray     # (T, M)
    gamma_targets: np.ndarray
    losses: List[float]
    phi: np.ndarray
    failures: int


class FLTrainer:
    def __init__(self, cfg: FLConfig, net_cfg: Optional[NetworkConfig] = None):
        self.cfg = cfg
        self.net = Network(net_cfg or NetworkConfig(),
                           np.random.default_rng(cfg.seed))
        self.rng = np.random.default_rng(cfg.seed + 1)
        ncfg = self.net.cfg

        # local dataset sizes D_n ~ U(0, 2000]; training batch D~_n = alpha*D_n
        self.d_sizes = np.maximum(
            (self.rng.uniform(0, cfg.max_dataset, ncfg.n_devices)).astype(int), 40)
        self.d_tilde = np.maximum((cfg.alpha * self.d_sizes).astype(int), 4)

        # non-IID classes: gateway 0's devices see the widest variety
        # (paper Sec. VII-B: "the 1-th gateway ... a wider variety")
        q = np.zeros(ncfg.n_devices, dtype=int)
        for n in range(ncfg.n_devices):
            gw = self.net.assign[n]
            q[n] = cfg.classes if gw == 0 else int(self.rng.integers(1, 4))
        self.ds = make_fl_dataset(ncfg.n_devices, self.d_sizes, q,
                                  chi=cfg.chi, classes=cfg.classes,
                                  seed=cfg.seed)

        # model + layer-level costs (paper Table II)
        key = jax.random.PRNGKey(cfg.seed)
        if cfg.model == "vgg":
            self.plan, params = vgg.init_vgg11(key, cfg.width_mult, cfg.classes)
            self.layers = cm.vgg11_layers(cfg.width_mult, classes=cfg.classes)
        else:
            sizes = (3072, 128, 64, cfg.classes)
            self.plan, params = vgg.init_mlp(key, sizes)
            self.layers = vgg.mlp_layer_costs(sizes)
        self.bs = BaseStation(self.plan, params)

        o = cm.flops_vector(self.layers)
        g = cm.mem_vector(self.layers, batch=int(self.d_tilde.max()))
        self.workload = Workload(o, g, cm.model_size_bytes(self.layers),
                                 cfg.k_iters, self.d_tilde.astype(float))

        self.gateways = [
            Gateway(m, [Device(int(n), m, int(self.d_sizes[n]), int(self.d_tilde[n]))
                        for n in self.net.devices_of(m)])
            for m in range(ncfg.n_gateways)]

        # the scheduler can select at most n_channels gateways per round
        # (C2/C3), so this many slots always fit every round's participants;
        # packing into them skips compute for absent devices at fixed shapes.
        per_gw = int(np.bincount(self.net.assign,
                                 minlength=ncfg.n_gateways).max())
        self.cohort_capacity = min(ncfg.n_devices, ncfg.n_channels * per_gw)

        self.last_boundary_rms: Optional[np.ndarray] = None
        t0 = time.perf_counter()
        self.stats = self.estimate_stats(params)
        self.stats_seconds = time.perf_counter() - t0  # for fl_round_bench
        self.phi = divergence_bound(self.stats, self.net.assign,
                                    cfg.lr, cfg.k_iters)
        self.gamma = participation_rates(self.phi, ncfg.n_channels)

    # ------------------------------------------------------------------
    def estimate_stats(self, params, engine: Optional[str] = None) -> DataStats:
        """Online estimators for sigma_n, delta_n, L_n (paper Sec. VII-A).

        The cohort engine computes all devices' statistics in one jitted
        vmap-of-vmap per-sample-grad program; "sequential" keeps the seed's
        O(devices x samples) loop as the parity/benchmark reference.
        """
        if _check_engine(engine or self.cfg.engine) == "sequential":
            return self._estimate_stats_sequential(params)
        cfg = self.cfg
        n_dev = self.net.cfg.n_devices
        batch = sample_cohort_batch(self.rng, self.ds, range(n_dev),
                                    self.d_tilde, int(self.d_tilde.max()))
        mix = self.d_sizes / self.d_sizes.sum()
        sigma, delta, lips = cohort_lib.cohort_stats(
            self.plan, params, batch, mix, cfg.lr, cfg.sigma_samples)
        return DataStats(np.asarray(sigma), np.asarray(delta),
                         np.maximum(np.asarray(lips), 0.1),
                         self.d_tilde.astype(float))

    def _estimate_stats_sequential(self, params) -> DataStats:
        cfg = self.cfg
        n_dev = self.net.cfg.n_devices
        grads, sigmas, lips = [], [], []
        for n in range(n_dev):
            x, y = sample_batch(self.rng, self.ds, n, self.d_tilde[n])
            g = np.asarray(split_lib.flat_grad(self.plan, params, x, y))
            grads.append(g)
            # sigma: per-sample gradient spread
            m_s = min(cfg.sigma_samples, len(y))
            per = [np.asarray(split_lib.flat_grad(self.plan, params,
                                                  x[i:i + 1], y[i:i + 1]))
                   for i in range(m_s)]
            mean_g = np.mean(per, axis=0)
            sigmas.append(float(np.mean([np.linalg.norm(p - mean_g) for p in per])))
            # L_n: two-point secant
            w0 = split_lib.flat_params(params)
            pert = jax.tree.map(
                lambda p_, gg: p_ - cfg.lr * gg,
                params, jax.tree.unflatten(jax.tree.structure(params),
                                           _unflatten_like(g, params)))
            g2 = np.asarray(split_lib.flat_grad(self.plan, pert, x, y))
            w1 = split_lib.flat_params(pert)
            dw = np.linalg.norm(np.asarray(w1) - np.asarray(w0))
            lips.append(float(np.linalg.norm(g2 - g) / max(dw, 1e-9)))
        weights = self.d_sizes / self.d_sizes.sum()
        global_g = np.sum([w * g for w, g in zip(weights, grads)], axis=0)
        deltas = [float(np.linalg.norm(g - global_g)) for g in grads]
        return DataStats(np.asarray(sigmas), np.asarray(deltas),
                         np.maximum(np.asarray(lips), 0.1),
                         self.d_tilde.astype(float))

    # ------------------------------------------------------------------
    def run(self, scheduler_name: Optional[str] = None,
            engine: Optional[str] = None) -> FLResult:
        cfg = self.cfg
        ncfg = self.net.cfg
        engine = _check_engine(engine or cfg.engine)
        name = scheduler_name or cfg.scheduler
        sched_cls = SCHEDULERS[name]
        scheduler = sched_cls() if name != "random" else sched_cls(cfg.seed)

        queues = np.zeros(ncfg.n_gateways)
        losses = np.full(ncfg.n_gateways, np.log(cfg.classes))
        acc, acc_rounds, cum_delay, parts, loss_hist = [], [], [], [], []
        delay_sum, failures = 0.0, 0

        for t in range(cfg.rounds):
            st = self.net.draw()
            ctx = RoundContext(t, self.workload, self.net, st, queues,
                               self.gamma, cfg.v, losses=losses.copy())
            dec = scheduler.schedule(ctx)
            queues = dec.queues
            parts.append(dec.selected.copy())

            # resolve the schedule into trained gateways + per-device cuts
            trained, l_n = [], np.zeros(ncfg.n_devices, int)
            round_delay = 0.0
            for m in np.where(dec.selected)[0]:
                j = int(np.argmax(dec.assignment[m]))
                sol = dec.solutions.get((int(m), j))
                if sol is None:
                    continue
                if not sol.feasible or not np.isfinite(sol.delay):
                    failures += 1     # energy/memory violation: round fails
                    continue
                round_delay = max(round_delay, sol.delay)
                trained.append(int(m))
                for i, dev in enumerate(self.gateways[m].devices):
                    l_n[dev.idx] = int(sol.l_split[i])

            if engine == "sequential":
                self._sequential_round(trained, l_n, losses)
            elif trained:
                self._cohort_round(trained, l_n, losses)
            delay_sum += round_delay
            cum_delay.append(delay_sum)
            loss_hist.append(float(np.mean(losses)))

            if (t + 1) % cfg.eval_every == 0 or t == cfg.rounds - 1:
                acc.append(vgg.accuracy(self.plan, self.bs.params,
                                        self.ds.x_test, self.ds.y_test))
                acc_rounds.append(t + 1)

        return FLResult(acc, acc_rounds, cum_delay, np.asarray(parts),
                        self.gamma, loss_hist, self.phi, failures)

    # ------------------------------------------------------------------
    def _sequential_round(self, trained: List[int], l_n: np.ndarray,
                          losses: np.ndarray) -> None:
        """Seed per-device Python loop (kept as the parity/bench reference)."""
        cfg = self.cfg
        models, weights = [], []
        for m in trained:
            gw = self.gateways[m]
            l_splits = np.asarray([l_n[d.idx] for d in gw.devices])
            combined, gw_loss, w_m = gw.shop_floor_round(
                self.plan, self.bs.params, self.ds, l_splits,
                cfg.k_iters, cfg.lr, self.rng)
            models.append(combined)
            weights.append(w_m)
            losses[m] = gw_loss
        self.bs.aggregate(models, np.asarray(weights))

    def _cohort_round(self, trained: List[int], l_n: np.ndarray,
                      losses: np.ndarray) -> None:
        """One fused XLA program for the whole (devices x K epochs) round,
        FedAvg included; a single host sync reads the per-gateway losses.
        Participants are packed into ``cohort_capacity`` fixed slots."""
        cfg = self.cfg
        device_ids: List[int] = []
        for m in trained:
            device_ids.extend(dev.idx for dev in self.gateways[m].devices)
        # capacity always fits a schedulable round; fall back to the all-
        # devices layout (one extra compile, same numerics) if it ever won't
        cap = self.cohort_capacity if len(device_ids) <= self.cohort_capacity \
            else self.net.cfg.n_devices
        l_slot = np.zeros(cap, int)
        w_slot = np.zeros(cap, np.float32)
        slot_gw = np.zeros((cap, self.net.cfg.n_gateways), np.float32)
        for s, n in enumerate(device_ids):
            l_slot[s] = l_n[n]
            w_slot[s] = self.d_tilde[n]
            slot_gw[s, self.net.assign[n]] = 1.0
        batch = sample_cohort_batch(self.rng, self.ds, device_ids,
                                    self.d_tilde, int(self.d_tilde.max()),
                                    capacity=cap)
        new_global, gw_loss, _, _, boundary = cohort_lib.cohort_round(
            self.plan, self.bs.params, batch, l_slot, w_slot, slot_gw,
            cfg.k_iters, cfg.lr, with_boundary=cfg.boundary_telemetry)
        self.bs.params = new_global
        if cfg.boundary_telemetry:
            rms = np.zeros(self.net.cfg.n_devices)
            rms[device_ids] = np.asarray(boundary)[:len(device_ids)]
            self.last_boundary_rms = rms
        gw_loss = np.asarray(gw_loss)
        for m in trained:
            losses[m] = float(gw_loss[m])


def _check_engine(engine: str) -> str:
    if engine not in ("cohort", "sequential"):
        raise ValueError(f"unknown engine {engine!r}: "
                         f"expected 'cohort' or 'sequential'")
    return engine


def _unflatten_like(flat: np.ndarray, tree):
    """Split a flat vector back into leaves shaped like ``tree``."""
    leaves = jax.tree.leaves(tree)
    out, i = [], 0
    for leaf in leaves:
        n = leaf.size
        out.append(np.asarray(flat[i:i + n]).reshape(leaf.shape).astype(leaf.dtype))
        i += n
    return out
