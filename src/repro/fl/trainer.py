"""Deprecated FLTrainer/FLConfig shim over ``repro.fl.sim``.

The FL simulation surface moved to the composable Scenario / Policy / Engine
API in ``repro.fl.sim`` (see ``src/repro/fl/README.md`` for the migration
table). This module keeps the historical ``FLTrainer(FLConfig(...)).run()``
entry point working by delegating every attribute to an underlying
:class:`repro.fl.sim.Simulation`, so existing call sites — including ones
that poke trainer internals like ``tr.bs.params = ...`` or ``tr.rng = ...``
— behave exactly as before.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.core.network import NetworkConfig
from repro.fl.sim import FLResult, Scenario, Simulation, make_engine

__all__ = ["FLConfig", "FLResult", "FLTrainer"]


@dataclasses.dataclass
class FLConfig:
    """Deprecated: use ``repro.fl.sim.Scenario`` (same fields, plus the
    network config embedded as ``net`` and ``scheduler`` renamed ``policy``)."""
    model: str = "vgg"            # repro.models.registry.FL_MODELS key
    width_mult: float = 0.25
    classes: int = 10
    k_iters: int = 5              # local epochs K
    lr: float = 0.01              # step size beta
    alpha: float = 0.05           # training data sampling ratio
    rounds: int = 50
    v: float = 0.01               # Lyapunov control parameter
    scheduler: str = "ddsra"
    seed: int = 0
    eval_every: int = 5
    max_dataset: int = 2000
    chi: float = 1.0              # non-IID degree
    sigma_samples: int = 8        # per-sample grads for sigma estimation
    engine: str = "cohort"        # cohort (fused/jitted) | sequential (seed)
    boundary_telemetry: bool = False  # per-device boundary-activation RMS

    def to_scenario(self, net_cfg: Optional[NetworkConfig] = None) -> Scenario:
        """Translate this legacy config into the equivalent Scenario."""
        return Scenario(
            model=self.model, width_mult=self.width_mult,
            classes=self.classes, k_iters=self.k_iters, lr=self.lr,
            alpha=self.alpha, rounds=self.rounds, v=self.v,
            policy=self.scheduler, seed=self.seed,
            eval_every=self.eval_every, max_dataset=self.max_dataset,
            chi=self.chi, sigma_samples=self.sigma_samples,
            engine=self.engine, net=net_cfg or NetworkConfig())


class FLTrainer:
    """Deprecated facade over :class:`repro.fl.sim.Simulation`."""

    def __init__(self, cfg: FLConfig, net_cfg: Optional[NetworkConfig] = None):
        self.cfg = cfg
        self.sim = Simulation(cfg.to_scenario(net_cfg))
        self.last_boundary_rms: Optional[np.ndarray] = None

    # every piece of historical trainer state delegates to the Simulation,
    # so external mutation (tr.rng = ..., tr.bs.params = ...) stays visible
    # to the round loop.
    _DELEGATED = ("net", "rng", "ds", "d_sizes", "d_tilde", "plan", "layers",
                  "bs", "workload", "gateways", "cohort_capacity", "stats",
                  "stats_seconds", "phi", "gamma")

    def __getattr__(self, name):
        if name in FLTrainer._DELEGATED:
            return getattr(self.sim, name)
        raise AttributeError(name)

    def __setattr__(self, name, value):
        if name in FLTrainer._DELEGATED:
            setattr(self.sim, name, value)
        else:
            object.__setattr__(self, name, value)

    def estimate_stats(self, params, engine: Optional[str] = None):
        """Deprecated alias for ``Simulation.estimate_stats``."""
        return self.sim.estimate_stats(params, engine=engine)

    def run(self, scheduler_name: Optional[str] = None,
            engine: Optional[str] = None) -> FLResult:
        """Deprecated alias for ``Simulation.run`` (plus the historical
        ``boundary_telemetry`` / per-call ``engine`` override semantics)."""
        old_engine = self.sim.engine
        if engine is not None:
            self.sim.engine = make_engine(engine)
        try:
            if not self.cfg.boundary_telemetry:
                return self.sim.run(scheduler_name)
            self.sim.restart()
            records: List = []
            for rec in self.sim.rounds(scheduler_name, boundary=True):
                records.append(rec)
                if rec.boundary_rms is not None:
                    self.last_boundary_rms = rec.boundary_rms
            return self.sim.result_of(records)
        finally:
            self.sim.engine = old_engine
