"""Split forward/backward across the DNN partition point.

Implements the paper's mechanism exactly (Sec. II-B3): the device runs the
bottom ``l`` blocks forward and ships the boundary activation to the gateway;
the gateway runs the top blocks, computes the loss, backpropagates to the
boundary and returns the boundary *error*; the device completes backward for
the bottom blocks. Only the boundary activation/error and labels cross the
tier boundary — never raw inputs or intermediate weights.

Everything here is model-agnostic: ``model`` is any
``repro.models.split_model.SplitModel`` handle (hashable, so it rides jit
static arguments), and ``params`` is its matching per-block list.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.split_model import Params, SplitModel


def device_forward(model: SplitModel, bottom: Params, x: jax.Array, l: int):
    """Bottom-block forward with a VJP handle kept device-side."""
    act, vjp = jax.vjp(lambda p: model.forward_range(p, x, 0, l), bottom)
    return act, vjp


def gateway_step(model: SplitModel, top: Params, act: jax.Array,
                 labels: jax.Array, l: int):
    """Top-block forward+backward. Returns loss, top grads, boundary error."""
    def loss_of(p, a):
        logits = model.forward_range([None] * l + p, a, l, model.n_blocks)
        return model.loss(logits, labels)

    loss, (g_top, g_act) = jax.value_and_grad(loss_of, argnums=(0, 1))(top, act)
    return loss, g_top, g_act


@functools.partial(jax.jit, static_argnums=(0, 3))
def split_sgd_step(model: SplitModel, params: Params, batch_xy, l: int, lr):
    """One local iteration of split training at partition point ``l``."""
    x, labels = batch_xy
    bottom, top = params[:l], params[l:]
    act, vjp = device_forward(model, bottom, x, l)
    loss, g_top, g_act = gateway_step(model, top, act, labels, l)
    (g_bottom,) = vjp(g_act)

    def sgd(p, g):
        return jax.tree.map(lambda w, gw: w - lr * gw, p, g)

    new_params = sgd(bottom, g_bottom) + sgd(top, g_top)
    return new_params, loss


@functools.partial(jax.jit, static_argnames=("model", "k_iters"))
def _local_sgd(model: SplitModel, params: Params, x, y, k_iters: int, lr):
    """K split-SGD epochs as one scan. The partition point drops out of the
    math (split ≡ unsplit — pinned by the parity tests), so one program
    covers every ``l`` and the loss carry stays on device."""
    def step(p, _):
        loss, g = jax.value_and_grad(
            lambda pp: model.loss(model.forward(pp, x), y))(p)
        return jax.tree.map(lambda w, gw: w - lr * gw, p, g), loss

    params, losses = jax.lax.scan(step, params, None, length=k_iters)
    return params, losses[-1]


def local_train(model: SplitModel, params: Params, x, y, l: int, k_iters: int,
                lr: float) -> Tuple[Params, float]:
    """K local epochs over the sampled batch (paper's update rule).

    One jitted program regardless of ``l`` (no per-partition-point re-jit),
    one host transfer for the final loss (no per-iteration sync).
    """
    del l  # numerically irrelevant: split training ≡ unsplit SGD
    params, loss = _local_sgd(model, params, x, y, k_iters, jnp.float32(lr))
    return params, float(loss)


# --- gradient statistics for the participation-rate estimators -------------


@functools.partial(jax.jit, static_argnums=(0,))
def flat_grad(model: SplitModel, params: Params, x, y) -> jnp.ndarray:
    def loss_of(p):
        return model.loss(model.forward(p, x), y)
    g = jax.grad(loss_of)(params)
    return jnp.concatenate([l_.ravel() for l_ in jax.tree.leaves(g)])


def flat_params(params) -> jnp.ndarray:
    return jnp.concatenate([l_.ravel() for l_ in jax.tree.leaves(params)])
