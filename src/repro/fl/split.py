"""Split forward/backward across the DNN partition point.

Implements the paper's mechanism exactly (Sec. II-B3): the device runs the
bottom ``l`` layers forward and ships the boundary activation to the gateway;
the gateway runs the top layers, computes the loss, backpropagates to the
boundary and returns the boundary *error*; the device completes backward for
the bottom layers. Only the boundary activation/error and labels cross the
tier boundary — never raw inputs or intermediate weights.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models import vgg
from repro.models.vgg import Params, Plan


def device_forward(plan: Plan, bottom: Params, x: jax.Array, l: int):
    """Bottom-layer forward with a VJP handle kept device-side."""
    act, vjp = jax.vjp(lambda p: vgg.forward_range(plan, p, x, 0, l), bottom)
    return act, vjp


def gateway_step(plan: Plan, top: Params, act: jax.Array, labels: jax.Array,
                 l: int):
    """Top-layer forward+backward. Returns loss, top grads, boundary error."""
    def loss_of(p, a):
        logits = vgg.forward_range(plan, [None] * l + p, a, l, len(plan))
        return vgg.xent_loss(logits, labels)

    loss, (g_top, g_act) = jax.value_and_grad(loss_of, argnums=(0, 1))(top, act)
    return loss, g_top, g_act


@functools.partial(jax.jit, static_argnums=(0, 3))
def split_sgd_step(plan: Plan, params: Params, batch_xy, l: int, lr):
    """One local iteration of split training at partition point ``l``."""
    x, labels = batch_xy
    bottom, top = params[:l], params[l:]
    act, vjp = device_forward(plan, bottom, x, l)
    loss, g_top, g_act = gateway_step(plan, top, act, labels, l)
    (g_bottom,) = vjp(g_act)

    def sgd(p, g):
        return jax.tree.map(lambda w, gw: w - lr * gw, p, g)

    new_params = sgd(bottom, g_bottom) + sgd(top, g_top)
    return new_params, loss


def local_train(plan: Plan, params: Params, x, y, l: int, k_iters: int,
                lr: float) -> Tuple[Params, float]:
    """K local epochs over the sampled batch (paper's update rule)."""
    loss = jnp.inf
    lr = jnp.float32(lr)
    for _ in range(k_iters):
        params, loss = split_sgd_step(plan, params, (x, y), l, lr)
    return params, float(loss)


# --- gradient statistics for the participation-rate estimators -------------


@functools.partial(jax.jit, static_argnums=(0,))
def flat_grad(plan: Plan, params: Params, x, y) -> jnp.ndarray:
    def loss_of(p):
        return vgg.xent_loss(vgg.forward(plan, p, x), y)
    g = jax.grad(loss_of)(params)
    return jnp.concatenate([l_.ravel() for l_ in jax.tree.leaves(g)])


def flat_params(params) -> jnp.ndarray:
    return jnp.concatenate([l_.ravel() for l_ in jax.tree.leaves(params)])
