"""Batched cohort split-training engine: one XLA program per FL round.

The seed trainer executed the cohort one device at a time — a fresh jitted
``split_sgd_step`` per device per local epoch, retraced for every distinct
partition point ``l`` (a static argnum) and batch shape, with a ``float(loss)``
host sync after every step. This module replaces that with a single fused
program per round:

* per-device parameters are a struct-of-arrays pytree (leading device axis),
* ``jax.vmap`` runs the split forward/backward for the whole cohort at once,
* ``jax.lax.scan`` iterates the K local epochs inside the same program,
* the shop-floor + base-station FedAvg reduction is fused into the end of the
  step, so nothing round-trips to the host until the round result is read.

**Partition point handled as data (masking, not bucketing).** Split training
at partition point ``l`` computes *exactly* the same parameter update as
unsplit SGD — the boundary activation/error exchange is mathematically
transparent (proved by ``tests/test_split_training.py``). The engine
therefore executes the mathematically-equal fused forward/backward once per
device and keeps ``l_n`` a *traced per-device array*: it selects, per device,
which layer boundary's activation statistics are reported (the tensor that
would cross the device→gateway link), via a masked gather over the stacked
per-layer activation norms. The alternative — bucketing devices by ``l`` and
running a separate two-segment program per bucket — would compile
``O(distinct l)`` programs, reintroduce per-bucket host syncs, and change
shapes whenever the scheduler's partition decisions change; masking compiles
exactly once for all rounds, device subsets and partition vectors. The
tradeoff is that per-tier work is not physically separated on one host — the
tier *accounting* (delay/energy) lives in ``repro.core.costmodel``, which is
where the paper keeps it too.

Fixed-shape batching contract: inputs come from
``repro.fl.data.sample_cohort_batch`` — padded slots with a row-validity
mask, all slots present every round, non-participants zero-masked and
zero-weighted — so varying device subsets never retrace. Slots may use
**tiered widths** (``repro.fl.data.CohortLayout``): slot *i* is padded to
roughly the i-th largest global ``d_tilde`` instead of the global maximum,
and the fused program runs one ``vmap`` segment per tier — same single
compile, a fraction of the padded samples. The per-slot helpers here
(`_local_train`, `_boundary_rms`, `_grads_sigma_lips`) are shared with the
`jax.shard_map`-sharded engine in ``repro.fl.shard``, which wraps them in a
mapped body and turns the FedAvg reductions into masked ``psum`` s.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.fl.data import TieredCohortBatch
from repro.fl.data import traced_batch_indices as _traced_indices
from repro.fl.split import flat_params as _flat
from repro.models.split_model import Params, SplitModel

# Incremented inside the traced bodies (Python side effects run only at trace
# time), so tests/benchmarks can assert "exactly one compile across rounds".
# "round"/"stats" count per-round program traces; "train_scan" counts traces
# of the whole-run fused training loop (repro.fl.fused_sim).
TRACE_COUNTS = {"round": 0, "stats": 0, "train_scan": 0}


def _unflatten_stacked(flat_nd: jnp.ndarray, like):
    """(N, P) flat rows -> pytree like ``like`` with leading device axis."""
    leaves, treedef = jax.tree.flatten(like)
    out, i = [], 0
    for leaf in leaves:
        sz = leaf.size
        out.append(flat_nd[:, i:i + sz]
                   .reshape((flat_nd.shape[0],) + leaf.shape)
                   .astype(leaf.dtype))
        i += sz
    return jax.tree.unflatten(treedef, out)


def _masked_rms(a: jax.Array, mask: jax.Array) -> jax.Array:
    """RMS over the valid rows of a (B, ...) activation."""
    a2 = a.reshape(a.shape[0], -1).astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(mask), 1.0) * a2.shape[1]
    return jnp.sqrt(jnp.sum(a2 * a2 * mask[:, None]) / denom)


def _boundary_rms(model: SplitModel, params: Params, x, mask, l) -> jax.Array:
    """RMS of the activation crossing the device->gateway boundary at cut
    ``l`` (a traced int: l=0 ships the raw input, l=model.n_blocks ships
    logits — i.e. everything ran device-side)."""
    norms = [_masked_rms(a, mask) for a in model.activations(params, x)]
    return jnp.take(jnp.stack(norms), l)


# ---------------------------------------------------------------------------
# shared per-slot building blocks (single-host cohort AND sharded engine)
# ---------------------------------------------------------------------------


def _maybe_flatten(model: SplitModel, xs: Tuple[jax.Array, ...]):
    """Per-model input prep, once per round (not inside every scanned
    epoch) — e.g. all-fc stacks flatten images, token models pass through."""
    return tuple(model.prepare_inputs(x) for x in xs)


# Scenario.dtype -> the dtype activations/weights are *computed and shipped*
# in. Master parameters, optimizer math and the stats pass stay float32; the
# control plane (DDSRA) stays x64 (see repro.core.ddsra).
COMPUTE_DTYPES = {"f32": None, "bf16": jnp.bfloat16}


def _cast_floats(tree, dtype):
    """Cast floating leaves of a pytree (bf16 storage/HBM traffic; non-float
    leaves untouched). ``dtype=None`` is the identity."""
    if dtype is None:
        return tree
    return jax.tree.map(
        lambda a: a.astype(dtype)
        if jnp.issubdtype(a.dtype, jnp.floating) else a, tree)


def _local_train(model: SplitModel, params: Params, xs, ys, masks,
                 k_iters: int, lr, compute_dtype: str = "f32"):
    """K local SGD epochs for every slot: one ``vmap`` segment per tier
    inside one ``lax.scan`` over the epochs.

    ``xs/ys/masks`` are per-tier tuples (tier k: ``(S_k, W_k, ...)``).
    Returns (per-tier stacked final params, per-tier last-epoch losses) in
    the same tuple-of-tiers form, so callers control whether slots are
    concatenated locally (single host) or reduced via ``psum`` (sharded).

    ``compute_dtype="bf16"`` runs the forward/backward GEMMs in bfloat16
    (mixed precision): master params stay f32, the cast happens *inside* the
    loss closure so ``value_and_grad`` differentiates through it and the
    gradients come back f32 against the f32 masters; the Pallas kernels
    accumulate in f32 VMEM scratch regardless of operand dtype, and the
    logits are promoted to f32 before the cross-entropy reduction.
    """
    cdt = COMPUTE_DTYPES[compute_dtype]
    stacked = tuple(
        jax.tree.map(lambda p: jnp.broadcast_to(p, (x.shape[0],) + p.shape),
                     params)
        for x in xs)

    def dev_step(p, xb, yb, mb):
        def loss_of(pp):
            logits = model.forward(_cast_floats(pp, cdt),
                                   _cast_floats(xb, cdt))
            return model.masked_loss(logits.astype(jnp.float32), yb, mb)
        loss, g = jax.value_and_grad(loss_of)(p)
        new_p = jax.tree.map(lambda w_, g_: w_ - lr * g_, p, g)
        return new_p, loss

    def one_epoch(p_stacks, _):
        outs = [jax.vmap(dev_step)(p, x, y, m)
                for p, x, y, m in zip(p_stacks, xs, ys, masks)]
        return tuple(o[0] for o in outs), tuple(o[1] for o in outs)

    final, loss_hist = jax.lax.scan(one_epoch, stacked, None, length=k_iters)
    # last-epoch losses: matching the sequential path's "last
    # split_sgd_step" loss semantics.
    return final, tuple(lh[-1] for lh in loss_hist)


def _boundary_tiers(model: SplitModel, finals, xs, masks, ls):
    """Per-slot boundary-activation RMS, one vmap segment per tier."""
    return tuple(
        jax.vmap(lambda p, xb, mb, l: _boundary_rms(model, p, xb, mb, l))(
            f, x, m, l)
        for f, x, m, l in zip(finals, xs, masks, ls))


def _split_tiers(v, sizes: Tuple[int, ...]):
    """Split a tier-major per-slot vector/matrix into per-tier pieces."""
    out, off = [], 0
    for s in sizes:
        out.append(v[off:off + s])
        off += s
    return tuple(out)


def _concat_tiers(tree_tuple):
    """Concatenate a tuple of pytrees along the leading (slot) axis."""
    if len(tree_tuple) == 1:
        return tree_tuple[0]
    return jax.tree.map(lambda *ls: jnp.concatenate(ls), *tree_tuple)


def _batch_tiers(batch):
    """(xs, ys, masks) per-tier tuples from a CohortBatch or
    TieredCohortBatch — single-width batches become one-tier tuples."""
    tiers = batch.tiers if isinstance(batch, TieredCohortBatch) else (batch,)
    return (tuple(jnp.asarray(t.x) for t in tiers),
            tuple(jnp.asarray(t.y) for t in tiers),
            tuple(jnp.asarray(t.mask) for t in tiers))


# ---------------------------------------------------------------------------
# one FL round: (devices x K local epochs + FedAvg) fused
# ---------------------------------------------------------------------------


def cohort_round_traced(model: SplitModel, params: Params, xs, ys, masks, l_n,
                        weights, gw_onehot, lr, *, k_iters: int,
                        with_boundary: bool,
                        with_gateway_models: bool = False,
                        compute_dtype: str = "f32"):
    """The fused round as a plain traced function: the body behind the
    per-round jit below, *and* the scan step of the whole-run fused
    training loop (:func:`train_scan` / ``repro.fl.fused_sim``) — one
    implementation, two compilation granularities."""
    TRACE_COUNTS["round"] += 1
    xs = _maybe_flatten(model, xs)
    sizes = tuple(x.shape[0] for x in xs)
    final_t, loss_t = _local_train(model, params, xs, ys, masks, k_iters, lr,
                                   compute_dtype)
    final = _concat_tiers(final_t)
    dev_losses = jnp.concatenate(loss_t)

    # fused two-tier FedAvg: gateway-level then BS-level weighted averaging
    # telescopes to one weighted average over participating devices.
    w = weights / jnp.maximum(jnp.sum(weights), 1e-12)
    new_global = jax.tree.map(lambda s: jnp.tensordot(w, s, axes=1), final)

    active = (weights > 0).astype(jnp.float32)
    gw_count = gw_onehot.T @ active                                 # (M,)
    gw_loss = (gw_onehot.T @ (dev_losses * active)) / jnp.maximum(gw_count, 1.0)

    if with_boundary:
        boundary = jnp.concatenate(_boundary_tiers(
            model, final_t, xs, masks, _split_tiers(l_n, sizes)))
    else:    # skip the extra forward pass; l_n stays unused data
        boundary = jnp.zeros_like(weights)

    if with_gateway_models:
        # per-gateway shop-floor FedAvg before the global mix: columns of the
        # (N, M) incidence, weighted by d_tilde and normalized per gateway.
        gw_w = gw_onehot * weights[:, None]
        gw_w = gw_w / jnp.maximum(jnp.sum(gw_w, axis=0, keepdims=True), 1e-12)
        gw_models = jax.tree.map(
            lambda s: jnp.tensordot(gw_w.T, s, axes=1), final)   # (M, ...)
    else:
        gw_models = None

    return new_global, gw_loss, gw_count, dev_losses, boundary, gw_models


_cohort_round = functools.partial(
    jax.jit, static_argnames=("model", "k_iters", "with_boundary",
                              "with_gateway_models", "compute_dtype")
)(cohort_round_traced)


def _eval_hits(model: SplitModel, params: Params, x_eval, y_test, ev_t):
    """``lax.cond``-gated in-scan accuracy snapshot: hit count over the
    full (prepared) test set after this round's update, or -1 on rounds
    ``eval_every`` skips. Runs on the f32 master params, so it equals the
    stepwise loop's post-round ``SplitModel.accuracy`` hit count exactly
    (one full-batch forward; chunking does not change integer hits)."""

    def hits(p):
        logits = model.forward(p, x_eval)
        return jnp.sum(jnp.argmax(logits, -1) == y_test).astype(jnp.int32)

    return jax.lax.cond(ev_t, hits, lambda p: jnp.int32(-1), params)


@functools.partial(jax.jit,
                   static_argnames=("model", "k_iters", "compute_dtype"))
def train_scan(model: SplitModel, params: Params, losses0, xs, ys, masks, ls, ws,
               gws, trained, lr, eval_mask, x_test, y_test, *, k_iters: int,
               compute_dtype: str = "f32"):
    """The whole training run as ONE program: ``lax.scan`` of the fused
    round over stacked per-round inputs.

    ``xs/ys/masks/ls/ws/gws`` are per-tier tuples with a leading round
    axis — tier k: ``(T, S_k, ...)`` — and ``trained`` is the (T, M) bool
    trained-gateway mask (the same per-tier structure the sharded twin,
    ``repro.fl.shard._train_scan_program``, shards over the mesh). The
    carry is (global params, per-gateway losses); each trip runs
    :func:`cohort_round_traced` on that round's pre-packed batch + decision
    tensors (``repro.fl.fused_sim`` threads them straight from the traced
    DDSRA decide scan). Two guards keep the scan equal to the stepwise
    loop round-for-round:

    * an all-zero-weight round (nobody trained) keeps the old params — the
      per-round path simply skips the program, while the normalized FedAvg
      here would otherwise average into zeros;
    * per-gateway losses update only where ``trained`` is set, mirroring
      ``sim.losses[m] = gw_loss[m]`` for trained gateways only.

    ``eval_mask`` is the (T,) bool ``eval_every`` schedule: marked rounds
    run a ``lax.cond``-gated test-set forward *inside* the scan (see
    :func:`_eval_hits`), restoring mid-run accuracy snapshots without
    leaving the fused program.

    Returns (final params, final losses (M,), per-round loss history
    (T, M) f32, per-round test hits (T,) int32 — -1 where not evaluated).
    One compile per (topology, rounds) shape.
    """
    TRACE_COUNTS["train_scan"] += 1
    x_eval = model.prepare_inputs(x_test)

    def step(carry, x):
        params, losses = carry
        xs_t, ys_t, masks_t, l_t, w_t, gw_t, tr_t, ev_t = x
        w = jnp.concatenate(w_t)
        new_global, gw_loss, _, _, _, _ = cohort_round_traced(
            model, params, xs_t, ys_t, masks_t, jnp.concatenate(l_t), w,
            jnp.concatenate(gw_t), lr, k_iters=k_iters,
            with_boundary=False, compute_dtype=compute_dtype)
        any_trained = jnp.sum(w) > 0
        params = jax.tree.map(
            lambda new, old: jnp.where(any_trained, new, old),
            new_global, params)
        losses = jnp.where(tr_t, gw_loss, losses)
        hits = _eval_hits(model, params, x_eval, y_test, ev_t)
        return (params, losses), (losses, hits)

    (params, losses), (loss_hist, hits) = jax.lax.scan(
        step, (params, jnp.asarray(losses0, jnp.float32)),
        (xs, ys, masks, ls, ws, gws, trained, eval_mask))
    return params, losses, loss_hist, hits


@functools.partial(jax.jit,
                   static_argnames=("model", "k_iters", "compute_dtype",
                                    "tier_widths"))
def train_scan_traced(model: SplitModel, params: Params, losses0, x_all, y_all,
                      pool_lens, batch_lens, data_key, ts, slot_devs, ls, ws,
                      gws, trained, lr, eval_mask, x_test, y_test, *,
                      k_iters: int, compute_dtype: str = "f32",
                      tier_widths: Tuple[int, ...]):
    """:func:`train_scan` with the data plane moved INSIDE the program.

    Instead of host-packed ``(T, S_k, W_k, ...)`` batch stacks, each round
    gathers its training batches in-scan from the device-resident shard
    stacks (``repro.fl.data.device_resident_stacks``): ``slot_devs`` maps
    every tier-major slot to its device id (-1 = empty), and the
    counter-based draw ``repro.fl.data.traced_batch_indices(data_key, t,
    dev, ...)`` reproduces the host oracle's indices bit-for-bit — so the
    whole run ships only the decision tensors (a few KB/round) to the
    accelerator, not ``T`` copies of padded sample batches.

    Empty slots gather device 0's rows with an all-zero validity mask; the
    masked loss multiplies their (finite) per-row losses by exactly 0.0,
    so the garbage rows contribute the same exact-zero loss and gradients
    as the host plane's zero padding. ``tier_widths`` is static — it fixes
    each tier's gather width ``W_k``.

    Returns the same (params, losses, loss_hist, hits) as
    :func:`train_scan`.
    """
    TRACE_COUNTS["train_scan"] += 1
    x_eval = model.prepare_inputs(x_test)
    l_max = x_all.shape[1]

    def gather_tier(t, devs, width):
        def one(dev):
            d = jnp.maximum(dev, 0)
            idx = _traced_indices(data_key, t, d, pool_lens[d], width, l_max)
            mb = ((jnp.arange(width) < batch_lens[d]) & (dev >= 0)
                  ).astype(jnp.float32)
            return x_all[d][idx], y_all[d][idx], mb
        return jax.vmap(one)(devs)

    def step(carry, x):
        params, losses = carry
        t, sd_t, l_t, w_t, gw_t, tr_t, ev_t = x
        gathered = [gather_tier(t, devs, width)
                    for devs, width in zip(sd_t, tier_widths)]
        xs_t = tuple(g[0] for g in gathered)
        ys_t = tuple(g[1] for g in gathered)
        masks_t = tuple(g[2] for g in gathered)
        w = jnp.concatenate(w_t)
        new_global, gw_loss, _, _, _, _ = cohort_round_traced(
            model, params, xs_t, ys_t, masks_t, jnp.concatenate(l_t), w,
            jnp.concatenate(gw_t), lr, k_iters=k_iters,
            with_boundary=False, compute_dtype=compute_dtype)
        any_trained = jnp.sum(w) > 0
        params = jax.tree.map(
            lambda new, old: jnp.where(any_trained, new, old),
            new_global, params)
        losses = jnp.where(tr_t, gw_loss, losses)
        hits = _eval_hits(model, params, x_eval, y_test, ev_t)
        return (params, losses), (losses, hits)

    (params, losses), (loss_hist, hits) = jax.lax.scan(
        step, (params, jnp.asarray(losses0, jnp.float32)),
        (ts, slot_devs, ls, ws, gws, trained, eval_mask))
    return params, losses, loss_hist, hits


def cohort_round(model: SplitModel, params: Params, batch, l_n, weights, gw_onehot,
                 k_iters: int, lr, with_boundary: bool = True,
                 with_gateway_models: bool = False,
                 compute_dtype: str = "f32") -> Tuple:
    """Run one fused FL round for the whole cohort.

    batch: ``repro.fl.data.CohortBatch`` (single padded width) or
    ``TieredCohortBatch`` (tiered slot widths, one vmap segment per tier).
    The slot axis is either "all devices", "packed slots" or "tier-major
    tiered slots" — the engine is agnostic; l_n / weights / gw_onehot just
    have to use the same indexing (``TieredCohortBatch.slot_of`` maps
    devices to tier-major slots).
    l_n: (S,) int partition point per slot — traced data, never static.
    weights: (S,) FedAvg weights (d_tilde for participants, 0 otherwise).
    gw_onehot: (S, M) slot->gateway incidence.
    with_boundary: also report each slot's boundary-activation RMS at its
    cut l_n (one extra forward pass).
    with_gateway_models: additionally return the per-gateway shop-floor
    FedAvg models (leading gateway axis), before the global mix — the
    intermediate the Fig. 2 divergence experiment measures.
    compute_dtype: "f32" (default) or "bf16" — the mixed-precision data
    plane (see ``_local_train``); master params and every returned tensor
    stay f32 either way.

    Returns (new_global_params, per_gateway_loss (M,), per_gateway_count (M,),
    per_slot_loss (S,), boundary_rms (S,)), plus the gateway models as a
    sixth element when ``with_gateway_models`` is set.
    """
    xs, ys, masks = _batch_tiers(batch)
    out = _cohort_round(model, params, xs, ys, masks,
                        jnp.asarray(l_n, jnp.int32),
                        jnp.asarray(weights, jnp.float32),
                        jnp.asarray(gw_onehot, jnp.float32),
                        jnp.float32(lr), k_iters=k_iters,
                        with_boundary=with_boundary,
                        with_gateway_models=with_gateway_models,
                        compute_dtype=compute_dtype)
    return out if with_gateway_models else out[:5]


def buffer_fedavg(models, weights):
    """Weighted FedAvg over a list of buffered model pytrees.

    The aggregation primitive of the buffered async engine
    (``repro.fl.async_engine``): ``models`` is a list of same-structure
    parameter pytrees (e.g. per-gateway shop-floor models pulled from the
    staleness buffer) and ``weights`` their aggregation coefficients —
    typically surviving-sample counts already discounted by staleness.
    Weights are normalized here, so callers pass raw coefficients. Uses the
    same stacked-tensordot idiom as the fused round's in-program FedAvg:
    with every entry at staleness 0 and the full cohort buffered, this
    reproduces ``_cohort_round``'s two-tier average (the degenerate-parity
    oracle relies on that).
    """
    w = jnp.asarray(np.asarray(weights), jnp.float32)
    w = w / jnp.maximum(jnp.sum(w), 1e-12)
    return jax.tree.map(
        lambda *leaves: jnp.tensordot(w, jnp.stack(leaves), axes=1), *models)


# ---------------------------------------------------------------------------
# per-device gradient statistics (sigma_n, delta_n, L_n) in one program
# ---------------------------------------------------------------------------


def _grads_sigma_lips(model: SplitModel, params: Params, x, y, mask, lr,
                      sigma_samples: int):
    """Per-device flat batch gradients, sigma_n and L_n — everything in the
    stats pass that needs **no** cross-device reduction, so the sharded
    engine can run it on a local slot shard and only ``psum`` the global
    gradient for delta_n. ``x`` must already be through
    ``model.prepare_inputs``. Returns (grads (N, P), sigma (N,), lips (N,))."""

    def batch_grad(p, xb, yb, mb):
        def loss_of(pp):
            return model.masked_loss(model.forward(pp, xb), yb, mb)
        return _flat(jax.grad(loss_of)(p))

    grads = jax.vmap(lambda xb, yb, mb: batch_grad(params, xb, yb, mb))(
        x, y, mask)                                              # (N, P)

    # sigma_n: per-sample gradient spread. vmap-of-vmap over (device, sample);
    # lax.map over the device axis keeps the (S, P) per-sample grad buffer
    # per-device instead of materializing (N, S, P).
    s = min(sigma_samples, x.shape[1])

    def dev_sigma(args):
        xs, ys, ms = args                                        # (S, ...)
        def one(xi, yi):
            def loss_of(pp):
                return model.loss(model.forward(pp, xi[None]), yi[None])
            return _flat(jax.grad(loss_of)(params))
        per = jax.vmap(one)(xs, ys)                              # (S, P)
        cnt = jnp.maximum(jnp.sum(ms), 1.0)
        mean_g = jnp.sum(per * ms[:, None], axis=0) / cnt
        dev = jnp.linalg.norm(per - mean_g[None], axis=1)
        return jnp.sum(dev * ms) / cnt

    sigma = jax.lax.map(dev_sigma, (x[:, :s], y[:, :s], mask[:, :s]))

    # L_n: two-point secant along the SGD direction.
    flat_params = _flat(params)
    pert = _unflatten_stacked(flat_params[None] - lr * grads, params)
    grads2 = jax.vmap(batch_grad)(pert, x, y, mask)
    dw = jnp.linalg.norm(jax.vmap(_flat)(pert) - flat_params[None], axis=1)
    lips = jnp.linalg.norm(grads2 - grads, axis=1) / jnp.maximum(dw, 1e-9)

    return grads, sigma, lips


@functools.partial(jax.jit, static_argnames=("model", "sigma_samples"))
def _cohort_stats(model: SplitModel, params: Params, x, y, mask, mix_weights,
                  lr, *, sigma_samples: int):
    TRACE_COUNTS["stats"] += 1
    x = model.prepare_inputs(x)

    grads, sigma, lips = _grads_sigma_lips(model, params, x, y, mask, lr,
                                           sigma_samples)

    # delta_n: divergence from the D_n-weighted global gradient.
    global_g = jnp.tensordot(mix_weights, grads, axes=1)
    delta = jnp.linalg.norm(grads - global_g[None], axis=1)

    return sigma, delta, lips


def cohort_stats(model: SplitModel, params: Params, batch, mix_weights, lr,
                 sigma_samples: int):
    """sigma/delta/Lipschitz for every device in one jitted program
    (the seed ran O(devices x samples) sequential jit calls)."""
    return _cohort_stats(model, params,
                         jnp.asarray(batch.x), jnp.asarray(batch.y),
                         jnp.asarray(batch.mask),
                         jnp.asarray(mix_weights, jnp.float32),
                         jnp.float32(lr), sigma_samples=sigma_samples)
