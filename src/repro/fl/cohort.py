"""Batched cohort split-training engine: one XLA program per FL round.

The seed trainer executed the cohort one device at a time — a fresh jitted
``split_sgd_step`` per device per local epoch, retraced for every distinct
partition point ``l`` (a static argnum) and batch shape, with a ``float(loss)``
host sync after every step. This module replaces that with a single fused
program per round:

* per-device parameters are a struct-of-arrays pytree (leading device axis),
* ``jax.vmap`` runs the split forward/backward for the whole cohort at once,
* ``jax.lax.scan`` iterates the K local epochs inside the same program,
* the shop-floor + base-station FedAvg reduction is fused into the end of the
  step, so nothing round-trips to the host until the round result is read.

**Partition point handled as data (masking, not bucketing).** Split training
at partition point ``l`` computes *exactly* the same parameter update as
unsplit SGD — the boundary activation/error exchange is mathematically
transparent (proved by ``tests/test_split_training.py``). The engine
therefore executes the mathematically-equal fused forward/backward once per
device and keeps ``l_n`` a *traced per-device array*: it selects, per device,
which layer boundary's activation statistics are reported (the tensor that
would cross the device→gateway link), via a masked gather over the stacked
per-layer activation norms. The alternative — bucketing devices by ``l`` and
running a separate two-segment program per bucket — would compile
``O(distinct l)`` programs, reintroduce per-bucket host syncs, and change
shapes whenever the scheduler's partition decisions change; masking compiles
exactly once for all rounds, device subsets and partition vectors. The
tradeoff is that per-tier work is not physically separated on one host — the
tier *accounting* (delay/energy) lives in ``repro.core.costmodel``, which is
where the paper keeps it too.

Fixed-shape batching contract: inputs come from
``repro.fl.data.sample_cohort_batch`` — always ``(N, B_pad, ...)`` with a
row-validity mask, all devices present, non-participants zero-masked and
zero-weighted — so varying device subsets never retrace.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.fl.split import flat_params as _flat
from repro.models import vgg
from repro.models.vgg import Params, Plan

# Incremented inside the traced bodies (Python side effects run only at trace
# time), so tests/benchmarks can assert "exactly one compile across rounds".
TRACE_COUNTS = {"round": 0, "stats": 0}


def _unflatten_stacked(flat_nd: jnp.ndarray, like):
    """(N, P) flat rows -> pytree like ``like`` with leading device axis."""
    leaves, treedef = jax.tree.flatten(like)
    out, i = [], 0
    for leaf in leaves:
        sz = leaf.size
        out.append(flat_nd[:, i:i + sz]
                   .reshape((flat_nd.shape[0],) + leaf.shape)
                   .astype(leaf.dtype))
        i += sz
    return jax.tree.unflatten(treedef, out)


def _masked_rms(a: jax.Array, mask: jax.Array) -> jax.Array:
    """RMS over the valid rows of a (B, ...) activation."""
    a2 = a.reshape(a.shape[0], -1).astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(mask), 1.0) * a2.shape[1]
    return jnp.sqrt(jnp.sum(a2 * a2 * mask[:, None]) / denom)


def _boundary_rms(plan: Plan, params: Params, x, mask, l) -> jax.Array:
    """RMS of the activation crossing the device->gateway boundary at cut
    ``l`` (a traced int: l=0 ships the raw input, l=len(plan) ships logits
    — i.e. everything ran device-side)."""
    norms = [_masked_rms(x, mask)]
    a = x
    for kind, layer in zip(plan, params):
        a = vgg._apply_layer(kind, layer, a)
        norms.append(_masked_rms(a, mask))
    return jnp.take(jnp.stack(norms), l)


# ---------------------------------------------------------------------------
# one FL round: (devices x K local epochs + FedAvg) fused
# ---------------------------------------------------------------------------


@functools.partial(jax.jit,
                   static_argnames=("plan", "k_iters", "with_boundary",
                                    "with_gateway_models"))
def _cohort_round(plan: Plan, params: Params, x, y, mask, l_n, weights,
                  gw_onehot, lr, *, k_iters: int, with_boundary: bool,
                  with_gateway_models: bool = False):
    TRACE_COUNTS["round"] += 1
    n_dev = x.shape[0]
    if all(k in ("fc", "fc_last") for k in plan):
        # flatten images once per round, not inside every scanned epoch
        x = x.reshape(x.shape[0], x.shape[1], -1)
    stacked = jax.tree.map(
        lambda p: jnp.broadcast_to(p, (n_dev,) + p.shape), params)

    def dev_step(p, xb, yb, mb):
        def loss_of(pp):
            return vgg.masked_xent_loss(vgg.forward(plan, pp, xb), yb, mb)
        loss, g = jax.value_and_grad(loss_of)(p)
        new_p = jax.tree.map(lambda w_, g_: w_ - lr * g_, p, g)
        return new_p, loss

    def one_epoch(p_stack, _):
        return jax.vmap(dev_step)(p_stack, x, y, mask)

    final, loss_hist = jax.lax.scan(one_epoch, stacked, None, length=k_iters)
    dev_losses = loss_hist[-1]                     # loss at start of epoch K,
    # matching the sequential path's "last split_sgd_step" loss semantics.

    # fused two-tier FedAvg: gateway-level then BS-level weighted averaging
    # telescopes to one weighted average over participating devices.
    w = weights / jnp.maximum(jnp.sum(weights), 1e-12)
    new_global = jax.tree.map(lambda s: jnp.tensordot(w, s, axes=1), final)

    active = (weights > 0).astype(jnp.float32)
    gw_count = gw_onehot.T @ active                                 # (M,)
    gw_loss = (gw_onehot.T @ (dev_losses * active)) / jnp.maximum(gw_count, 1.0)

    if with_boundary:
        boundary = jax.vmap(
            lambda p, xb, mb, l: _boundary_rms(plan, p, xb, mb, l)
        )(final, x, mask, l_n)
    else:    # skip the extra forward pass; l_n stays unused data
        boundary = jnp.zeros_like(weights)

    if with_gateway_models:
        # per-gateway shop-floor FedAvg before the global mix: columns of the
        # (N, M) incidence, weighted by d_tilde and normalized per gateway.
        gw_w = gw_onehot * weights[:, None]
        gw_w = gw_w / jnp.maximum(jnp.sum(gw_w, axis=0, keepdims=True), 1e-12)
        gw_models = jax.tree.map(
            lambda s: jnp.tensordot(gw_w.T, s, axes=1), final)   # (M, ...)
    else:
        gw_models = None

    return new_global, gw_loss, gw_count, dev_losses, boundary, gw_models


def cohort_round(plan: Plan, params: Params, batch, l_n, weights, gw_onehot,
                 k_iters: int, lr, with_boundary: bool = True,
                 with_gateway_models: bool = False) -> Tuple:
    """Run one fused FL round for the whole cohort.

    batch: ``repro.fl.data.CohortBatch`` (fixed padded shapes). The leading
    axis is either "all devices" or "packed slots" — the engine is agnostic;
    l_n / weights / gw_onehot just have to use the same indexing.
    l_n: (N,) int partition point per row — traced data, never static.
    weights: (N,) FedAvg weights (d_tilde for participants, 0 otherwise).
    gw_onehot: (N, M) row->gateway incidence.
    with_boundary: also report each row's boundary-activation RMS at its
    cut l_n (one extra forward pass).
    with_gateway_models: additionally return the per-gateway shop-floor
    FedAvg models (leading gateway axis), before the global mix — the
    intermediate the Fig. 2 divergence experiment measures.

    Returns (new_global_params, per_gateway_loss (M,), per_gateway_count (M,),
    per_row_loss (N,), boundary_rms (N,)), plus the gateway models as a sixth
    element when ``with_gateway_models`` is set.
    """
    out = _cohort_round(plan, params,
                        jnp.asarray(batch.x), jnp.asarray(batch.y),
                        jnp.asarray(batch.mask),
                        jnp.asarray(l_n, jnp.int32),
                        jnp.asarray(weights, jnp.float32),
                        jnp.asarray(gw_onehot, jnp.float32),
                        jnp.float32(lr), k_iters=k_iters,
                        with_boundary=with_boundary,
                        with_gateway_models=with_gateway_models)
    return out if with_gateway_models else out[:5]


# ---------------------------------------------------------------------------
# per-device gradient statistics (sigma_n, delta_n, L_n) in one program
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("plan", "sigma_samples"))
def _cohort_stats(plan: Plan, params: Params, x, y, mask, mix_weights, lr,
                  *, sigma_samples: int):
    TRACE_COUNTS["stats"] += 1
    if all(k in ("fc", "fc_last") for k in plan):
        x = x.reshape(x.shape[0], x.shape[1], -1)

    def batch_grad(p, xb, yb, mb):
        def loss_of(pp):
            return vgg.masked_xent_loss(vgg.forward(plan, pp, xb), yb, mb)
        return _flat(jax.grad(loss_of)(p))

    grads = jax.vmap(lambda xb, yb, mb: batch_grad(params, xb, yb, mb))(
        x, y, mask)                                              # (N, P)

    # sigma_n: per-sample gradient spread. vmap-of-vmap over (device, sample);
    # lax.map over the device axis keeps the (S, P) per-sample grad buffer
    # per-device instead of materializing (N, S, P).
    s = min(sigma_samples, x.shape[1])

    def dev_sigma(args):
        xs, ys, ms = args                                        # (S, ...)
        def one(xi, yi):
            def loss_of(pp):
                return vgg.xent_loss(vgg.forward(plan, pp, xi[None]),
                                     yi[None])
            return _flat(jax.grad(loss_of)(params))
        per = jax.vmap(one)(xs, ys)                              # (S, P)
        cnt = jnp.maximum(jnp.sum(ms), 1.0)
        mean_g = jnp.sum(per * ms[:, None], axis=0) / cnt
        dev = jnp.linalg.norm(per - mean_g[None], axis=1)
        return jnp.sum(dev * ms) / cnt

    sigma = jax.lax.map(dev_sigma, (x[:, :s], y[:, :s], mask[:, :s]))

    # delta_n: divergence from the D_n-weighted global gradient.
    global_g = jnp.tensordot(mix_weights, grads, axes=1)
    delta = jnp.linalg.norm(grads - global_g[None], axis=1)

    # L_n: two-point secant along the SGD direction.
    flat_params = _flat(params)
    pert = _unflatten_stacked(flat_params[None] - lr * grads, params)
    grads2 = jax.vmap(batch_grad)(pert, x, y, mask)
    dw = jnp.linalg.norm(jax.vmap(_flat)(pert) - flat_params[None], axis=1)
    lips = jnp.linalg.norm(grads2 - grads, axis=1) / jnp.maximum(dw, 1e-9)

    return sigma, delta, lips


def cohort_stats(plan: Plan, params: Params, batch, mix_weights, lr,
                 sigma_samples: int):
    """sigma/delta/Lipschitz for every device in one jitted program
    (the seed ran O(devices x samples) sequential jit calls)."""
    return _cohort_stats(plan, params,
                         jnp.asarray(batch.x), jnp.asarray(batch.y),
                         jnp.asarray(batch.mask),
                         jnp.asarray(mix_weights, jnp.float32),
                         jnp.float32(lr), sigma_samples=sigma_samples)
