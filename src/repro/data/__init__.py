from repro.data.lm import LMStream, markov_stream

__all__ = ["LMStream", "markov_stream"]
