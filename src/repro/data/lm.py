"""Synthetic LM token pipeline (offline container: no downloaded corpora).

A sparse first-order Markov chain over the model vocab with Zipfian marginals
gives a learnable next-token structure: a model that learns the transition
table reaches substantially-below-uniform loss, so training curves are
meaningful. Deterministic per seed; sharded iteration for data parallelism.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np


@dataclasses.dataclass
class LMStream:
    vocab: int
    seq_len: int
    batch: int
    seed: int = 0
    branching: int = 8           # successors per token

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        v = self.vocab
        self.succ = rng.integers(0, v, size=(v, self.branching))
        # Zipfian start distribution
        ranks = np.arange(1, v + 1)
        p = 1.0 / ranks
        self.start_p = p / p.sum()
        self._rng = np.random.default_rng(self.seed + 1)

    def batches(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next_batch()

    def next_batch(self) -> Dict[str, np.ndarray]:
        rng = self._rng
        b, s, v = self.batch, self.seq_len, self.vocab
        toks = np.empty((b, s + 1), np.int32)
        toks[:, 0] = rng.choice(v, size=b, p=self.start_p)
        choices = rng.integers(0, self.branching, size=(b, s))
        for t in range(s):
            toks[:, t + 1] = self.succ[toks[:, t], choices[:, t]]
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def entropy_floor(self) -> float:
        """Per-token loss floor: log(branching) for uniform successor picks."""
        return float(np.log(self.branching))


def markov_stream(vocab: int, seq_len: int, batch: int, seed: int = 0) -> LMStream:
    return LMStream(vocab, seq_len, batch, seed)
