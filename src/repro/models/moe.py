"""Mixture-of-Experts FFN with sort-based capacity dispatch.

Experts are sharded over the ``model`` mesh axis (expert parallelism); the
dispatch buffer is (E, C, D) so per-expert matmuls are MXU-shaped batched
GEMMs. Tokens overflowing an expert's capacity are dropped (standard
capacity-factor semantics); the residual path keeps them lossless.
"""
from __future__ import annotations

import math
from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig


def capacity(n_tokens: int, cfg: MoEConfig) -> int:
    c = math.ceil(n_tokens * cfg.top_k / cfg.n_experts * cfg.capacity_factor)
    return max(8, int(math.ceil(c / 8) * 8))


def router_topk(logits: jax.Array, k: int):
    """logits (T, E) -> gates (T, k) fp32 (softmaxed over top-k), idx (T, k)."""
    top, idx = jax.lax.top_k(logits.astype(jnp.float32), k)
    gates = jax.nn.softmax(top, axis=-1)
    return gates, idx


def _dispatch_one_group(xt: jax.Array, router: jax.Array, e: int, k: int,
                        cap: int):
    """xt (T, D) -> dispatch buffer (E, C, D) + combine metadata."""
    t = xt.shape[0]
    logits = xt @ router                                 # (T, E)
    gates, idx = router_topk(logits, k)                  # (T, k)

    flat_expert = idx.reshape(-1)                        # (T*k,)
    flat_token = jnp.repeat(jnp.arange(t), k)
    flat_gate = gates.reshape(-1)

    order = jnp.argsort(flat_expert)                     # stable sort by expert
    se, st, sg = flat_expert[order], flat_token[order], flat_gate[order]

    # rank of each slot within its expert group
    sizes = jnp.bincount(se, length=e)                   # (E,)
    starts = jnp.cumsum(sizes) - sizes
    rank = jnp.arange(t * k) - starts[se]
    keep = rank < cap

    buf = jnp.zeros((e, cap, xt.shape[1]), xt.dtype)
    se_c = jnp.where(keep, se, 0)
    rk_c = jnp.where(keep, rank, 0)
    vals = jnp.where(keep[:, None], xt[st], 0).astype(xt.dtype)
    buf = buf.at[se_c, rk_c].add(vals)
    return buf, (se_c, rk_c, st, sg, keep)


def _combine_one_group(yb: jax.Array, meta, t: int) -> jax.Array:
    se_c, rk_c, st, sg, keep = meta
    contrib = yb[se_c, rk_c] * (sg * keep)[:, None].astype(yb.dtype)
    return jnp.zeros((t, yb.shape[-1]), yb.dtype).at[st].add(contrib)


def moe_ffn(x: jax.Array, params: Dict[str, jax.Array], cfg: MoEConfig) -> jax.Array:
    """x: (B, S, D) -> (B, S, D).

    params: router (D, E), w1/w3 (E, D, F), w2 (E, F, D).

    With ``cfg.dispatch_groups == G > 1`` the token stream is split into G
    fixed groups (aligned with the data-parallel shards by the launch layer):
    routing/sort/scatter stay group-local — only the expert GEMM, whose
    operands are already (groups x experts)-sharded, crosses the mesh.
    """
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.top_k
    g = max(1, cfg.dispatch_groups)
    assert t % g == 0, (t, g)
    tg = t // g
    cap = capacity(tg, cfg)

    xg = x.reshape(g, tg, d)
    bufs, metas = jax.vmap(
        lambda xt: _dispatch_one_group(xt, params["router"], e, k, cap))(xg)
    # bufs: (G, E, C, D) — G sharded over data, E over model
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", bufs, params["w1"]))
    h = h * jnp.einsum("gecd,edf->gecf", bufs, params["w3"])
    yb = jnp.einsum("gecf,efd->gecd", h, params["w2"])   # (G, E, C, D)

    y = jax.vmap(lambda y_, m: _combine_one_group(y_, m, tg))(yb, metas)
    return y.reshape(b, s, d)


def aux_load_balance_loss(logits: jax.Array, idx: jax.Array, n_experts: int) -> jax.Array:
    """Switch-style load-balance auxiliary loss (used by training drivers)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    me = jnp.mean(probs, axis=0)
    one_hot = jax.nn.one_hot(idx[..., 0], n_experts)
    ce = jnp.mean(one_hot, axis=0)
    return n_experts * jnp.sum(me * ce)
