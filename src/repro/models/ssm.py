"""Mamba-2 SSD (state-space duality) block — chunked dual form + step form.

Follows arXiv:2405.21060: within-chunk computation uses the quadratic
(attention-like) dual form, cross-chunk state is carried by a linear
recurrence, so train/prefill cost is O(S * Q) instead of O(S^2), and decode
is O(1) per token via the recurrent step.

Shapes: x (B,S,D); d_inner = expand*D; heads n with head_dim p; state ds.
B/C projections are shared across heads (n_groups=1, as in the 2.7b model).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, SSMConfig
from repro.models import backend
from repro.models.layers import rms_norm


def _split_proj(x, params, cfg: ArchConfig):
    s = cfg.ssm
    d_in = s.d_inner(cfg.d_model)
    zx = x @ params["w_xz"]
    z, xin = jnp.split(zx, 2, axis=-1)
    bc = x @ params["w_bc"]
    b_ssm, c_ssm = jnp.split(bc, 2, axis=-1)
    dt = jax.nn.softplus((x @ params["w_dt"]).astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))
    return z, xin, b_ssm, c_ssm, dt


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv. x (B,S,C), w (K,C), b (C,)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(k))
    return jax.nn.silu(out + b)


def ssd_chunked(xh: jax.Array, dt: jax.Array, a_log: jax.Array,
                b_ssm: jax.Array, c_ssm: jax.Array, chunk: int,
                h0: jax.Array | None = None) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.

    xh (B,S,n,p); dt (B,S,n) fp32; a_log (n,); b_ssm/c_ssm (B,S,ds).
    Returns (y (B,S,n,p), final state (B,n,ds,p)).
    """
    bsz, s, n, p = xh.shape
    ds = b_ssm.shape[-1]
    nc, rem = divmod(s, chunk)
    assert rem == 0, (s, chunk)
    a = -jnp.exp(a_log.astype(jnp.float32))             # (n,) negative decay rates

    def rs(t, extra):  # (B,S,...) -> (NC, B, chunk, ...)
        return t.reshape(bsz, nc, chunk, *extra).transpose(1, 0, 2, *(i + 3 for i in range(len(extra))))

    xc = rs(xh, (n, p))
    dtc = rs(dt, (n,))
    bcs = rs(b_ssm, (ds,))
    ccs = rs(c_ssm, (ds,))

    adt = dtc * a                                       # (NC,B,Q,n) log-decay
    cum = jnp.cumsum(adt, axis=2)                       # inclusive cumsum

    # intra-chunk dual (quadratic) term
    qpos = jnp.arange(chunk)
    causal = qpos[:, None] >= qpos[None, :]
    scores = jnp.einsum("cbqs,cbks->cbqk", ccs, bcs)    # (NC,B,Q,Q) shared heads
    # decay from k to q: exp(cum_q - cum_k) for q >= k
    ldec = jnp.exp(cum[:, :, :, None, :] - cum[:, :, None, :, :])  # (NC,B,Q,K,n)
    w = scores[..., None] * jnp.where(causal[None, None, :, :, None], ldec, 0.0)
    w = w * dtc[:, :, None, :, :]                       # * dt_k
    y_intra = jnp.einsum("cbqkn,cbknp->cbqnp", w.astype(xh.dtype), xc)

    # per-chunk end states
    wk = jnp.exp(cum[:, :, -1:, :] - cum) * dtc         # (NC,B,Q,n)
    states = jnp.einsum("cbks,cbkn,cbknp->cbnsp", bcs, wk.astype(xh.dtype), xc)
    chunk_decay = jnp.exp(cum[:, :, -1, :])             # (NC,B,n)

    if h0 is None:
        h0 = jnp.zeros((bsz, n, ds, p), jnp.float32)

    def step(h, xs):
        st, dec, cseg, cumseg = xs
        # inter-chunk contribution for this chunk, using state *before* it
        y = jnp.einsum("bqs,bnsp,bqn->bqnp", cseg, h.astype(xh.dtype),
                       jnp.exp(cumseg).astype(xh.dtype))
        h_next = h * dec[..., None, None] + st.astype(jnp.float32)
        return h_next, y

    h_final, y_inter = jax.lax.scan(step, h0, (states, chunk_decay, ccs, cum))
    y = y_intra + y_inter                               # (NC,B,Q,n,p)
    y = y.transpose(1, 0, 2, 3, 4).reshape(bsz, s, n, p)
    return y, h_final


def ssd_step(xh: jax.Array, dt: jax.Array, a_log: jax.Array,
             b_ssm: jax.Array, c_ssm: jax.Array,
             h: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Single-token recurrence. xh (B,n,p); dt (B,n); b/c (B,ds); h (B,n,ds,p)."""
    a = -jnp.exp(a_log.astype(jnp.float32))
    dec = jnp.exp(dt * a)                               # (B,n)
    upd = dt[..., None, None] * b_ssm[:, None, :, None] * xh[:, :, None, :].astype(jnp.float32)
    h = h * dec[..., None, None] + upd
    y = jnp.einsum("bnsp,bs->bnp", h, c_ssm.astype(jnp.float32))
    return y.astype(xh.dtype), h


def mamba_block(x: jax.Array, params: Dict[str, jax.Array], cfg: ArchConfig,
                h0=None, return_state: bool = False):
    """Full Mamba-2 block, sequence mode. x (B,S,D) -> (B,S,D)."""
    s_cfg = cfg.ssm
    d_in = s_cfg.d_inner(cfg.d_model)
    n, p, ds = s_cfg.n_heads(cfg.d_model), s_cfg.head_dim, s_cfg.d_state
    bsz, s, _ = x.shape

    z, xin, b_ssm, c_ssm, dt = _split_proj(x, params, cfg)
    conv_in = jnp.concatenate([xin, b_ssm, c_ssm], axis=-1)
    conv_out = _causal_conv(conv_in, params["conv_w"], params["conv_b"])
    xin, b_ssm, c_ssm = jnp.split(conv_out, [d_in, d_in + ds], axis=-1)

    xh = xin.reshape(bsz, s, n, p)
    be = backend.current()
    # kernel routing: the backend switch picks pallas/interpret; otherwise
    # the env-resolved default (REPRO_SSD_SCAN_IMPL) decides. The "ref"
    # default keeps the chunked dual form below — same math, no op layer —
    # while any kernel impl dispatches through the ssd_scan_vjp custom VJP
    # (differentiable: backward recomputes via the sequential oracle).
    from repro.kernels.ssd_scan import ops as ssd_ops
    impl = (("interpret" if be.interpret else "pallas") if be.pallas
            else ssd_ops.default_impl())
    if (impl != "ref" and h0 is None and not return_state
            and backend.ssd_ok(s, n, s_cfg.chunk_size, be.ssd_block_h)):
        y = ssd_ops.ssd(xh, dt, params["a_log"], b_ssm, c_ssm,
                        chunk=min(s_cfg.chunk_size, s),
                        block_h=min(be.ssd_block_h, n), impl=impl)
        h = None
    else:
        y, h = ssd_chunked(xh, dt, params["a_log"], b_ssm, c_ssm,
                           min(s_cfg.chunk_size, s), h0=h0)
    y = y + params["d_skip"].astype(x.dtype)[:, None] * xh
    y = y.reshape(bsz, s, d_in)
    y = rms_norm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    out = y @ params["w_out"]
    if return_state:
        return out, h
    return out


def mamba_step(x: jax.Array, params: Dict[str, jax.Array], cfg: ArchConfig,
               conv_state: jax.Array, h: jax.Array):
    """Decode step. x (B,1,D); conv_state (B,K-1,C); h (B,n,ds,p)."""
    s_cfg = cfg.ssm
    d_in = s_cfg.d_inner(cfg.d_model)
    n, p, ds = s_cfg.n_heads(cfg.d_model), s_cfg.head_dim, s_cfg.d_state
    bsz = x.shape[0]

    z, xin, b_ssm, c_ssm, dt = _split_proj(x[:, 0], params, cfg)
    conv_in = jnp.concatenate([xin, b_ssm, c_ssm], axis=-1)     # (B,C)
    w = params["conv_w"]
    full = jnp.concatenate([conv_state, conv_in[:, None, :]], axis=1)  # (B,K,C)
    conv_out = jax.nn.silu(jnp.einsum("bkc,kc->bc", full, w) + params["conv_b"])
    new_conv_state = full[:, 1:]
    xin, b_ssm, c_ssm = jnp.split(conv_out, [d_in, d_in + ds], axis=-1)

    xh = xin.reshape(bsz, n, p)
    y, h = ssd_step(xh, dt, params["a_log"], b_ssm, c_ssm, h)
    y = y + params["d_skip"].astype(x.dtype) [:, None] * xh
    y = y.reshape(bsz, d_in)
    y = rms_norm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    return (y @ params["w_out"])[:, None], new_conv_state, h
