"""Core layer primitives (pure jnp; Pallas variants live in repro.kernels)."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# norms / activations
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(dt) * scale


def swiglu(x: jax.Array, w1: jax.Array, w3: jax.Array, w2: jax.Array) -> jax.Array:
    h = jax.nn.silu(x @ w1) * (x @ w3)
    return h @ w2


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------


def rope_freqs(hd: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: (..., S) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (chunked-causal for train/prefill; one-step for decode)
# ---------------------------------------------------------------------------


def _gqa_scores(q: jax.Array, k: jax.Array) -> jax.Array:
    """q: (B,Sq,H,hd)  k: (B,Sk,KV,hd) -> (B,H,Sq,Sk) with GQA groups."""
    b, sq, h, hd = q.shape
    kv = k.shape[2]
    g = h // kv
    qg = q.reshape(b, sq, kv, g, hd)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k, preferred_element_type=jnp.float32)
    return s.reshape(b, h, sq, k.shape[1])


def _gqa_out(p: jax.Array, v: jax.Array) -> jax.Array:
    """p: (B,H,Sq,Sk)  v: (B,Sk,KV,hd) -> (B,Sq,H,hd)."""
    b, h, sq, sk = p.shape
    kv = v.shape[2]
    g = h // kv
    pg = p.reshape(b, kv, g, sq, sk)
    o = jnp.einsum("bkgqs,bskd->bqkgd", pg, v.astype(p.dtype))
    return o.reshape(b, sq, h, v.shape[-1])


def causal_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     *, block_q: int = 1024, window: Optional[int] = None,
                     causal: bool = True) -> jax.Array:
    """Memory-bounded causal (optionally sliding-window) attention.

    Scans over query blocks so the live score matrix is (B,H,block_q,Sk):
    the jnp analogue of the flash-attention tiling, and the oracle the Pallas
    kernel is tested against.
    q: (B,S,H,hd), k/v: (B,S,KV,hd) -> (B,S,H,hd)
    """
    b, s, h, hd = q.shape
    sk = k.shape[1]
    scale = hd ** -0.5
    block_q = min(block_q, s)
    n_blk, rem = divmod(s, block_q)
    assert rem == 0, (s, block_q)

    qb = q.reshape(b, n_blk, block_q, h, hd).transpose(1, 0, 2, 3, 4)

    def body(_, args):
        i, qi = args
        qpos = i * block_q + jnp.arange(block_q)
        scores = _gqa_scores(qi, k) * scale             # (B,H,bq,Sk)
        kpos = jnp.arange(sk)
        mask = jnp.ones((block_q, sk), bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window is not None:
            mask &= kpos[None, :] > qpos[:, None] - window
        scores = jnp.where(mask[None, None], scores, -jnp.inf)
        p = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
        return (), _gqa_out(p, v)

    _, ob = jax.lax.scan(body, (), (jnp.arange(n_blk), qb))
    return ob.transpose(1, 0, 2, 3, 4).reshape(b, s, h, hd)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     pos: jax.Array) -> jax.Array:
    """One-token attention against a cache.

    q: (B,1,H,hd); k_cache/v_cache: (B,S,KV,hd); pos: () current position.
    Entries at index > pos are masked out.
    """
    s = k_cache.shape[1]
    scale = q.shape[-1] ** -0.5
    scores = _gqa_scores(q, k_cache) * scale            # (B,H,1,S)
    valid = jnp.arange(s) <= pos
    scores = jnp.where(valid[None, None, None], scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1).astype(v_cache.dtype)
    return _gqa_out(p, v_cache)


def ring_index(pos: jax.Array, size: int) -> jax.Array:
    """Write index for a ring-buffer (sliding-window) cache."""
    return jnp.mod(pos, size)
