"""Model-agnostic split-training interface.

The paper's DNN-partition mechanism is model-agnostic: a device trains the
bottom ``l`` blocks, its gateway the top. This module owns the seam the FL
stack trains through — every engine sees only a :class:`SplitModel` handle:

* a **hashable, frozen** description of one model (it rides ``jax.jit``
  static arguments and ``lru_cache`` keys exactly like the old VGG ``plan``
  tuple did);
* ``init`` produces ``params`` as a *list of per-block dicts* aligned with
  ``block_kinds``, so a partition point ``l`` splits ``params[:l]`` /
  ``params[l:]`` and ``forward_range(lo, hi)`` runs blocks [lo, hi);
* losses (masked + unmasked), ``accuracy``, valid partition points and the
  per-block :class:`~repro.core.costmodel.LayerCost` profile the DDSRA
  partition search prices.

Families:

* :class:`VGGSplitModel` / :class:`MLPSplitModel` — the original layer-list
  models (``repro.models.vgg``), one block per layer, image inputs;
* :class:`SeqSplitModel` — any decoder-only ``ArchConfig`` from the model
  zoo (dense/GQA attention, MoE FFN, Mamba-2 SSD), one block per
  embedding / attention / SSM / FFN / head boundary, token inputs.
  Attention routes through the differentiable ``flash_attention`` op
  (Pallas forward + backward kernels; ``REPRO_FLASH_ATTENTION_IMPL``
  selects pallas/interpret/ref).

Blocks of a :class:`SeqSplitModel` map 1:1 onto
``costmodel.arch_layers(cfg, seq)`` entries, so ``layer_costs()`` is the
analytic per-block profile scaled from per-token to per-sequence (the FL
data unit is one sequence).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, MoEConfig, SSMConfig
from repro.core import costmodel as cm
from repro.models import model as model_lib
from repro.models import params as params_lib
from repro.models import ssm as ssm_lib
from repro.models import vgg
from repro.models.layers import rms_norm

Params = List[Dict[str, Any]]


class SplitModel:
    """Base contract. Subclasses are frozen dataclasses (hashable)."""

    input_kind: str = "image"   # "image" -> float batches, "tokens" -> int32
    min_cut: int = 0            # smallest valid partition point

    # -- structure ---------------------------------------------------------

    @property
    def block_kinds(self) -> Tuple[str, ...]:
        raise NotImplementedError

    @property
    def n_blocks(self) -> int:
        return len(self.block_kinds)

    @property
    def valid_cuts(self) -> Tuple[int, ...]:
        """Partition points ``l``: device trains blocks [0, l)."""
        return tuple(range(self.min_cut, self.n_blocks + 1))

    # -- params / forward --------------------------------------------------

    def init(self, key: jax.Array) -> Params:
        raise NotImplementedError

    def apply_block(self, i: int, p: Dict[str, Any], x: jax.Array) -> jax.Array:
        raise NotImplementedError

    def forward_range(self, params: Params, x: jax.Array,
                      lo: int, hi: int) -> jax.Array:
        for i in range(lo, hi):
            x = self.apply_block(i, params[i], x)
        return x

    def forward(self, params: Params, x: jax.Array) -> jax.Array:
        return self.forward_range(params, x, 0, self.n_blocks)

    def activations(self, params: Params, x: jax.Array) -> List[jax.Array]:
        """The tensor crossing each cut: a[0] = input, a[i] = after block i."""
        acts = [x]
        for i in range(self.n_blocks):
            x = self.apply_block(i, params[i], x)
            acts.append(x)
        return acts

    def prepare_inputs(self, x: jax.Array) -> jax.Array:
        """Reshape packed batches (lead-2 axes = slots, width) for block 0."""
        return x

    # -- losses / eval -----------------------------------------------------

    def loss(self, logits: jax.Array, labels: jax.Array) -> jax.Array:
        raise NotImplementedError

    def masked_loss(self, logits: jax.Array, labels: jax.Array,
                    mask: jax.Array) -> jax.Array:
        """Per-sample mask over a padded batch; equals ``loss`` when all 1."""
        raise NotImplementedError

    @property
    def init_loss(self) -> float:
        """Loss of the uniform predictor (pre-training telemetry value)."""
        return math.log(self.classes)

    def accuracy(self, params: Params, x, labels, batch: int = 256) -> float:
        hits, n = 0, 0
        fwd = _jit_forward(self)
        for i in range(0, len(x), batch):
            logits = fwd(params, x[i:i + batch])
            yb = labels[i:i + batch]
            hits += int(jnp.sum(jnp.argmax(logits, -1) == yb))
            n += int(np.size(yb))
        return hits / max(n, 1)

    # -- cost profile ------------------------------------------------------

    def layer_costs(self) -> List[cm.LayerCost]:
        raise NotImplementedError


@functools.lru_cache(maxsize=32)
def _jit_forward(model: SplitModel):
    """Compiled forward per handle, shared across eval rounds."""
    return jax.jit(lambda p, x: model.forward(p, x))


# ---------------------------------------------------------------------------
# layer-list families (VGG-11 / MLP) — blocks are vgg.py layers
# ---------------------------------------------------------------------------


class _LayerListModel(SplitModel):
    """Shared plumbing for the ``(plan, params)`` layer-list models."""

    def apply_block(self, i, p, x):
        return vgg._apply_layer(self.block_kinds[i], p, x)

    def loss(self, logits, labels):
        return vgg.xent_loss(logits, labels)

    def masked_loss(self, logits, labels, mask):
        return vgg.masked_xent_loss(logits, labels, mask)


_VGG_PLAN: Tuple[str, ...] = tuple(
    "pool" if item == "M" else "conv" for item in cm.VGG11_PLAN
) + ("fc", "fc", "fc_last")


@dataclasses.dataclass(frozen=True)
class VGGSplitModel(_LayerListModel):
    width_mult: float = 1.0
    classes: int = 10
    image: int = 32

    @property
    def block_kinds(self):
        return _VGG_PLAN

    def init(self, key):
        plan, params = vgg.init_vgg11(key, self.width_mult, self.classes,
                                      self.image)
        assert plan == self.block_kinds
        return params

    def layer_costs(self):
        return cm.vgg11_layers(self.width_mult, image=self.image,
                               classes=self.classes)


@dataclasses.dataclass(frozen=True)
class MLPSplitModel(_LayerListModel):
    sizes: Tuple[int, ...] = (3072, 128, 64, 10)

    @property
    def classes(self) -> int:
        return self.sizes[-1]

    @property
    def block_kinds(self):
        return ("fc",) * (len(self.sizes) - 2) + ("fc_last",)

    def prepare_inputs(self, x):
        # all-fc stack on image data: flatten the sample dims once up front
        # so packed (slots, width, H, W, C) batches hit block 0 as features.
        return x.reshape(x.shape[0], x.shape[1], -1) if x.ndim > 3 else x

    def init(self, key):
        _, params = vgg.init_mlp(key, self.sizes)
        return params

    def layer_costs(self):
        return vgg.mlp_layer_costs(self.sizes)


# ---------------------------------------------------------------------------
# sequence families (transformer / MoE / SSM) — blocks are arch_layers entries
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _seq_blocks(cfg: ArchConfig) -> Tuple[Tuple[str, int], ...]:
    """(kind, layer_idx) per block, 1:1 with ``costmodel.arch_layers``."""
    blocks: List[Tuple[str, int]] = [("embed", -1)]
    for i in range(cfg.n_layers):
        blocks.append(("attn" if cfg.kind(i) == "A" else "ssm", i))
        if cfg.d_ff:
            blocks.append(("ffn", i))
    blocks.append(("head", -1))
    return tuple(blocks)


@dataclasses.dataclass(frozen=True)
class SeqSplitModel(SplitModel):
    """Token split model over a decoder-only ``ArchConfig``.

    The embedding block stays device-side (``min_cut=1``): tokens are
    integers, so no gradient can cross the cut below the embedding.
    """

    cfg: ArchConfig
    seq_len: int = 32

    input_kind = "tokens"
    min_cut = 1

    def __post_init__(self):
        assert self.cfg.enc_layers == 0, "split models are decoder-only"
        assert not self.cfg.tie_embeddings, (
            "tied embeddings couple the embed and head blocks across the cut")

    @property
    def classes(self) -> int:
        return self.cfg.vocab

    @property
    def block_kinds(self):
        return tuple(kind for kind, _ in _seq_blocks(self.cfg))

    def init(self, key):
        cfg = self.cfg
        full = params_lib.init_params(key, model_lib.build_template(cfg))
        pat = model_lib.pattern_of(cfg)
        blocks: Params = []
        for kind, li in _seq_blocks(cfg):
            if kind == "embed":
                blocks.append({"embed": full["embed"]})
            elif kind == "head":
                blocks.append({"final_norm": full["final_norm"],
                               "unembed": full["unembed"]})
            else:
                u, j = divmod(li, len(pat))
                sub = jax.tree.map(lambda a: a[u], full["blocks"][f"s{j}"])
                if kind == "ffn":
                    blocks.append({"ln2": sub["ln2"], "ffn": sub["ffn"]})
                elif kind == "attn":
                    blocks.append({"ln1": sub["ln1"], "attn": sub["attn"]})
                else:
                    blocks.append({"ln1": sub["ln1"], "mamba": sub["mamba"]})
        return blocks

    def apply_block(self, i, p, x):
        cfg = self.cfg
        kind = self.block_kinds[i]
        if kind == "embed":
            return jnp.take(p["embed"], x, axis=0)
        if kind == "head":
            return rms_norm(x, p["final_norm"], cfg.norm_eps) @ p["unembed"]
        if kind == "ffn":
            h = rms_norm(x, p["ln2"], cfg.norm_eps)
            return x + model_lib._ffn_apply(h, p["ffn"], cfg)
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        if kind == "attn":
            return x + self._attention(h, p["attn"])
        return x + ssm_lib.mamba_block(h, p["mamba"], cfg)

    def _attention(self, h, p):
        from repro.kernels.flash_attention import ops as flash_ops
        cfg = self.cfg
        b, s, _ = h.shape
        positions = jnp.arange(s)[None, :]
        q, k, v = model_lib._proj_qkv(h, p, cfg, positions)
        o = flash_ops.gqa_attention(q, k, v, causal=True,
                                    impl=flash_ops.default_impl())
        return o.reshape(b, s, cfg.n_heads * cfg.hd) @ p["wo"]

    def loss(self, logits, labels):
        logp = jax.nn.log_softmax(logits.astype(jnp.float32))
        ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
        return -jnp.mean(ll)

    def masked_loss(self, logits, labels, mask):
        # mask is per *sample* (one sequence); broadcast over the seq axis so
        # padded slots contribute an exact 0, matching the image contract.
        logp = jax.nn.log_softmax(logits.astype(jnp.float32))
        ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
        denom = jnp.maximum(jnp.sum(mask), 1.0) * labels.shape[-1]
        return -jnp.sum(ll * mask[:, None]) / denom

    def layer_costs(self):
        # arch_layers prices per *token*; the FL data unit is one sequence.
        per_tok = cm.arch_layers(self.cfg, self.seq_len, sf=4)
        return [dataclasses.replace(
            lc,
            flops_fwd=lc.flops_fwd * self.seq_len,
            flops_bwd=lc.flops_bwd * self.seq_len,
            mem_act_per_sample=lc.mem_act_per_sample * self.seq_len)
            for lc in per_tok]


# ---------------------------------------------------------------------------
# smoke-size FL zoo configs (registered in repro.models.registry)
# ---------------------------------------------------------------------------

FL_TRANSFORMER = ArchConfig(
    name="fl-transformer", family="dense", n_layers=2, d_model=64,
    n_heads=2, n_kv_heads=2, d_ff=128, vocab=128,
    source="smoke-size GQA decoder for FL split training")

FL_MOE = ArchConfig(
    name="fl-moe", family="moe", n_layers=2, d_model=64,
    n_heads=2, n_kv_heads=1, d_ff=64, vocab=128,
    moe=MoEConfig(n_experts=4, top_k=2),
    source="smoke-size MoE decoder for FL split training")

FL_SSM = ArchConfig(
    name="fl-ssm", family="ssm", n_layers=2, d_model=64,
    n_heads=1, n_kv_heads=1, d_ff=0, vocab=128,
    ssm=SSMConfig(d_state=16, d_conv=4, head_dim=32, expand=2, chunk_size=32),
    source="smoke-size Mamba-2 SSD decoder for FL split training")
