"""Parameter templates.

A *template* is a pytree whose leaves are :class:`PSpec` descriptors
(shape + logical axis names + init kind). From one template we derive:

* ``init_params(rng, template)``      -> real arrays (smoke tests, FL sim)
* ``abstract_params(template)``       -> ShapeDtypeStructs (multi-pod dry-run)
* ``partition_specs(template, rules)``-> jax.sharding.PartitionSpec pytree

Keeping shape, init and sharding in a single descriptor guarantees the three
views can never drift apart.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class PSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]   # logical axis name per dim (None = replicated)
    init: str = "normal"              # normal | zeros | ones | embed | small
    dtype: Optional[jnp.dtype] = None

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_pspec(x) -> bool:
    return isinstance(x, PSpec)


def _fan_in(shape: Tuple[int, ...]) -> int:
    # stacked-layer leading dims are not fan-in; use 2nd-to-last for matmuls
    if len(shape) >= 2:
        return shape[-2]
    return max(shape[0], 1)


def init_leaf(rng: jax.Array, spec: PSpec, dtype) -> jax.Array:
    dt = spec.dtype or dtype
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dt)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dt)
    scale = {"normal": 1.0 / math.sqrt(_fan_in(spec.shape)),
             "embed": 0.02, "small": 0.01}[spec.init]
    return (jax.random.normal(rng, spec.shape, jnp.float32) * scale).astype(dt)


def init_params(rng: jax.Array, template, dtype=jnp.float32):
    leaves, treedef = jax.tree.flatten(template, is_leaf=is_pspec)
    rngs = jax.random.split(rng, len(leaves))
    return jax.tree.unflatten(
        treedef, [init_leaf(r, s, dtype) for r, s in zip(rngs, leaves)])


def abstract_params(template, dtype=jnp.bfloat16):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype or dtype),
        template, is_leaf=is_pspec)


# Logical-axis -> mesh-axis rules. A rule value may be a string, a tuple of
# mesh axes, or None.
DEFAULT_RULES = {
    "vocab": "model",
    "embed": "data",       # FSDP-ish: gathered on use, keeps HBM in budget
    "q_heads": "model",    # fused n_heads*head_dim
    "kv_fused": "model",
    "mlp": "model",
    "experts": "model",    # expert parallelism
    "moe_d": "data",       # expert weight d_model dim (FSDP-ish)
    "moe_f": None,         # expert weight hidden dim
    "ssm_in": "model",     # fused d_inner
    "nheads": "model",     # SSD heads
    "hd": "model",         # per-head dim (KV caches)
    "batch": "data",
    "layers": None,
    "seq": None,
}


def rules_for_mesh(mesh, overrides=None):
    rules = dict(DEFAULT_RULES)
    if "pod" in mesh.axis_names:
        rules["batch"] = ("pod", "data")
    if overrides:
        rules.update(overrides)
    return rules


def _axis_size(mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    return int(np.prod([mesh.shape[a] for a in axes]))


def partition_specs(template, mesh, rules=None):
    """Map logical axes to mesh axes, dropping non-divisible shardings."""
    rules = rules or rules_for_mesh(mesh)

    def one(spec: PSpec):
        out = []
        used = set()
        for dim, ax in zip(spec.shape, spec.axes):
            mesh_ax = rules.get(ax) if ax else None
            if mesh_ax is not None:
                flat = (mesh_ax,) if isinstance(mesh_ax, str) else tuple(mesh_ax)
                if dim % _axis_size(mesh, mesh_ax) != 0 or used & set(flat):
                    mesh_ax = None
                else:
                    used |= set(flat)
            out.append(mesh_ax)
        return P(*out)

    return jax.tree.map(one, template, is_leaf=is_pspec)


def spec_bytes(template, dtype=jnp.bfloat16) -> int:
    total = 0
    for leaf in jax.tree.leaves(template, is_leaf=is_pspec):
        dt = leaf.dtype or dtype
        total += int(np.prod(leaf.shape)) * jnp.dtype(dt).itemsize
    return total
