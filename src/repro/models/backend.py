"""Compute-backend switch: pure-jnp (default, CPU/compile-safe) vs Pallas
kernels (TPU target; interpret=True runs the kernel bodies on CPU).

    with backend.use_pallas(interpret=True):
        logits = model.forward(params, batch, cfg)

Model code consults :func:`attention_impl` / :func:`ssd_impl`; shapes that
don't meet the kernels' tiling constraints fall back to jnp silently (the
kernels are drop-in replacements validated against the same oracles).
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Optional

_state = threading.local()


@dataclasses.dataclass(frozen=True)
class BackendConfig:
    pallas: bool = False
    interpret: bool = False
    block_q: int = 128
    block_k: int = 128
    ssd_block_h: int = 8


def current() -> BackendConfig:
    return getattr(_state, "cfg", BackendConfig())


@contextlib.contextmanager
def use_pallas(interpret: bool = False, **kw):
    prev = current()
    _state.cfg = BackendConfig(pallas=True, interpret=interpret, **kw)
    try:
        yield
    finally:
        _state.cfg = prev


def attention_ok(seq: int, head_dim: int, block_q: int, block_k: int) -> bool:
    return (seq % min(block_q, seq) == 0 and seq % min(block_k, seq) == 0
            and head_dim in (64, 80, 128, 256))


def ssd_ok(seq: int, n_heads: int, chunk: int, block_h: int) -> bool:
    return seq % min(chunk, seq) == 0 and n_heads % min(block_h, n_heads) == 0
