from repro.models.registry import ModelBundle, bundle_for, get_bundle, demo_batch

__all__ = ["ModelBundle", "bundle_for", "get_bundle", "demo_batch"]
