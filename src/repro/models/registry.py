"""Model registries.

* arch-id -> (template, init, apply, serve) bundle for the LLM stack;
* FL split-model registry: name -> builder producing the ``(plan, params,
  layer costs)`` triple the FL simulation consumes, replacing the
  ``if model == "vgg"`` string dispatch that was duplicated across the
  trainer, examples and benchmarks.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import configs as cfg_lib
from repro.configs.base import ArchConfig
from repro.models import model as model_lib
from repro.models import params as params_lib


@dataclasses.dataclass(frozen=True)
class ModelBundle:
    cfg: ArchConfig
    build_template: Callable[[], Any]
    init: Callable[[jax.Array], Any]                     # rng -> params
    forward: Callable[..., jax.Array]
    loss_fn: Callable[..., jax.Array]
    serve_step: Callable[..., Any]
    cache_template: Callable[..., Any]

    def abstract_params(self, dtype=jnp.bfloat16):
        return params_lib.abstract_params(self.build_template(), dtype)


def bundle_for(cfg: ArchConfig) -> ModelBundle:
    template = model_lib.build_template(cfg)
    return ModelBundle(
        cfg=cfg,
        build_template=lambda: template,
        init=lambda rng, dtype=jnp.float32: params_lib.init_params(rng, template, dtype),
        forward=lambda p, b, **kw: model_lib.forward(p, b, cfg, **kw),
        loss_fn=lambda p, b, **kw: model_lib.loss_fn(p, b, cfg, **kw),
        serve_step=lambda p, c, t, pos, **kw: model_lib.serve_step(p, c, t, pos, cfg, **kw),
        cache_template=lambda batch, cache_len, enc_len=0: model_lib.cache_template(
            cfg, batch, cache_len, enc_len),
    )


def get_bundle(arch: str, smoke: bool = False) -> ModelBundle:
    cfg = cfg_lib.get_smoke_config(arch) if smoke else cfg_lib.get_config(arch)
    return bundle_for(cfg)


# ---------------------------------------------------------------------------
# FL split-model registry
# ---------------------------------------------------------------------------

# name -> builder(key, spec) -> (SplitModel, params, List[LayerCost]).
# ``spec`` is any object exposing the scenario fields the builder needs
# (width_mult, classes, mlp_hidden, seq_len, ...) — typically
# ``repro.fl.sim.Scenario``. The returned handle implements the
# ``repro.models.split_model.SplitModel`` contract and is what every FL
# engine trains through.
FL_MODELS: Dict[str, Callable[..., Tuple[Any, Any, Any]]] = {}


def register_fl_model(name: str):
    """Decorator registering an FL split-model builder; duplicates raise."""
    def deco(fn):
        if name in FL_MODELS:
            raise ValueError(f"FL model {name!r} already registered")
        FL_MODELS[name] = fn
        return fn
    return deco


def build_fl_model(name: str, key: jax.Array, spec) -> Tuple[Any, Any, Any]:
    """Resolve + build ``name`` -> (SplitModel, params, layer costs)."""
    if name not in FL_MODELS:
        raise KeyError(f"unknown FL model {name!r}; known: {sorted(FL_MODELS)}")
    return FL_MODELS[name](key, spec)


@register_fl_model("vgg")
def _build_vgg(key: jax.Array, spec):
    from repro.models import split_model as sm
    model = sm.VGGSplitModel(width_mult=spec.width_mult, classes=spec.classes)
    return model, model.init(key), model.layer_costs()


@register_fl_model("mlp")
def _build_mlp(key: jax.Array, spec):
    from repro.models import split_model as sm
    sizes = (3072, *getattr(spec, "mlp_hidden", (128, 64)), spec.classes)
    model = sm.MLPSplitModel(sizes=sizes)
    return model, model.init(key), model.layer_costs()


@register_fl_model("transformer")
def _build_transformer(key: jax.Array, spec):
    from repro.models import split_model as sm
    model = sm.SeqSplitModel(sm.FL_TRANSFORMER,
                             seq_len=getattr(spec, "seq_len", 32))
    return model, model.init(key), model.layer_costs()


@register_fl_model("moe")
def _build_moe(key: jax.Array, spec):
    from repro.models import split_model as sm
    model = sm.SeqSplitModel(sm.FL_MOE, seq_len=getattr(spec, "seq_len", 32))
    return model, model.init(key), model.layer_costs()


@register_fl_model("ssm")
def _build_ssm(key: jax.Array, spec):
    from repro.models import split_model as sm
    model = sm.SeqSplitModel(sm.FL_SSM, seq_len=getattr(spec, "seq_len", 32))
    return model, model.init(key), model.layer_costs()


def demo_batch(cfg: ArchConfig, batch: int, seq: int, rng=None,
               enc_len: int = 64) -> Dict[str, jax.Array]:
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    r1, r2 = jax.random.split(rng)
    out = {
        "tokens": jax.random.randint(r1, (batch, seq), 0, cfg.vocab, jnp.int32),
        "labels": jax.random.randint(r2, (batch, seq), 0, cfg.vocab, jnp.int32),
    }
    if cfg.enc_layers:
        out["enc_frames"] = jax.random.normal(
            jax.random.PRNGKey(7), (batch, enc_len, cfg.d_model), jnp.float32)
    return out
