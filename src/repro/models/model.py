"""Unified decoder / encoder-decoder model covering all assigned families.

One config-driven implementation: dense, GQA (+bias, +qk-norm), MoE,
Mamba-2 SSD, hybrid interleave (Jamba), early-fusion VLM (discrete VQ tokens
in the shared vocab) and enc-dec audio (frame-embedding frontend stub).

Layers are scanned over "units" (one repetition of ``cfg.layer_pattern``) so
HLO size is O(pattern), not O(depth).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import backend
from repro.models import ssm as ssm_lib
from repro.models.layers import (apply_rope, causal_attention, decode_attention,
                                 rms_norm, swiglu)
from repro.models.moe import moe_ffn
from repro.models.params import PSpec

@dataclasses.dataclass(frozen=True)
class ActShardings:
    """Activation sharding constraints (GSPMD anchor points).

    Without these, weight shardings (e.g. the embed table's d_model over
    'data') win sharding propagation and activations lose their batch
    sharding — observed as global-batch-sized buffers per device.
    """
    residual: Optional[P] = None     # (batch, seq, d_model)
    logits: Optional[P] = None       # (batch, seq, vocab)

    def constrain(self, x, which: str = "residual"):
        spec = getattr(self, which)
        if spec is None:
            return x
        return jax.lax.with_sharding_constraint(x, spec)


_NO_SHARDING = ActShardings()


# ---------------------------------------------------------------------------
# templates
# ---------------------------------------------------------------------------


def pattern_of(cfg: ArchConfig) -> str:
    if cfg.layer_pattern is not None:
        return cfg.layer_pattern
    return "M" if cfg.family == "ssm" else "A"


def n_units(cfg: ArchConfig) -> int:
    pat = pattern_of(cfg)
    assert cfg.n_layers % len(pat) == 0, (cfg.n_layers, pat)
    return cfg.n_layers // len(pat)


def _attn_template(cfg: ArchConfig, u: int, cross: bool = False) -> Dict[str, PSpec]:
    d, hd = cfg.d_model, cfg.hd
    nh, kv = cfg.n_heads, cfg.n_kv_heads
    t = {
        "wq": PSpec((u, d, nh * hd), ("layers", "embed", "q_heads")),
        "wk": PSpec((u, d, kv * hd), ("layers", "embed", "kv_fused")),
        "wv": PSpec((u, d, kv * hd), ("layers", "embed", "kv_fused")),
        "wo": PSpec((u, nh * hd, d), ("layers", "q_heads", "embed")),
    }
    if cfg.qkv_bias and not cross:
        t["bq"] = PSpec((u, nh * hd), ("layers", "q_heads"), "zeros")
        t["bk"] = PSpec((u, kv * hd), ("layers", "kv_fused"), "zeros")
        t["bv"] = PSpec((u, kv * hd), ("layers", "kv_fused"), "zeros")
    if cfg.qk_norm and not cross:
        t["q_norm"] = PSpec((u, hd), ("layers", None), "ones")
        t["k_norm"] = PSpec((u, hd), ("layers", None), "ones")
    return t


def _ffn_template(cfg: ArchConfig, u: int, layer_in_unit: int, global_stride: int) -> Optional[Dict[str, PSpec]]:
    if cfg.d_ff == 0:
        return None
    d, f = cfg.d_model, cfg.d_ff
    moe = cfg.moe
    is_moe = moe is not None and (layer_in_unit % moe.every_n == moe.every_n - 1)
    if is_moe:
        e = moe.n_experts
        # expert weights get their own logical axes so sharding variants can
        # move them independently of the dense path ("moe_d" defaults to the
        # same mesh axis as "embed"; "moe_f" defaults to replicated)
        return {
            "router": PSpec((u, d, e), ("layers", "embed", None), "small"),
            "w1": PSpec((u, e, d, f), ("layers", "experts", "moe_d", "moe_f")),
            "w3": PSpec((u, e, d, f), ("layers", "experts", "moe_d", "moe_f")),
            "w2": PSpec((u, e, f, d), ("layers", "experts", "moe_f", "moe_d")),
        }
    return {
        "w1": PSpec((u, d, f), ("layers", "embed", "mlp")),
        "w3": PSpec((u, d, f), ("layers", "embed", "mlp")),
        "w2": PSpec((u, f, d), ("layers", "mlp", "embed")),
    }


def _mamba_template(cfg: ArchConfig, u: int) -> Dict[str, PSpec]:
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.d_inner(d)
    n = s.n_heads(d)
    ds = s.d_state
    conv_ch = d_in + 2 * ds
    return {
        "w_xz": PSpec((u, d, 2 * d_in), ("layers", "embed", "ssm_in")),
        "w_bc": PSpec((u, d, 2 * ds), ("layers", "embed", None)),
        "w_dt": PSpec((u, d, n), ("layers", "embed", "nheads")),
        "dt_bias": PSpec((u, n), ("layers", "nheads"), "zeros"),
        "a_log": PSpec((u, n), ("layers", "nheads"), "zeros"),
        "d_skip": PSpec((u, n), ("layers", "nheads"), "ones"),
        "conv_w": PSpec((u, s.d_conv, conv_ch), ("layers", None, "ssm_in")),
        "conv_b": PSpec((u, conv_ch), ("layers", "ssm_in"), "zeros"),
        "norm": PSpec((u, d_in), ("layers", "ssm_in"), "ones"),
        "w_out": PSpec((u, d_in, d), ("layers", "ssm_in", "embed")),
    }


def _unit_template(cfg: ArchConfig, u: int, cross: bool = False) -> Dict[str, Any]:
    pat = pattern_of(cfg)
    unit: Dict[str, Any] = {}
    for j, kind in enumerate(pat):
        sub: Dict[str, Any] = {"ln1": PSpec((u, cfg.d_model), ("layers", "embed"), "ones")}
        if kind == "A":
            sub["attn"] = _attn_template(cfg, u)
        else:
            sub["mamba"] = _mamba_template(cfg, u)
        ffn = _ffn_template(cfg, u, j, len(pat))
        if ffn is not None:
            sub["ln2"] = PSpec((u, cfg.d_model), ("layers", "embed"), "ones")
            sub["ffn"] = ffn
        if cross:
            sub["ln_x"] = PSpec((u, cfg.d_model), ("layers", "embed"), "ones")
            sub["xattn"] = _attn_template(cfg, u, cross=True)
        unit[f"s{j}"] = sub
    return unit


def build_template(cfg: ArchConfig) -> Dict[str, Any]:
    d = cfg.d_model
    t: Dict[str, Any] = {
        "embed": PSpec((cfg.vocab, d), ("vocab", "embed"), "embed"),
        "final_norm": PSpec((d,), ("embed",), "ones"),
        "blocks": _unit_template(cfg, n_units(cfg), cross=cfg.enc_layers > 0),
    }
    if not cfg.tie_embeddings:
        t["unembed"] = PSpec((d, cfg.vocab), ("embed", "vocab"))
    if cfg.enc_layers:
        enc_cfg = _encoder_cfg(cfg)
        t["encoder"] = {
            "blocks": _unit_template(enc_cfg, cfg.enc_layers),
            "final_norm": PSpec((d,), ("embed",), "ones"),
        }
    return t


def _encoder_cfg(cfg: ArchConfig) -> ArchConfig:
    import dataclasses
    return dataclasses.replace(cfg, layer_pattern="A", moe=None, enc_layers=0,
                               n_layers=cfg.enc_layers, qkv_bias=False,
                               qk_norm=False)


# ---------------------------------------------------------------------------
# forward building blocks
# ---------------------------------------------------------------------------


def _proj_qkv(x, p, cfg: ArchConfig, positions):
    b, s, _ = x.shape
    hd, nh, kvh = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, nh, hd)
    k = k.reshape(b, s, kvh, hd)
    v = v.reshape(b, s, kvh, hd)
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if positions is not None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _attention(x, p, cfg: ArchConfig, *, causal: bool = True):
    b, s, _ = x.shape
    positions = jnp.arange(s)[None, :]
    q, k, v = _proj_qkv(x, p, cfg, positions if causal else None)
    be = backend.current()
    if be.pallas and backend.attention_ok(s, cfg.hd, be.block_q, be.block_k):
        from repro.kernels.flash_attention.ops import gqa_attention
        o = gqa_attention(q, k, v, causal=causal,
                          block_q=min(be.block_q, s), block_k=min(be.block_k, s),
                          interpret=be.interpret)
    else:
        o = causal_attention(q, k, v, causal=causal)
    return o.reshape(b, s, cfg.n_heads * cfg.hd) @ p["wo"]


def _cross_attention(x, enc_out, p, cfg: ArchConfig):
    b, s, _ = x.shape
    hd, nh, kvh = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    q = (x @ p["wq"]).reshape(b, s, nh, hd)
    k = (enc_out @ p["wk"]).reshape(b, enc_out.shape[1], kvh, hd)
    v = (enc_out @ p["wv"]).reshape(b, enc_out.shape[1], kvh, hd)
    o = causal_attention(q, k, v, causal=False)
    return o.reshape(b, s, nh * hd) @ p["wo"]


def _ffn_apply(x, p, cfg: ArchConfig):
    if "router" in p:
        return moe_ffn(x, p, cfg.moe)
    return swiglu(x, p["w1"], p["w3"], p["w2"])


def _sublayer_seq(x, sub, kind: str, cfg: ArchConfig, enc_out=None, causal=True):
    h = rms_norm(x, sub["ln1"], cfg.norm_eps)
    if kind == "A":
        x = x + _attention(h, sub["attn"], cfg, causal=causal)
    else:
        x = x + ssm_lib.mamba_block(h, sub["mamba"], cfg)
    if "xattn" in sub and enc_out is not None:
        h = rms_norm(x, sub["ln_x"], cfg.norm_eps)
        x = x + _cross_attention(h, enc_out, sub["xattn"], cfg)
    if "ffn" in sub:
        h = rms_norm(x, sub["ln2"], cfg.norm_eps)
        x = x + _ffn_apply(h, sub["ffn"], cfg)
    return x


def _scan_units(x, blocks, cfg: ArchConfig, enc_out=None, *, causal=True,
                remat: bool = False, acts: ActShardings = _NO_SHARDING,
                unroll: bool = False):
    pat = pattern_of(cfg)

    def unit(xc, unit_params):
        for j, kind in enumerate(pat):
            xc = _sublayer_seq(xc, unit_params[f"s{j}"], kind, cfg, enc_out, causal)
            xc = acts.constrain(xc)
        return xc

    if remat:
        unit = jax.checkpoint(unit)
    y, _ = jax.lax.scan(lambda c, p: (unit(c, p), None), x, blocks,
                        unroll=unroll)
    return y


# ---------------------------------------------------------------------------
# public: sequence-mode forward (train / prefill)
# ---------------------------------------------------------------------------


def forward(params, batch: Dict[str, jax.Array], cfg: ArchConfig,
            *, remat: bool = False,
            acts: ActShardings = _NO_SHARDING,
            unroll: bool = False) -> jax.Array:
    """batch: tokens (B,S) int32 [+ enc_frames (B,T,D) for audio]."""
    tokens = batch["tokens"]
    x = acts.constrain(jnp.take(params["embed"], tokens, axis=0))

    enc_out = None
    if cfg.enc_layers:
        enc_cfg = _encoder_cfg(cfg)
        e = acts.constrain(batch["enc_frames"].astype(x.dtype))
        e = _scan_units(e, params["encoder"]["blocks"], enc_cfg, causal=False,
                        remat=remat, acts=acts, unroll=unroll)
        enc_out = rms_norm(e, params["encoder"]["final_norm"], cfg.norm_eps)

    x = _scan_units(x, params["blocks"], cfg, enc_out, causal=True, remat=remat,
                    acts=acts, unroll=unroll)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    unembed = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    return acts.constrain(x @ unembed, "logits")


def loss_fn(params, batch, cfg: ArchConfig, *, remat: bool = False,
            acts: ActShardings = _NO_SHARDING, unroll: bool = False) -> jax.Array:
    logits = forward(params, batch, cfg, remat=remat, acts=acts, unroll=unroll)
    labels = batch["labels"]
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(
        logits.astype(jnp.float32), labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


# ---------------------------------------------------------------------------
# public: decode (serve_step) with per-sublayer caches
# ---------------------------------------------------------------------------


def cache_template(cfg: ArchConfig, batch: int, cache_len: int,
                   enc_len: int = 0) -> Dict[str, Any]:
    """PSpec pytree for the decode cache (stacked over units)."""
    pat = pattern_of(cfg)
    u = n_units(cfg)
    hd, kvh = cfg.hd, cfg.n_kv_heads
    blocks: Dict[str, Any] = {}
    for j, kind in enumerate(pat):
        if kind == "A":
            blocks[f"s{j}"] = {
                "k": PSpec((u, batch, cache_len, kvh, hd),
                           ("layers", "batch", "seq", None, "hd"), "zeros"),
                "v": PSpec((u, batch, cache_len, kvh, hd),
                           ("layers", "batch", "seq", None, "hd"), "zeros"),
            }
        else:
            s = cfg.ssm
            d_in = s.d_inner(cfg.d_model)
            blocks[f"s{j}"] = {
                "conv": PSpec((u, batch, s.d_conv - 1, d_in + 2 * s.d_state),
                              ("layers", "batch", None, "ssm_in"), "zeros"),
                "h": PSpec((u, batch, s.n_heads(cfg.d_model), s.d_state, s.head_dim),
                           ("layers", "batch", "nheads", None, None), "zeros",
                           dtype=jnp.float32),
            }
        if cfg.enc_layers:
            blocks[f"s{j}"]["xk"] = PSpec(
                (u, batch, enc_len, kvh, hd),
                ("layers", "batch", "seq", None, "hd"), "zeros")
            blocks[f"s{j}"]["xv"] = PSpec(
                (u, batch, enc_len, kvh, hd),
                ("layers", "batch", "seq", None, "hd"), "zeros")
    return {"blocks": blocks}


def _decode_sublayer(x, sub, cache_sub, kind: str, cfg: ArchConfig, pos, ring: int):
    """x (B,1,D); cache entries without the unit dim."""
    b = x.shape[0]
    hd, nh, kvh = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    h = rms_norm(x, sub["ln1"], cfg.norm_eps)
    new_cache = {}
    if kind == "A":
        q, k, v = _proj_qkv(h, sub["attn"], cfg, jnp.full((b, 1), pos))
        slot = jnp.mod(pos, ring) if ring else pos
        kc = jax.lax.dynamic_update_slice(cache_sub["k"], k.astype(cache_sub["k"].dtype),
                                          (0, slot, 0, 0))
        vc = jax.lax.dynamic_update_slice(cache_sub["v"], v.astype(cache_sub["v"].dtype),
                                          (0, slot, 0, 0))
        # with a ring buffer every slot is valid once pos >= ring; positions
        # are only used for masking so pass the cache-local bound.
        mask_pos = jnp.minimum(pos, kc.shape[1] - 1)
        o = decode_attention(q, kc, vc, mask_pos)
        x = x + o.reshape(b, 1, nh * hd) @ sub["attn"]["wo"]
        new_cache.update(k=kc, v=vc)
    else:
        o, conv_state, hstate = ssm_lib.mamba_step(h, sub["mamba"], cfg,
                                                   cache_sub["conv"], cache_sub["h"])
        x = x + o
        new_cache.update(conv=conv_state, h=hstate)
    if "xattn" in sub:
        hx = rms_norm(x, sub["ln_x"], cfg.norm_eps)
        q = (hx @ sub["xattn"]["wq"]).reshape(b, 1, nh, hd)
        enc_len = cache_sub["xk"].shape[1]
        o = decode_attention(q, cache_sub["xk"], cache_sub["xv"], enc_len - 1)
        x = x + o.reshape(b, 1, nh * hd) @ sub["xattn"]["wo"]
        new_cache.update(xk=cache_sub["xk"], xv=cache_sub["xv"])
    if "ffn" in sub:
        hf = rms_norm(x, sub["ln2"], cfg.norm_eps)
        x = x + _ffn_apply(hf, sub["ffn"], cfg)
    return x, new_cache


def serve_step(params, cache, tokens: jax.Array, pos: jax.Array,
               cfg: ArchConfig, *, cache_len: Optional[int] = None,
               ring: bool = False,
               acts: ActShardings = _NO_SHARDING,
               unroll: bool = False) -> Tuple[jax.Array, Dict[str, Any]]:
    """One decode step. tokens (B,1) -> logits (B,1,V), updated cache.

    ``ring=True`` treats attention caches as sliding-window ring buffers
    (the sub-quadratic long_500k path for full-attention archs).
    """
    pat = pattern_of(cfg)
    x = acts.constrain(jnp.take(params["embed"], tokens, axis=0))

    def unit(xc, xs):
        unit_params, unit_cache = xs
        new_unit_cache = {}
        for j, kind in enumerate(pat):
            # cache k inside the scan is (B, cache_len, KV, hd)
            ring_size = unit_cache[f"s{j}"]["k"].shape[1] if (ring and kind == "A") else 0
            xc, nc = _decode_sublayer(
                xc, unit_params[f"s{j}"], unit_cache[f"s{j}"],
                kind, cfg, pos, ring_size)
            xc = acts.constrain(xc)
            new_unit_cache[f"s{j}"] = nc
        return xc, new_unit_cache

    x, new_blocks = jax.lax.scan(unit, x, (params["blocks"], cache["blocks"]),
                                 unroll=unroll)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    unembed = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = acts.constrain(x @ unembed, "logits")
    return logits, {"blocks": new_blocks}


def encode_for_decode(params, enc_frames, cfg: ArchConfig):
    """Run the encoder once and precompute per-layer cross K/V (audio serve)."""
    enc_cfg = _encoder_cfg(cfg)
    e = _scan_units(enc_frames, params["encoder"]["blocks"], enc_cfg, causal=False)
    enc_out = rms_norm(e, params["encoder"]["final_norm"], cfg.norm_eps)
    return enc_out


def fill_cross_cache(params, cache, enc_out, cfg: ArchConfig):
    """Populate xk/xv cache entries from encoder output."""
    b, t, _ = enc_out.shape
    hd, kvh = cfg.hd, cfg.n_kv_heads
    pat = pattern_of(cfg)

    def per_unit(unit_params):
        out = {}
        for j in range(len(pat)):
            p = unit_params[f"s{j}"]["xattn"]
            out[f"s{j}"] = {
                "xk": (enc_out @ p["wk"]).reshape(b, t, kvh, hd),
                "xv": (enc_out @ p["wv"]).reshape(b, t, kvh, hd),
            }
        return out

    filled = jax.vmap(per_unit)(params["blocks"])
    blocks = dict(cache["blocks"])
    for j in range(len(pat)):
        sub = dict(blocks[f"s{j}"])
        sub["xk"] = filled[f"s{j}"]["xk"].astype(sub["xk"].dtype)
        sub["xv"] = filled[f"s{j}"]["xv"].astype(sub["xv"].dtype)
        blocks[f"s{j}"] = sub
    return {"blocks": blocks}
