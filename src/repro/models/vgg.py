"""VGG-11 (the paper's experiment DNN) + MLP, as *layer lists* so the DNN
partition point indexes the same layer sequence as the Table II cost model.

A model is a pair ``(plan, params)``: ``plan`` is a static tuple of layer
kinds (hashable, jit-friendly); ``params`` is a matching list of dicts of
arrays (empty dict for parameterless layers). ``forward_range`` runs layers
[lo, hi) — the primitive split training is built on.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.costmodel import VGG11_PLAN, LayerCost
from repro.kernels.fused_linear import ops as fused_ops

Plan = Tuple[str, ...]
Params = List[Dict[str, jax.Array]]


def init_vgg11(rng: jax.Array, width_mult: float = 1.0, classes: int = 10,
               image: int = 32) -> Tuple[Plan, Params]:
    plan: List[str] = []
    params: Params = []
    ci, hw = 3, image
    for item in VGG11_PLAN:
        if item == "M":
            plan.append("pool")
            params.append({})
            hw //= 2
        else:
            co = max(1, int(item * width_mult))
            rng, k = jax.random.split(rng)
            scale = math.sqrt(2.0 / (ci * 9))
            plan.append("conv")
            params.append({
                "w": jax.random.normal(k, (3, 3, ci, co)) * scale,
                "b": jnp.zeros((co,)),
            })
            ci = co
    feat = ci * hw * hw
    fc1 = max(16, int(4096 * width_mult))
    dims = [(feat, fc1), (fc1, fc1), (fc1, classes)]
    for i, (si, so) in enumerate(dims):
        rng, k = jax.random.split(rng)
        plan.append("fc_last" if i == len(dims) - 1 else "fc")
        params.append({
            "w": jax.random.normal(k, (si, so)) * math.sqrt(2.0 / si),
            "b": jnp.zeros((so,)),
        })
    return tuple(plan), params


def init_mlp(rng: jax.Array, sizes=(3072, 128, 64, 10)) -> Tuple[Plan, Params]:
    plan: List[str] = []
    params: Params = []
    for i, (si, so) in enumerate(zip(sizes[:-1], sizes[1:])):
        rng, k = jax.random.split(rng)
        plan.append("fc_last" if i == len(sizes) - 2 else "fc")
        params.append({
            "w": jax.random.normal(k, (si, so)) * math.sqrt(2.0 / si),
            "b": jnp.zeros((so,)),
        })
    return tuple(plan), params


def mlp_layer_costs(sizes=(3072, 128, 64, 10), sf: int = 4) -> List[LayerCost]:
    from repro.core.costmodel import fc_layer
    return [fc_layer(f"fc{i}", si, so, sf=sf)
            for i, (si, so) in enumerate(zip(sizes[:-1], sizes[1:]))]


def _apply_layer(kind: str, layer: Dict[str, jax.Array], x: jax.Array) -> jax.Array:
    if kind == "conv":
        y = jax.lax.conv_general_dilated(
            x, layer["w"], window_strides=(1, 1), padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        return jax.nn.relu(y + layer["b"])
    if kind == "pool":
        return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                     (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
    if kind in ("fc", "fc_last"):
        if x.ndim > 2:
            x = x.reshape(x.shape[0], -1)
        # fused matmul+bias+activation through the custom VJP on every
        # impl (dedicated Pallas fwd+bwd kernels on TPU/interpret,
        # transpose-free dot_general refs elsewhere), so split training
        # exercises the kernel path in both directions.
        act = "none" if kind == "fc_last" else "relu"
        return fused_ops.linear(x, layer["w"], layer["b"], activation=act)
    raise ValueError(kind)


def forward_range(plan: Plan, params: Params, x: jax.Array,
                  lo: int, hi: int) -> jax.Array:
    for kind, layer in zip(plan[lo:hi], params[lo:hi]):
        x = _apply_layer(kind, layer, x)
    return x


def forward(plan: Plan, params: Params, x: jax.Array) -> jax.Array:
    return forward_range(plan, params, x, 0, len(plan))


def xent_loss(logits: jax.Array, labels: jax.Array) -> jax.Array:
    logp = jax.nn.log_softmax(logits.astype(jnp.float32))
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def masked_xent_loss(logits: jax.Array, labels: jax.Array,
                     mask: jax.Array) -> jax.Array:
    """Mean cross-entropy over the valid (mask==1) rows of a padded batch.

    Equals ``xent_loss`` on the unpadded batch: padded rows contribute an
    exact 0 to the sum, so only summation length differs.
    """
    logp = jax.nn.log_softmax(logits.astype(jnp.float32))
    ll = jnp.take_along_axis(logp, labels[:, None], axis=1)[:, 0]
    return -jnp.sum(mask * ll) / jnp.maximum(jnp.sum(mask), 1.0)


@functools.lru_cache(maxsize=32)
def _jit_forward(plan: Plan):
    """Compiled forward per plan, shared across eval rounds (a fresh
    ``jax.jit`` per call would recompile on every accuracy evaluation)."""
    return jax.jit(functools.partial(forward, plan))


def accuracy(plan: Plan, params: Params, x, labels, batch: int = 256) -> float:
    hits, n = 0, 0
    fwd = _jit_forward(plan)
    for i in range(0, len(x), batch):
        logits = fwd(params, x[i:i + batch])
        hits += int(jnp.sum(jnp.argmax(logits, -1) == labels[i:i + batch]))
        n += len(x[i:i + batch])
    return hits / max(n, 1)
