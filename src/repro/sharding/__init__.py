"""Sharding substrate for the repro system.

Two families of helpers live here:

* **Model-parallel parameter sharding** — logical-axis rules mapped to
  ``jax.sharding.PartitionSpec`` trees (re-exported from
  ``repro.models.params``): ``DEFAULT_RULES``, ``partition_specs``,
  ``rules_for_mesh``.
* **Cohort-axis data parallelism** — the 1-D ``"cohort"`` mesh the sharded
  FL engine (``repro.fl.shard``) maps device *slots* over while replicating
  model parameters: ``COHORT_AXIS``, ``cohort_mesh``, and the two
  canonical specs ``SLOT_SPEC`` (leading slot axis sharded) /
  ``REPLICATED``.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, PartitionSpec

from repro.models.params import (DEFAULT_RULES, partition_specs,
                                 rules_for_mesh)

# The mesh axis the sharded cohort engine maps device slots over.
COHORT_AXIS = "cohort"

# Canonical specs for the cohort mesh: per-slot arrays shard their leading
# axis; model parameters / global reductions are replicated. Whole-run
# fused loops (repro.fl.fused_sim) stack rounds in front of the slot axis,
# so their per-slot arrays shard axis 1 instead (STACKED_SLOT_SPEC).
SLOT_SPEC = PartitionSpec(COHORT_AXIS)
STACKED_SLOT_SPEC = PartitionSpec(None, COHORT_AXIS)
REPLICATED = PartitionSpec()


@functools.lru_cache(maxsize=None)
def cohort_mesh(mesh_shape: Optional[Tuple[int, ...]] = None) -> Mesh:
    """Build the 1-D ``"cohort"`` mesh for the sharded FL engine.

    ``mesh_shape`` is the (optionally multi-dim, flattened) device count to
    request; ``None`` uses every addressable device. The mesh degrades
    gracefully: asking for more devices than the process has (e.g. on a
    single-CPU dev box) silently clamps to what is available, down to a
    1-device mesh — the sharded engine then runs as a plain fused program
    with mathematically identical results. Use
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` to exercise a
    real multi-device CPU mesh in tests.
    """
    devices = jax.devices()
    want = len(devices) if mesh_shape is None else int(np.prod(mesh_shape))
    n = max(1, min(want, len(devices)))
    return Mesh(np.asarray(devices[:n]), (COHORT_AXIS,))


__all__ = ["DEFAULT_RULES", "partition_specs", "rules_for_mesh",
           "COHORT_AXIS", "SLOT_SPEC", "STACKED_SLOT_SPEC", "REPLICATED",
           "cohort_mesh"]
