"""Logical-axis sharding rules -> PartitionSpec (see repro.models.params)."""
from repro.models.params import (DEFAULT_RULES, partition_specs,
                                 rules_for_mesh)

__all__ = ["DEFAULT_RULES", "partition_specs", "rules_for_mesh"]
