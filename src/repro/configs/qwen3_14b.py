"""qwen3-14b — dense, qk_norm, GQA [hf:Qwen/Qwen3-8B card family]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-14b", family="dense", n_layers=40, d_model=5120,
    n_heads=40, n_kv_heads=8, d_ff=17408, vocab=151936, qk_norm=True,
    head_dim=128, rope_theta=1e6, source="hf:Qwen/Qwen3-8B (family card)")

def reduced() -> ArchConfig:
    return ArchConfig(name="qwen3-14b-smoke", family="dense", n_layers=2,
                      d_model=256, n_heads=4, n_kv_heads=2, d_ff=512, vocab=512,
                      qk_norm=True, head_dim=64, source=CONFIG.source)
