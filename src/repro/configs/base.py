"""Architecture + input-shape configuration objects.

Every assigned architecture gets one module in this package exporting
``CONFIG`` (the exact published shape, cited) and ``reduced()`` (a smoke-test
variant: <=2 layers, d_model<=512, <=4 experts) of the same family.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    # layers that are MoE (every_n == 1 -> all FFN layers are MoE)
    every_n: int = 1
    # dispatch groups: tokens are routed within fixed groups (aligned to the
    # data-parallel shards) so sort/scatter stay shard-local and only the
    # expert GEMM crosses the mesh. 1 = single global group.
    dispatch_groups: int = 1


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    head_dim: int = 64
    expand: int = 2
    chunk_size: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None   # default d_model // n_heads
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid: pattern of layer kinds, tiled to n_layers. 'A'=attention 'M'=mamba
    layer_pattern: Optional[str] = None
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # encoder-decoder (audio): n_layers is decoder depth
    enc_layers: int = 0
    enc_input: Optional[str] = None  # 'audio_frames' -> frontend stub embeds
    max_seq: int = 524_288
    # sliding-window used for long_500k decode on full-attention archs
    window: int = 8192
    source: str = ""                 # citation

    @property
    def hd(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    def kind(self, layer_idx: int) -> str:
        if self.layer_pattern is None:
            return "M" if self.family == "ssm" else "A"
        pat = self.layer_pattern
        return pat[layer_idx % len(pat)]

    @property
    def n_params(self) -> int:
        """Total parameter count (embeddings + blocks), used for 6ND."""
        return _count_params(self, active_only=False)

    @property
    def n_active_params(self) -> int:
        """Params touched per token (MoE: top_k experts only)."""
        return _count_params(self, active_only=True)


def _ffn_params(cfg: ArchConfig, active_only: bool, layer_idx: int = 0) -> int:
    swiglu = 3 * cfg.d_model * cfg.d_ff
    if cfg.d_ff == 0:
        return 0
    moe = cfg.moe
    is_moe = moe is not None and (layer_idx % moe.every_n == moe.every_n - 1)
    if not is_moe:
        return swiglu
    mult = moe.top_k if active_only else moe.n_experts
    router = cfg.d_model * moe.n_experts
    return router + mult * swiglu


def _attn_params(cfg: ArchConfig) -> int:
    hd = cfg.hd
    q = cfg.d_model * cfg.n_heads * hd
    kv = 2 * cfg.d_model * cfg.n_kv_heads * hd
    o = cfg.n_heads * hd * cfg.d_model
    return q + kv + o


def _mamba_params(cfg: ArchConfig) -> int:
    s = cfg.ssm or SSMConfig()
    d_in = s.d_inner(cfg.d_model)
    nh = s.n_heads(cfg.d_model)
    in_proj = cfg.d_model * (2 * d_in + 2 * s.d_state + nh)
    conv = s.d_conv * (d_in + 2 * s.d_state)
    out = d_in * cfg.d_model
    return in_proj + conv + out + 2 * nh  # + A_log, D


def _count_params(cfg: ArchConfig, active_only: bool) -> int:
    total = cfg.vocab * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    for i in range(cfg.n_layers):
        k = cfg.kind(i)
        if k == "A":
            total += _attn_params(cfg)
        else:
            total += _mamba_params(cfg)
        total += _ffn_params(cfg, active_only, i)
        total += 2 * cfg.d_model  # norms
    for _ in range(cfg.enc_layers):
        total += _attn_params(cfg) + 3 * cfg.d_model * cfg.d_ff + 2 * cfg.d_model
        if cfg.enc_input is not None:
            pass
    if cfg.enc_layers:  # decoder cross-attention
        total += cfg.n_layers * (_attn_params(cfg) + cfg.d_model)
    return total


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str  # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}
