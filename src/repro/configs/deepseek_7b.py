"""deepseek-7b — dense llama-arch [arXiv:2401.02954]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-7b", family="dense", n_layers=30, d_model=4096,
    n_heads=32, n_kv_heads=32, d_ff=11008, vocab=102400,
    source="arXiv:2401.02954 (DeepSeek LLM 7B)")

def reduced() -> ArchConfig:
    return ArchConfig(name="deepseek-7b-smoke", family="dense", n_layers=2,
                      d_model=256, n_heads=4, n_kv_heads=4, d_ff=512, vocab=512,
                      source=CONFIG.source)
