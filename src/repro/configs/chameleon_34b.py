"""chameleon-34b — early-fusion VLM, VQ image tokens in vocab [arXiv:2405.09818].

The vision frontend (VQ-VAE tokenizer) is a stub: image patches arrive as
discrete tokens drawn from the shared vocab, so the backbone consumes one
token stream (early fusion).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="chameleon-34b", family="vlm", n_layers=48, d_model=8192,
    n_heads=64, n_kv_heads=8, d_ff=22016, vocab=65536, qk_norm=True,
    source="arXiv:2405.09818 (Chameleon)")

def reduced() -> ArchConfig:
    return ArchConfig(name="chameleon-34b-smoke", family="vlm", n_layers=2,
                      d_model=256, n_heads=4, n_kv_heads=2, d_ff=512, vocab=512,
                      qk_norm=True, source=CONFIG.source)
