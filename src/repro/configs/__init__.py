"""Config registry: ``get_config(arch_id)`` / ``get_smoke_config(arch_id)``."""
from __future__ import annotations

import importlib

from repro.configs.base import ArchConfig, MoEConfig, SSMConfig, ShapeConfig, SHAPES

# public arch id -> module name
_MODULES = {
    "deepseek-7b": "deepseek_7b",
    "mamba2-2.7b": "mamba2_2_7b",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "llama4-maverick-400b-a17b": "llama4_maverick",
    "chameleon-34b": "chameleon_34b",
    "stablelm-3b": "stablelm_3b",
    "granite-moe-1b-a400m": "granite_moe_1b",
    "qwen2.5-32b": "qwen2_5_32b",
    "qwen3-14b": "qwen3_14b",
    "seamless-m4t-medium": "seamless_m4t_medium",
}

ARCHS = tuple(_MODULES)


def _mod(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch]}")


def get_config(arch: str) -> ArchConfig:
    return _mod(arch).CONFIG


def get_smoke_config(arch: str) -> ArchConfig:
    return _mod(arch).reduced()


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


__all__ = [
    "ArchConfig", "MoEConfig", "SSMConfig", "ShapeConfig", "SHAPES",
    "ARCHS", "get_config", "get_smoke_config", "get_shape",
]
