"""seamless-m4t-medium — enc-dec multimodal (speech/text) [arXiv:2308.11596].

The mel-spectrogram + conv feature extractor frontend is a stub:
``input_specs`` provides precomputed frame embeddings (B, T_src, d_model).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium", family="audio", n_layers=12, d_model=1024,
    n_heads=16, n_kv_heads=16, d_ff=4096, vocab=256206,
    enc_layers=12, enc_input="audio_frames",
    source="arXiv:2308.11596 (SeamlessM4T medium)")

def reduced() -> ArchConfig:
    return ArchConfig(name="seamless-smoke", family="audio", n_layers=2,
                      d_model=256, n_heads=4, n_kv_heads=4, d_ff=512, vocab=512,
                      enc_layers=2, enc_input="audio_frames", source=CONFIG.source)
