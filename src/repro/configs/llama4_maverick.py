"""llama4-maverick-400b-a17b — MoE 128e top-1, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E family card]."""
from repro.configs.base import ArchConfig, MoEConfig

# MoE interleaved every second layer (dense FFN otherwise) — this is what
# makes 128e x top-1 total ~400B with ~17B active, as the model id states.
CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b", family="moe", n_layers=48, d_model=5120,
    n_heads=40, n_kv_heads=8, d_ff=8192, vocab=202048,
    moe=MoEConfig(n_experts=128, top_k=1, every_n=2), layer_pattern="AA",
    qk_norm=True, rope_theta=5e5,
    source="hf:meta-llama/Llama-4-Scout-17B-16E (family card)")

def reduced() -> ArchConfig:
    return ArchConfig(name="llama4-maverick-smoke", family="moe", n_layers=2,
                      d_model=256, n_heads=4, n_kv_heads=2, d_ff=256, vocab=512,
                      moe=MoEConfig(n_experts=4, top_k=1, every_n=2),
                      layer_pattern="AA", qk_norm=True,
                      source=CONFIG.source)
