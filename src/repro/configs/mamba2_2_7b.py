"""mamba2-2.7b — attention-free SSM, SSD (state-space duality) [arXiv:2405.21060]."""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="mamba2-2.7b", family="ssm", n_layers=64, d_model=2560,
    n_heads=0, n_kv_heads=0, d_ff=0, vocab=50280,
    ssm=SSMConfig(d_state=128), source="arXiv:2405.21060 (Mamba-2 SSD)")

def reduced() -> ArchConfig:
    return ArchConfig(name="mamba2-smoke", family="ssm", n_layers=2,
                      d_model=256, n_heads=0, n_kv_heads=0, d_ff=0, vocab=512,
                      ssm=SSMConfig(d_state=16, head_dim=32, chunk_size=32),
                      source=CONFIG.source)
