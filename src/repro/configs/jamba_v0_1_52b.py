"""jamba-v0.1-52b — hybrid Mamba+attention 1:7 interleave, MoE 16e top-2
[arXiv:2403.19887]. Every block carries an FFN; every other FFN is MoE
(the published model applies MoE at every second layer)."""
from repro.configs.base import ArchConfig, MoEConfig, SSMConfig

# Jamba block pattern: 8 layers per block, attention at index 4 -> 1:7 ratio.
_PATTERN = "MMMMAMMM"

CONFIG = ArchConfig(
    name="jamba-v0.1-52b", family="hybrid", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=8, d_ff=14336, vocab=65536,
    moe=MoEConfig(n_experts=16, top_k=2, every_n=2),
    ssm=SSMConfig(d_state=16, head_dim=64), layer_pattern=_PATTERN,
    source="arXiv:2403.19887 (Jamba)")

def reduced() -> ArchConfig:
    return ArchConfig(name="jamba-smoke", family="hybrid", n_layers=2,
                      d_model=256, n_heads=4, n_kv_heads=2, d_ff=256, vocab=512,
                      moe=MoEConfig(n_experts=4, top_k=2, every_n=2),
                      ssm=SSMConfig(d_state=16, head_dim=32, chunk_size=32),
                      layer_pattern="MA", source=CONFIG.source)
