from repro.checkpoint.store import (all_steps, gc_steps, latest_step,
                                    load_pytree, save_pytree)

__all__ = ["save_pytree", "load_pytree", "latest_step", "all_steps",
           "gc_steps"]
