"""Numpy-backed pytree checkpointing (no external deps).

Flattens a pytree to path-keyed arrays in a single ``.npz`` plus a JSON
treedef manifest; restores exactly, including dtypes (bf16 stored as uint16
views since numpy lacks bfloat16).
"""
from __future__ import annotations

import json
import os
import pathlib
import re
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _escape_segment(seg: str) -> str:
    """Escape the path separator inside one key segment, so dict keys that
    themselves contain ``/`` (e.g. ``{"a/b": ...}``) can never collide with
    genuine nesting (``{"a": {"b": ...}}``) in the flat ``.npz`` namespace."""
    return seg.replace("\\", "\\\\").replace("/", "\\/")


def _paths_and_leaves(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(_escape_segment(str(getattr(p, "key",
                                                   getattr(p, "idx", p))))
                       for p in path)
        out.append((key, leaf))
    return out


def atomic_write_bytes(fname: pathlib.Path, write_fn) -> None:
    """Write a file atomically: ``write_fn(file_object)`` fills a ``.tmp``
    sibling which is then ``os.replace``-d over ``fname``. Readers (e.g.
    ``Simulation.resume`` racing a background checkpoint writer) therefore
    only ever see absent or *complete* files, never partial ones."""
    tmp = fname.with_name(fname.name + ".tmp")
    with open(tmp, "wb") as f:
        write_fn(f)
    os.replace(tmp, fname)


def save_pytree(path, tree, step: Optional[int] = None,
                keep_last: Optional[int] = None,
                prefix: str = "step") -> pathlib.Path:
    """Write ``tree`` under ``path``; with ``step``, as ``step_NNNNNNNN.npz``.

    ``keep_last`` rotates stepped checkpoints: after a successful write,
    only the ``keep_last`` newest ``step_*`` files (counting this one) are
    kept and older ones are deleted — long runs no longer grow the
    checkpoint directory without bound. The step just written is never
    deleted, even if the directory holds stale higher-numbered steps from
    an earlier, longer run.

    ``prefix`` names the file family (default ``"step"``); side-car trees
    such as the async engine's staleness buffer use their own prefix (e.g.
    ``engine_NNNNNNNN.npz``) so they never collide with the model params.
    Rotation (``keep_last``/:func:`gc_steps`) only tracks the ``step``
    family; callers of other prefixes GC their own files. Both the ``.npz``
    and its dtype manifest are written atomically (tmp + rename).
    """
    if keep_last is not None and keep_last < 1:
        raise ValueError(f"keep_last must be >= 1, got {keep_last}")
    path = pathlib.Path(path)
    path.mkdir(parents=True, exist_ok=True)
    fname = path / (f"{prefix}_{step:08d}.npz" if step is not None
                    else "ckpt.npz")
    arrays = {}
    meta = {}
    for key, leaf in _paths_and_leaves(tree):
        if key in arrays:
            raise ValueError(f"duplicate checkpoint key {key!r}")
        arr = np.asarray(leaf)
        if arr.dtype == jnp.bfloat16:
            meta[key] = "bfloat16"
            arr = arr.view(np.uint16)
        arrays[key] = arr
    atomic_write_bytes(fname, lambda f: np.savez(f, **arrays))
    atomic_write_bytes(fname.with_suffix(".json"),
                       lambda f: f.write(json.dumps(meta).encode()))
    if step is not None and keep_last is not None and prefix == "step":
        gc_steps(path, keep_last, protect=step)
    return fname


def all_steps(path) -> list:
    """Sorted step numbers of every ``step_*.npz`` under ``path``."""
    path = pathlib.Path(path)
    if not path.exists():
        return []
    return sorted(int(m.group(1)) for f in path.glob("step_*.npz")
                  if (m := re.match(r"step_(\d+)\.npz", f.name)))


def gc_steps(path, keep_last: int, protect: Optional[int] = None) -> list:
    """Delete all but the ``keep_last`` newest ``step_*`` checkpoint pairs
    under ``path`` (and never ``protect``); returns the deleted steps."""
    if keep_last < 1:
        raise ValueError(f"keep_last must be >= 1, got {keep_last}")
    path = pathlib.Path(path)
    dropped = [s for s in all_steps(path)[:-keep_last] if s != protect]
    for s in dropped:
        (path / f"step_{s:08d}.npz").unlink(missing_ok=True)
        (path / f"step_{s:08d}.json").unlink(missing_ok=True)
    return dropped


def load_pytree(fname, like) -> Any:
    fname = pathlib.Path(fname)
    data = np.load(fname)
    meta = json.loads(fname.with_suffix(".json").read_text())
    leaves = []
    for key, leaf in _paths_and_leaves(like):
        arr = data[key]
        if meta.get(key) == "bfloat16":
            arr = arr.view(jnp.bfloat16)
        leaves.append(jnp.asarray(arr))
    return jax.tree.unflatten(jax.tree.structure(like), leaves)


def latest_step(path) -> Optional[int]:
    steps = all_steps(path)
    return steps[-1] if steps else None
