"""Numpy-backed pytree checkpointing (no external deps).

Flattens a pytree to path-keyed arrays in a single ``.npz`` plus a JSON
treedef manifest; restores exactly, including dtypes (bf16 stored as uint16
views since numpy lacks bfloat16).
"""
from __future__ import annotations

import json
import pathlib
import re
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _paths_and_leaves(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((key, leaf))
    return out


def save_pytree(path, tree, step: Optional[int] = None) -> pathlib.Path:
    path = pathlib.Path(path)
    path.mkdir(parents=True, exist_ok=True)
    fname = path / (f"step_{step:08d}.npz" if step is not None else "ckpt.npz")
    arrays = {}
    meta = {}
    for key, leaf in _paths_and_leaves(tree):
        arr = np.asarray(leaf)
        if arr.dtype == jnp.bfloat16:
            meta[key] = "bfloat16"
            arr = arr.view(np.uint16)
        arrays[key] = arr
    np.savez(fname, **arrays)
    (fname.with_suffix(".json")).write_text(json.dumps(meta))
    return fname


def load_pytree(fname, like) -> Any:
    fname = pathlib.Path(fname)
    data = np.load(fname)
    meta = json.loads(fname.with_suffix(".json").read_text())
    leaves = []
    for key, leaf in _paths_and_leaves(like):
        arr = data[key]
        if meta.get(key) == "bfloat16":
            arr = arr.view(jnp.bfloat16)
        leaves.append(jnp.asarray(arr))
    return jax.tree.unflatten(jax.tree.structure(like), leaves)


def latest_step(path) -> Optional[int]:
    path = pathlib.Path(path)
    if not path.exists():
        return None
    steps = [int(m.group(1)) for f in path.glob("step_*.npz")
             if (m := re.match(r"step_(\d+)\.npz", f.name))]
    return max(steps) if steps else None
