"""Jitted DDSRA control plane: the numpy Algorithm 1, vectorized in JAX.

``repro.core.ddsra`` is the host-side oracle: Python loops over every
(gateway m, channel j) pair, 40-trip scalar bisections for the partition /
frequency / power sub-problems (21)-(24), and a Python Kuhn-Munkres per
lambda cap for the channel assignment (26)-(29).  This module is the same
algorithm as data-parallel XLA:

* the per-(m, j) block-coordinate descent is ``vmap``-ed over all M x J
  pairs at once (the paper marks these solves "do in parallel");
* every bisection becomes a fixed-trip ``lax.scan`` (identical lo/hi/mid
  trajectory, infeasibility carried as a sticky mask instead of an early
  ``return None``), so the whole solve is branch-free;
* the lambda-cap sweep maps the jittable Kuhn-Munkres
  (:func:`repro.core.hungarian.hungarian_min_jax`) over all M*J caps and
  replicates the oracle's first-wins / 1e-12-improvement selection with a
  small ``lax.scan``;
* the channel/energy draw and the Lyapunov queue update (14) are also
  expressed in JAX, so a whole scheduling step is one jitted function of
  ``(key, queues)`` — which makes batched sweeps (``vmap`` over V values or
  seeds, ``lax.scan`` over rounds) single XLA programs
  (:meth:`DDSRAPlan.simulate_v_sweep`, used by
  ``benchmarks/theorem2_tradeoff.py``).

Precision: the numpy oracle is implicitly float64, and the bisections
resolve constraint boundaries far below float32's ~1e-7 relative grid, so
the jitted control plane always runs in **x64** (entry points trace and
execute under ``jax.experimental.enable_x64`` regardless of the global
flag; the data plane stays f32).  Parity with the oracle — identical
assignments / selected sets, Lambda and tau within 1e-6 — is pinned in
``tests/test_ddsra_jax.py``.

Ragged shop floors are padded: per-gateway device vectors are (M, n_max)
with a validity mask; padded lanes carry ``d_tilde = 0`` and are masked
out of every reduction, so they contribute exact zeros and never flip a
feasibility test.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental import enable_x64

from repro.core.ddsra import (GatewaySolution, RoundDecision, Workload, _PSI,
                              _cum)
from repro.core.hungarian import assign_channels_jax
from repro.core.lyapunov import update_queues_jax
from repro.core.network import (ChannelState, ChannelStateT, Network,
                                draw_state_jax)

_BCD_ITERS = 4        # block-coordinate descent sweeps (oracle: bcd_iters)
_PART_ITERS = 40      # bisection trips for (21), (22), (23)/(24)
_FREQ_ITERS = 40
_POW_ITERS = 60

# Incremented inside the traced bodies (Python side effects run only at
# trace time): "round" per stepwise round trace, "decide" per fused
# decide-scan trace, "sweep" per seeds x V sweep trace. Tests assert exact
# compile counts against these (tests/conftest.py ``compile_count``).
TRACE_COUNTS = {"round": 0, "decide": 0, "sweep": 0}


class _Cfg(NamedTuple):
    """NetworkConfig scalars as traced leaves (no recompile across nets)."""
    phi_dev: jnp.ndarray
    phi_gw: jnp.ndarray
    v_dev: jnp.ndarray
    v_gw: jnp.ndarray
    f_gw_max: jnp.ndarray
    f_gw_min: jnp.ndarray
    g_dev_max: jnp.ndarray
    g_gw_max: jnp.ndarray
    p_max: jnp.ndarray
    p_bs: jnp.ndarray
    b_up: jnp.ndarray
    b_down: jnp.ndarray
    n0: jnp.ndarray
    e_dev_max: jnp.ndarray
    e_gw_max: jnp.ndarray
    i_up_var: jnp.ndarray
    i_down_var: jnp.ndarray


class _Statics(NamedTuple):
    """Per-(workload, network) arrays: everything the round solve reads."""
    cfg: _Cfg
    cumf: jnp.ndarray       # (L+1,) cumulative FLOPs prefix
    cumg: jnp.ndarray       # (L+1,) cumulative memory prefix
    gamma: jnp.ndarray      # model size, bytes
    kd: jnp.ndarray         # (M, n_max) K * d_tilde, 0 on padded lanes
    f_dev: jnp.ndarray      # (M, n_max) device frequency, 1.0 on padding
    valid: jnp.ndarray      # (M, n_max) bool
    n_loc: jnp.ndarray      # (M,) devices per gateway (float)
    dev_idx: jnp.ndarray    # (M, n_max) int32 device index, 0 on padding
    path: jnp.ndarray       # (M,) path-loss factor for the JAX channel draw


# One round's ChannelState as a pytree — shared with repro.core.network
# (the fused-simulation contract; was a private _St twin here).
_St = ChannelStateT


class RoundContextT(NamedTuple):
    """Traced twin of ``repro.core.schedulers.RoundContext``: the per-round
    scheduling inputs as a pytree, so a whole trajectory of contexts is one
    stacked pytree a ``lax.scan`` can thread. Only the tensors the traced
    DDSRA round actually reads are carried — the host RoundContext's object
    references (net, workload) live in :class:`_Statics` instead."""
    queues: jnp.ndarray        # (M,) virtual-queue backlog Q_m(t)
    gamma_rates: jnp.ndarray   # (M,) participation-rate targets
    v: jnp.ndarray             # scalar Lyapunov trade-off weight


class DecisionArrays(NamedTuple):
    """Raw per-round DDSRA solver outputs as a typed pytree (was an untyped
    dict): everything Algorithm 1 decides, padded-dense over (M, J[, n_max])
    so rounds stack/scan without shape games. ``repro.fl.fused_sim`` threads
    these straight into the fused training round without leaving the device;
    :meth:`DDSRAPlan.round` repackages them as the oracle's
    :class:`RoundDecision` for the stepwise host path."""
    feasible: jnp.ndarray      # (M, J) bool
    lam: jnp.ndarray           # (M, J) round delay Lambda_{m,j} (inf = infeasible)
    l: jnp.ndarray             # (M, J, n_max) partition points
    f_gw: jnp.ndarray          # (M, J, n_max) gateway frequency split
    p_tx: jnp.ndarray          # (M, J) transmit power
    e_dev: jnp.ndarray         # (M, J, n_max) device energy used
    e_gw: jnp.ndarray          # (M, J) gateway energy used
    eye: jnp.ndarray           # (M, J) channel assignment indicator
    selected: jnp.ndarray      # (M,) bool participation
    tau: jnp.ndarray           # scalar round delay
    queues: jnp.ndarray        # (M,) post-update queues (Eq. 14)


class RoundDecisionT(NamedTuple):
    """Pytree-typed :class:`repro.core.ddsra.RoundDecision`: the *resolved*
    schedule in the exact form the fused training round consumes — per-device
    partition points scattered out of the padded (M, J, n_max) lanes, the
    trained mask with infeasible selections already failed out, and the
    realized delay. Produced traced by :func:`resolve_decision_arrays`
    (inside the fused scan) and host-side by
    ``repro.fl.sim.resolve_decision`` (the stepwise loop); the parity
    matrix pins the two bit-identical."""
    selected: jnp.ndarray      # (M,) bool scheduled participation
    trained: jnp.ndarray       # (M,) bool actually-training gateways
    l_dev: jnp.ndarray         # (N,) per-device partition points
    gw_delay: jnp.ndarray      # (M,) per-gateway delay (0 where not trained)
    delay: jnp.ndarray         # scalar realized round delay (max over trained)
    tau: jnp.ndarray           # scalar scheduler-reported round delay
    failures: jnp.ndarray      # scalar count of infeasible selections
    queues: jnp.ndarray        # (M,) post-update queues


# ---------------------------------------------------------------------------
# masked reductions over the padded device lane
# ---------------------------------------------------------------------------


def _msum(x, valid):
    return jnp.sum(jnp.where(valid, x, 0.0))


def _mmax(x, valid):
    return jnp.max(jnp.where(valid, x, -jnp.inf))


def _mmin(x, valid):
    return jnp.min(jnp.where(valid, x, jnp.inf))


def _mall(cond, valid):
    return jnp.all(jnp.where(valid, cond, True))


# ---------------------------------------------------------------------------
# link model (network.py's rate/time/energy, traced)
# ---------------------------------------------------------------------------


def _uplink_time(c: _Cfg, p, h, i_up, gamma):
    sinr = p * h / (c.b_up * c.n0 + i_up)
    r = c.b_up * jnp.log2(1.0 + sinr)
    return jnp.where(r > 0, gamma * 8.0 / r, jnp.inf)


def _uplink_energy(c: _Cfg, p, h, i_up, gamma):
    return p * _uplink_time(c, p, h, i_up, gamma)


def _downlink_time(c: _Cfg, h, i_down, gamma):
    sinr = c.p_bs * h / (c.b_down * c.n0 + i_down)
    r = c.b_down * jnp.log2(1.0 + sinr)
    return jnp.where(r > 0, gamma * 8.0 / r, jnp.inf)


# ---------------------------------------------------------------------------
# inner solvers for one (gateway, channel): fixed-trip lax.scan bisections
# ---------------------------------------------------------------------------


def _bisect(feasible, lo, hi, best0, iters: int):
    """The oracle's bisection: keep the feasible side, carry the last
    feasible payload. ``best0`` must be ``feasible(hi)``'s payload."""

    def trip(carry, _):
        lo, hi, best = carry
        mid = 0.5 * (lo + hi)
        ok, sol = feasible(mid)
        lo = jnp.where(ok, lo, mid)
        hi = jnp.where(ok, mid, hi)
        best = jax.tree.map(lambda new, old: jnp.where(ok, new, old),
                            sol, best)
        return (lo, hi, best), None

    (_, _, best), _ = lax.scan(trip, (lo, hi, best0), None, length=iters)
    return best


def _solve_partition(c: _Cfg, cumf, cumg, kd, f_dev, valid, e_dev, f_gw,
                     e_gw_budget):
    """Sub-problem (21): bisection on eta; returns (feasible, l per lane)."""
    big_l = cumf.shape[0] - 1
    tot_f, tot_g = cumf[-1], cumg[-1]

    # per-device static upper bounds from C7' (memory) and C10' (energy)
    mem_ok = cumg <= c.g_dev_max                               # (L+1,)
    e_grid = (kd * c.v_dev / c.phi_dev * f_dev ** 2)[:, None] * cumf[None, :]
    ok_static = mem_ok[None, :] & (e_grid <= e_dev[:, None])
    static_ok = _mall(ok_static.any(axis=1), valid)
    hi_static = big_l - jnp.argmax(ok_static[:, ::-1], axis=1)

    # per-device time at every cut, hoisted out of the bisection
    t_grid = kd[:, None] * (
        cumf[None, :] / (c.phi_dev * f_dev)[:, None]
        + (tot_f - cumf[None, :])
        / jnp.maximum(c.phi_gw * f_gw, 1e-9)[:, None])
    ls_ok_static = jnp.arange(big_l + 1)[None, :] <= hi_static[:, None]
    gw_e_coef = kd * c.v_gw / c.phi_gw * f_gw ** 2

    def feasible(eta):
        """Largest l per device with time <= eta, then joint C8'/C9'."""
        ok = (t_grid <= eta) & ls_ok_static
        l_pick = big_l - jnp.argmax(ok[:, ::-1], axis=1)
        per_dev_ok = _mall(ok.any(axis=1), valid)
        mem_ok_gw = _msum(tot_g - cumg[l_pick], valid) <= c.g_gw_max
        e_ok_gw = _msum(gw_e_coef * (tot_f - cumf[l_pick]),
                        valid) <= e_gw_budget
        return per_dev_ok & mem_ok_gw & e_ok_gw, l_pick

    lo = jnp.zeros_like(tot_f)
    hi = _mmax(kd, valid) * tot_f / jnp.minimum(
        c.phi_dev * _mmin(f_dev, valid),
        c.phi_gw * jnp.maximum(_mmin(f_gw, valid), 1e-9))
    ok_hi, best0 = feasible(hi)
    best = _bisect(feasible, lo, hi, best0, _PART_ITERS)
    return static_ok & ok_hi, best


def _solve_frequency(c: _Cfg, cumf, kd, f_dev, valid, n_loc, l, e_gw_budget):
    """Sub-problem (22): bisection on theta; returns (feasible, f per lane)."""
    tot = cumf[-1]
    dev_t = cumf[l] / (c.phi_dev * f_dev)        # per-sample device time
    gw_work = (tot - cumf[l]) / c.phi_gw         # cycles on gateway
    all_on_device = _mall(gw_work <= 0, valid)
    f_floor = c.f_gw_min / jnp.maximum(n_loc, 1.0)

    def f_of(theta):
        denom = theta / kd - dev_t               # padded: kd=0 -> +inf
        denom_ok = _mall(denom > 0, valid)
        f = jnp.where(valid, jnp.maximum(gw_work / denom, 0.0), 0.0)
        sum_ok = jnp.sum(f) <= c.f_gw_max
        e = _msum(kd * c.v_gw * gw_work * f ** 2, valid)
        return denom_ok & sum_ok & (e <= e_gw_budget), f

    lo = _mmax(kd * (dev_t + gw_work / c.f_gw_max), valid)
    hi = _mmax(kd * (dev_t + gw_work / jnp.maximum(f_floor, 1e3)), valid)
    hi = jnp.maximum(hi, lo * 4 + 1.0)
    ok_hi, best0 = f_of(hi)
    best = _bisect(f_of, lo, hi, best0, _FREQ_ITERS)

    feas = jnp.where(all_on_device, True, ok_hi)
    f = jnp.where(all_on_device, jnp.where(valid, f_floor, 0.0), best)
    return feas, f


def _solve_power(c: _Cfg, h_up, i_up, gamma, e_budget):
    """(23)/(24): largest transmit power whose upload energy fits.

    Opposite bisection direction from (21)/(22): a feasible mid *raises*
    ``lo`` (we want the largest feasible power), and ``lo`` is returned."""

    def fits(p):
        return _uplink_energy(c, p, h_up, i_up, gamma) <= e_budget

    def trip(carry, _):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        ok = fits(mid)
        return (jnp.where(ok, mid, lo), jnp.where(ok, hi, mid)), None

    (lo, _), _ = lax.scan(trip, (jnp.zeros_like(e_budget), c.p_max),
                          None, length=_POW_ITERS)
    p = jnp.where(fits(c.p_max), c.p_max, lo)
    return jnp.where(e_budget <= 0, 0.0, p)


def _solve_gateway(s: _Statics, kd, f_dev, valid, n_loc, e_dev, e_gw_m,
                   h_up, h_down, i_up, i_down):
    """Full BCD for one (m, j) — the traced twin of ``solve_gateway``.

    All carries are frozen the moment a sub-solve fails (sticky ``feas``
    mask), mirroring the oracle's early ``return infeasible``.
    """
    c = s.cfg
    cumf, cumg = s.cumf, s.cumg
    tot = cumf[-1]
    n_max = kd.shape[0]

    feas = n_loc > 0
    l = jnp.zeros(n_max, jnp.int32)
    f_gw = jnp.full(n_max, c.f_gw_max / jnp.maximum(n_loc, 1.0))
    p_tx = c.p_max * jnp.ones(())
    e_tra_gw = jnp.zeros(())

    for _ in range(_BCD_ITERS):
        e_up = _uplink_energy(c, p_tx, h_up, i_up, s.gamma)
        e_budget = e_gw_m - e_up
        ok_l, l_new = _solve_partition(c, cumf, cumg, kd, f_dev, valid,
                                       e_dev, f_gw, e_budget)
        ok_l = feas & ok_l
        l = jnp.where(ok_l, l_new, l)
        ok_f, f_new = _solve_frequency(c, cumf, kd, f_dev, valid, n_loc,
                                       l_new, e_budget)
        ok_f = ok_l & ok_f
        f_cand = jnp.maximum(f_new, 1e3)
        f_gw = jnp.where(ok_f, f_cand, f_gw)
        e_tra_new = _msum(kd * c.v_gw / c.phi_gw * (tot - cumf[l_new])
                          * f_cand ** 2, valid)
        e_tra_gw = jnp.where(ok_f, e_tra_new, e_tra_gw)
        p_new = _solve_power(c, h_up, i_up, s.gamma, e_gw_m - e_tra_new)
        ok_p = ok_f & (p_new > 0)
        p_tx = jnp.where(ok_p, p_new, p_tx)
        feas = ok_p

    # Lambda_{m,j} (18) and the emitted resources
    t_dev = cumf[l] / (c.phi_dev * f_dev)
    top = tot - cumf[l]
    t_gw = jnp.where(top > 0,
                     top / jnp.maximum(c.phi_gw * f_gw, 1e-9), 0.0)
    t_train = _mmax(kd * (t_dev + t_gw), valid)
    lam = (t_train + _uplink_time(c, p_tx, h_up, i_up, s.gamma)
           + _downlink_time(c, h_down, i_down, s.gamma))
    lam = jnp.where(feas, lam, jnp.inf)
    e_dev_used = kd * c.v_dev / c.phi_dev * cumf[l] * f_dev ** 2
    e_gw_used = e_tra_gw + _uplink_energy(c, p_tx, h_up, i_up, s.gamma)
    return feas, lam, l, f_gw, p_tx, e_dev_used, e_gw_used


# ---------------------------------------------------------------------------
# channel assignment (26)-(29): vmapped Hungarian over the lambda-cap sweep
# ---------------------------------------------------------------------------


def _assignment(lam, queues, v):
    """The oracle's cap sweep, batched: sort all M*J delays descending
    (a superset of ``np.unique(...)[::-1]`` — duplicates re-evaluate to the
    identical assignment and lose the strict-improvement test), solve the
    Theta assignment at every cap with the vmapped jittable Hungarian, and
    replay the first-wins / 1e-12 objective selection with a scan."""
    m_gw, j_ch = lam.shape
    finite = jnp.isfinite(lam)
    caps = jnp.sort(jnp.where(finite, lam, -jnp.inf).ravel())[::-1]

    def eval_cap(cap):
        allowed = finite & (lam <= cap + 1e-12)
        theta = jnp.where(allowed, -queues[:, None], _PSI)
        # a feasible assignment needs >=1 allowed gateway per channel
        ch_ok = ~jnp.any(jnp.all(theta >= _PSI, axis=0))
        eye = assign_channels_jax(theta)
        banned = jnp.any(jnp.where(eye > 0, theta, 0.0) >= _PSI)
        tau = jnp.max(jnp.where(eye > 0, lam, -jnp.inf))
        obj = v * tau - jnp.sum(queues * eye.sum(axis=1))
        return jnp.isfinite(cap) & ch_ok & ~banned, obj, eye

    cap_ok, objs, eyes = jax.vmap(eval_cap)(caps)

    def pick(carry, x):
        best_obj, best_idx, found = carry
        ok, obj, idx = x
        better = ok & (~found | (obj < best_obj - 1e-12))
        return (jnp.where(better, obj, best_obj),
                jnp.where(better, idx, best_idx),
                found | ok), None

    (_, best_idx, found), _ = lax.scan(
        pick, (jnp.inf, jnp.int32(0), jnp.asarray(False)),
        (cap_ok, objs, jnp.arange(caps.shape[0], dtype=jnp.int32)))
    eye = jnp.where(found, eyes[best_idx], jnp.zeros((m_gw, j_ch)))
    selected = eye.sum(axis=1) > 0
    tau = jnp.where(selected.any(),
                    jnp.max(jnp.where(eye > 0, lam, -jnp.inf)), 0.0)
    return eye, selected, tau


# ---------------------------------------------------------------------------
# the fused round + the jitted entry points
# ---------------------------------------------------------------------------


def _round(s: _Statics, st: ChannelStateT, ctx: RoundContextT
           ) -> DecisionArrays:
    """One whole DDSRA round as a single traced computation."""
    TRACE_COUNTS["round"] += 1
    e_dev_pad = jnp.where(s.valid, st.e_dev[s.dev_idx], jnp.inf)

    solve = _solve_gateway
    # inner vmap over channels j (gateway arrays broadcast), outer over m
    solve = jax.vmap(solve, in_axes=(None, None, None, None, None, None,
                                     None, 0, 0, 0, 0))
    solve = jax.vmap(solve, in_axes=(None, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0))
    feas, lam, l, f_gw, p_tx, e_dev_used, e_gw_used = solve(
        s, s.kd, s.f_dev, s.valid, s.n_loc, e_dev_pad, st.e_gw,
        st.h_up, st.h_down, st.i_up, st.i_down)

    eye, selected, tau = _assignment(lam, ctx.queues, ctx.v)
    # Eq. (14)
    new_q = update_queues_jax(ctx.queues, selected, ctx.gamma_rates)
    return DecisionArrays(feasible=feas, lam=lam, l=l, f_gw=f_gw, p_tx=p_tx,
                          e_dev=e_dev_used, e_gw=e_gw_used, eye=eye,
                          selected=selected, tau=tau, queues=new_q)


_round_jit = jax.jit(_round)


def resolve_decision_arrays(s: _Statics, out: DecisionArrays,
                            n_devices: int) -> RoundDecisionT:
    """Resolve raw solver outputs into the engine-facing
    :class:`RoundDecisionT` — the traced twin of
    ``repro.fl.sim.resolve_decision`` (same semantics, array form):

    * each selected gateway's assigned channel is the argmax of its ``eye``
      row (exactly one 1 when selected);
    * a selection whose solve is infeasible (or non-finite delay) *fails*
      instead of training — counted in ``failures``;
    * the per-lane partition points of trained gateways scatter into the
      dense (N,) ``l_dev`` vector (padded lanes carry ``dev_idx=0`` but
      scatter exact zeros, so they never corrupt device 0);
    * the realized round delay is the max over trained gateways (the FedAvg
      barrier), 0 when nobody trains.
    """
    m_idx = jnp.arange(out.eye.shape[0])
    j_star = jnp.argmax(out.eye, axis=1)                    # (M,)
    lam_sel = out.lam[m_idx, j_star]
    feas_sel = out.feasible[m_idx, j_star]
    trained = out.selected & feas_sel & jnp.isfinite(lam_sel)
    failures = jnp.sum(out.selected & ~trained)
    l_sel = out.l[m_idx, j_star]                            # (M, n_max)
    vals = jnp.where(s.valid & trained[:, None], l_sel, 0)
    l_dev = jnp.zeros((n_devices,), out.l.dtype).at[
        s.dev_idx.ravel()].add(vals.ravel())
    gw_delay = jnp.where(trained, lam_sel, 0.0)
    delay = jnp.where(trained.any(),
                      jnp.max(jnp.where(trained, lam_sel, -jnp.inf)), 0.0)
    return RoundDecisionT(selected=out.selected, trained=trained,
                          l_dev=l_dev, gw_delay=gw_delay, delay=delay,
                          tau=out.tau, failures=failures, queues=out.queues)


@functools.partial(jax.jit, static_argnames=("n_devices",))
def _decide_scan(s: _Statics, states: ChannelStateT, ctx0: RoundContextT,
                 *, n_devices: int):
    """Whole decide trajectory as one program: ``lax.scan`` the traced
    DDSRA round over stacked channel states, threading only the queue
    vector. Returns the stacked :class:`RoundDecisionT` (leading round
    axis) plus the stacked raw :class:`DecisionArrays` queues trajectory's
    final value via the decisions themselves."""
    TRACE_COUNTS["decide"] += 1

    def step(queues, st):
        out = _round(s, st, ctx0._replace(queues=queues))
        return out.queues, resolve_decision_arrays(s, out, n_devices)

    _, decisions = lax.scan(step, ctx0.queues, states)
    return decisions


@jax.jit
def _sweep_scan(s: _Statics, states: ChannelStateT, ctx0: RoundContextT,
                v_values):
    """seeds x V sweep as one program: ``vmap`` over the seed axis of the
    stacked states (leaves (S, T, ...)), ``vmap`` over V (all lanes share a
    seed's channel draws — the fair-sweep contract), ``lax.scan`` over
    rounds. Returns (taus, selected, queues) with leading (S, V, T) axes."""
    TRACE_COUNTS["sweep"] += 1

    def run_v(states_1seed, v):
        def step(queues, st):
            out = _round(s, st, ctx0._replace(queues=queues, v=v))
            return out.queues, (out.tau, out.selected, out.queues)
        _, ys = lax.scan(step, ctx0.queues, states_1seed)
        return ys

    per_seed = jax.vmap(lambda st1: jax.vmap(
        lambda v: run_v(st1, v))(v_values))
    return per_seed(states)


@dataclasses.dataclass
class DDSRAPlan:
    """Compiled control plane for one (Workload, Network) pair.

    Build once per simulation (``DDSRAPlan.build``); ``round(st, ...)``
    then runs the whole Algorithm 1 step as one jitted x64 program and
    repackages the outputs as the oracle's :class:`RoundDecision`.
    """
    statics: _Statics
    n_devices: int
    n_gateways: int
    n_channels: int
    n_max: int
    n_loc_host: np.ndarray      # (M,) int — for slicing padded lanes

    @classmethod
    def build(cls, w: Workload, net: Network) -> "DDSRAPlan":
        cfg = net.cfg
        m_gw, n_dev = cfg.n_gateways, cfg.n_devices
        counts = np.bincount(net.assign, minlength=m_gw)
        n_max = max(int(counts.max()), 1)
        kd = np.zeros((m_gw, n_max))
        f_dev = np.ones((m_gw, n_max))
        valid = np.zeros((m_gw, n_max), bool)
        dev_idx = np.zeros((m_gw, n_max), np.int32)
        for m in range(m_gw):
            devs = net.devices_of(m)
            kd[m, :len(devs)] = w.k_iters * w.d_tilde[devs]
            f_dev[m, :len(devs)] = net.f_dev[devs]
            valid[m, :len(devs)] = True
            dev_idx[m, :len(devs)] = devs
        with enable_x64():
            c = _Cfg(*[jnp.asarray(float(x)) for x in (
                cfg.phi_dev, cfg.phi_gw, cfg.v_dev, cfg.v_gw, cfg.f_gw_max,
                cfg.f_gw_min, cfg.g_dev_max, cfg.g_gw_max, cfg.p_max,
                cfg.p_bs, cfg.bandwidth_up, cfg.bandwidth_down, net.n0,
                cfg.e_dev_max, cfg.e_gw_max, cfg.interference_up_var,
                cfg.interference_down_var)])
            statics = _Statics(
                cfg=c,
                cumf=jnp.asarray(_cum(w.flops)),
                cumg=jnp.asarray(_cum(w.mem)),
                gamma=jnp.asarray(float(w.gamma)),
                kd=jnp.asarray(kd), f_dev=jnp.asarray(f_dev),
                valid=jnp.asarray(valid),
                n_loc=jnp.asarray(counts.astype(float)),
                dev_idx=jnp.asarray(dev_idx),
                path=jnp.asarray(net.h0 * (cfg.d0 / net.dist) ** cfg.nu))
        return cls(statics, n_dev, m_gw, cfg.n_channels, n_max,
                   counts.astype(int))

    # -- one oracle-parity round ----------------------------------------

    def _ctx(self, queues, gamma_rates, v) -> RoundContextT:
        """Host values -> the x64 traced context pytree."""
        return RoundContextT(
            queues=jnp.asarray(np.asarray(queues, np.float64)),
            gamma_rates=jnp.asarray(np.asarray(gamma_rates, np.float64)),
            v=jnp.asarray(float(v)))

    def round_arrays(self, st: ChannelState, queues, gamma_rates, v
                     ) -> DecisionArrays:
        """Run the jitted round on a host-drawn ChannelState; returns the
        raw :class:`DecisionArrays` pytree of device arrays (x64)."""
        with enable_x64():
            return _round_jit(self.statics, ChannelStateT.of(st),
                              self._ctx(queues, gamma_rates, v))

    def round(self, st: ChannelState, queues, gamma_rates, v
              ) -> RoundDecision:
        """Oracle-compatible round: jitted solve + host repackaging."""
        out = self.round_arrays(st, queues, gamma_rates, v)
        eye = np.asarray(out.eye)
        lam = np.asarray(out.lam)
        feas = np.asarray(out.feasible)
        l = np.asarray(out.l)
        f_gw = np.asarray(out.f_gw)
        p_tx = np.asarray(out.p_tx)
        e_dev = np.asarray(out.e_dev)
        e_gw = np.asarray(out.e_gw)
        sols = {}
        for m, j in zip(*np.nonzero(eye > 0)):
            n = int(self.n_loc_host[m])
            sols[(int(m), int(j))] = GatewaySolution(
                bool(feas[m, j]), float(lam[m, j]),
                l[m, j, :n].astype(int), f_gw[m, j, :n],
                float(p_tx[m, j]), e_dev[m, j, :n], float(e_gw[m, j]))
        selected = eye.sum(axis=1) > 0
        return RoundDecision(eye, selected, lam, sols,
                             float(out.tau), np.asarray(out.queues))

    # -- fused decide trajectories (repro.fl.fused_sim) ------------------

    def decide_scan(self, states: ChannelStateT, queues, gamma_rates, v
                    ) -> RoundDecisionT:
        """Run the whole decide trajectory as one compiled program.

        ``states`` is a stacked :class:`ChannelStateT` (leading round axis,
        host-drawn so the numpy channel stream is preserved); returns the
        stacked resolved :class:`RoundDecisionT` with every leaf carrying a
        leading ``(rounds,)`` axis. One compile per (topology, rounds)
        shape; re-running with different values never retraces.
        """
        with enable_x64():
            states = jax.tree.map(
                lambda a: jnp.asarray(np.asarray(a, np.float64)), states)
            return _decide_scan(self.statics, states,
                                self._ctx(queues, gamma_rates, v),
                                n_devices=self.n_devices)

    def sweep_states(self, states: ChannelStateT, gamma_rates, v_values,
                     queues=None):
        """seeds x V sweep over host-drawn channel trajectories as one
        compiled program.

        ``states`` leaves carry leading (seeds, rounds) axes (stack
        ``repro.core.network.stack_states`` per seed, then ``np.stack``
        over seeds). All V lanes of a seed share its channel draws — the
        PR 2 fair-sweep contract — so the trade-off curves isolate V.
        Returns numpy (taus, selected, queues) shaped
        (seeds, len(v_values), rounds[, M]).
        """
        with enable_x64():
            states = jax.tree.map(
                lambda a: jnp.asarray(np.asarray(a, np.float64)), states)
            q0 = np.zeros(self.n_gateways) if queues is None else queues
            taus, sel, qs = _sweep_scan(
                self.statics, states, self._ctx(q0, gamma_rates, 0.0),
                jnp.asarray(np.asarray(v_values, np.float64)))
            return np.asarray(taus), np.asarray(sel), np.asarray(qs)

    # -- fully-fused sweeps (device-resident rounds) ---------------------

    def simulate_v_sweep(self, key, gamma_rates, v_values, rounds: int):
        """vmap-over-V DDSRA runs, channel draws on device: one XLA program
        computes (taus, selected) of shape (len(v_values), rounds[, M]).

        All V lanes share the same per-round channel keys (the fair-sweep
        contract), so the trade-off curve isolates V."""
        with enable_x64():
            s = self.statics
            n_dev, j_ch = self.n_devices, self.n_channels
            gamma_rates = jnp.asarray(np.asarray(gamma_rates, np.float64))
            v_values = jnp.asarray(np.asarray(v_values, np.float64))
            keys = jax.random.split(jax.random.PRNGKey(0) if key is None
                                    else key, rounds)

            def one_round(q, key, v):
                c = s.cfg
                st = ChannelStateT(*draw_state_jax(
                    key, s.path, j_ch, n_dev,
                    e_dev_max=c.e_dev_max, e_gw_max=c.e_gw_max,
                    i_up_var=c.i_up_var, i_down_var=c.i_down_var))
                out = _round(s, st, RoundContextT(q, gamma_rates, v))
                return out.queues, (out.tau, out.selected)

            def run_v(v):
                def step(q, key):
                    return one_round(q, key, v)
                _, (taus, sel) = lax.scan(
                    step, jnp.zeros(self.n_gateways), keys)
                return taus, sel

            taus, sel = jax.jit(jax.vmap(run_v))(v_values)
            return np.asarray(taus), np.asarray(sel)
