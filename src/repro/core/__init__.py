"""The paper's primary contribution: layer-level cost model, device-specific
participation rate, and the DDSRA Lyapunov scheduler (+ baselines)."""
from repro.core import costmodel, ddsra, hungarian, lyapunov, network
from repro.core import participation, partition, schedulers

__all__ = ["costmodel", "ddsra", "hungarian", "lyapunov", "network",
           "participation", "partition", "schedulers"]
