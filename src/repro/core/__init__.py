"""The paper's primary contribution: layer-level cost model, device-specific
participation rate, and the DDSRA Lyapunov scheduler (+ baselines).

The control plane exists twice: ``ddsra`` is the host-side numpy oracle
(Algorithm 1 as written), ``ddsra_jax`` the vectorized, jittable x64 port
(one XLA program per scheduling round; registered as policy
``"ddsra_jax"``)."""
from repro.core import costmodel, ddsra, ddsra_jax, hungarian, lyapunov
from repro.core import network, participation, partition, schedulers

__all__ = ["costmodel", "ddsra", "ddsra_jax", "hungarian", "lyapunov",
           "network", "participation", "partition", "schedulers"]
