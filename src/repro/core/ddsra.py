"""DDSRA — dynamic device scheduling and resource allocation (Algorithm 1).

Per communication round:
  1. For every (gateway m, channel j) independently ("do in parallel" in the
     paper): block-coordinate descent over DNN partition points ``l_n`` (21,
     bisection), gateway frequency split ``f^G_{m,n}`` (22, bisection) and
     transmit power ``P_m`` (23)/(24, convex water solve) -> auxiliary delay
     matrix Lambda (18).
  2. Channel assignment (26)-(29): iterate the auxiliary cap ``lambda`` with
     the Hungarian method on the composite cost Theta.
  3. Virtual queue update (14).

This module is the host-side numpy implementation and serves as the
**parity oracle** for the vectorized, jittable control plane in
``repro.core.ddsra_jax`` (policy ``"ddsra_jax"``): the jitted port must
emit identical assignments/selected sets and Lambda/tau within 1e-6
(pinned in ``tests/test_ddsra_jax.py``). Change the semantics here and
you are changing the contract there.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.hungarian import assign_channels
from repro.core.lyapunov import update_queues
from repro.core.network import ChannelState, Network

_PSI = 1e18     # "extremely large positive value" in (29)


@dataclasses.dataclass
class Workload:
    """Layer-level training workload (from repro.core.costmodel)."""
    flops: np.ndarray        # (L,) o_l + o'_l per sample
    mem: np.ndarray          # (L,) g_l bytes (training batch already folded in)
    gamma: float             # model size, bytes
    k_iters: int             # local epochs K
    d_tilde: np.ndarray      # (N,) training batch sizes

    @property
    def n_layers(self) -> int:
        return len(self.flops)


@dataclasses.dataclass
class GatewaySolution:
    feasible: bool
    delay: float                   # Lambda_{m,j}
    l_split: np.ndarray            # per associated device
    f_gw: np.ndarray               # per associated device (Hz)
    p_tx: float
    e_dev: np.ndarray
    e_gw: float


@dataclasses.dataclass
class RoundDecision:
    """One round's schedule plus the policy's post-decision queue state.

    ``queues`` contract: it must be the Eq. (14) update of the pre-decision
    queues under the *scheduled* indicator ``selected``. Synchronous
    engines apply it verbatim. Under ``engine="async"`` realized
    participation can diverge from the schedule (churn, stragglers landing
    late), and when it does the simulation *discards* ``queues`` and redoes
    Eq. (14) from the pre-decision queues with the realized indicator
    (``lyapunov.update_queues_realized``) — a policy encoding a different
    queue law in ``queues`` would be silently overridden on exactly those
    rounds, so custom non-Eq.-(14) queue dynamics are only honored on
    synchronous engines (or fault-free async rounds).
    """
    assignment: np.ndarray         # I (M, J)
    selected: np.ndarray           # (M,) bool
    lam: np.ndarray                # (M, J) Lambda
    solutions: dict                # (m, j) -> GatewaySolution
    delay: float                   # tau(t), Eq. (10)
    queues: np.ndarray             # post-update virtual queues


# ---------------------------------------------------------------------------
# inner solvers for one (gateway, channel)
# ---------------------------------------------------------------------------


def _cum(front: np.ndarray) -> np.ndarray:
    """cumulative sums with a leading 0: cum[l] = sum of first l entries."""
    return np.concatenate([[0.0], np.cumsum(front)])


def _train_times(w: Workload, devs: np.ndarray, l: np.ndarray, f_dev: np.ndarray,
                 phi_dev: float, phi_gw: float, f_gw: np.ndarray) -> np.ndarray:
    cumf = _cum(w.flops)
    tot = cumf[-1]
    bottom = cumf[l]
    top = tot - bottom
    with np.errstate(divide="ignore"):
        t_dev = bottom / (phi_dev * f_dev)
        t_gw = np.where(top > 0, top / np.maximum(phi_gw * f_gw, 1e-9), 0.0)
    return w.k_iters * w.d_tilde[devs] * (t_dev + t_gw)


def solve_partition(w: Workload, net: Network, m: int, devs: np.ndarray,
                    f_gw: np.ndarray, st: ChannelState,
                    e_gw_budget: float, iters: int = 40) -> Optional[np.ndarray]:
    """Bisection on eta for sub-problem (21). Returns l (per device) or None."""
    cfg = net.cfg
    cumf, cumg = _cum(w.flops), _cum(w.mem)
    tot_f, tot_g = cumf[-1], cumg[-1]
    f_dev = net.f_dev[devs]
    n_loc = len(devs)
    big_l = w.n_layers

    kd = w.k_iters * w.d_tilde[devs]

    # per-device static upper bounds from C7' (memory) and C10' (energy),
    # all devices at once on the (n_loc, L+1) grid
    mem_ok = cumg <= cfg.g_dev_max                              # (L+1,)
    e_grid = (kd * cfg.v_dev / cfg.phi_dev * f_dev ** 2)[:, None] * cumf[None, :]
    ok_static = mem_ok[None, :] & (e_grid <= st.e_dev[devs][:, None])
    if not ok_static.any(axis=1).all():
        return None
    hi_static = big_l - np.argmax(ok_static[:, ::-1], axis=1)

    # per-device time at every cut, hoisted out of the bisection: (n_loc, L+1)
    t_grid = kd[:, None] * (
        cumf[None, :] / (cfg.phi_dev * f_dev)[:, None]
        + (tot_f - cumf[None, :]) / np.maximum(cfg.phi_gw * f_gw, 1e-9)[:, None])
    ls_ok_static = np.arange(big_l + 1)[None, :] <= hi_static[:, None]
    gw_e_coef = kd * cfg.v_gw / cfg.phi_gw * f_gw ** 2

    def feasible(eta: float) -> Optional[np.ndarray]:
        """Largest l per device with time <= eta (within static bounds),
        then check joint gateway constraints C8' and C9'."""
        ok = (t_grid <= eta) & ls_ok_static
        if not ok.any(axis=1).all():
            return None
        # prefer the largest l meeting eta: minimizes gateway load (C8'/C9')
        l_pick = big_l - np.argmax(ok[:, ::-1], axis=1)
        if np.sum(tot_g - cumg[l_pick]) > cfg.g_gw_max:
            return None
        if np.sum(gw_e_coef * (tot_f - cumf[l_pick])) > e_gw_budget:
            return None
        return l_pick

    lo = 0.0
    hi = float(np.max(w.k_iters * w.d_tilde[devs]) * tot_f
               / min(cfg.phi_dev * f_dev.min(), cfg.phi_gw * max(f_gw.min(), 1e-9)))
    best = feasible(hi)
    if best is None:
        return None
    for _ in range(iters):
        mid = 0.5 * (lo + hi)
        sol = feasible(mid)
        if sol is not None:
            hi, best = mid, sol
        else:
            lo = mid
    return best


def solve_frequency(w: Workload, net: Network, devs: np.ndarray, l: np.ndarray,
                    st: ChannelState, e_gw_budget: float,
                    iters: int = 40) -> Optional[np.ndarray]:
    """Bisection on theta for sub-problem (22)."""
    cfg = net.cfg
    cumf = _cum(w.flops)
    tot = cumf[-1]
    f_dev = net.f_dev[devs]
    dev_t = cumf[l] / (cfg.phi_dev * f_dev)              # per-sample device time
    gw_work = (tot - cumf[l]) / cfg.phi_gw               # cycles on gateway
    kd = w.k_iters * w.d_tilde[devs]

    if np.all(gw_work <= 0):
        return np.full(len(devs), cfg.f_gw_min / max(len(devs), 1))

    def f_of(theta: float) -> Optional[np.ndarray]:
        denom = theta / kd - dev_t
        if (denom <= 0).any():
            return None
        f = gw_work / denom
        f = np.maximum(f, 0.0)
        if f.sum() > cfg.f_gw_max:
            return None
        e = float(np.sum(kd * cfg.v_gw * gw_work * f ** 2))
        if e > e_gw_budget:
            return None
        return f

    lo = float(np.max(kd * (dev_t + gw_work / cfg.f_gw_max)))
    hi = float(np.max(kd * (dev_t + gw_work / max(cfg.f_gw_min / max(len(devs), 1), 1e3))))
    hi = max(hi, lo * 4 + 1.0)
    sol = f_of(hi)
    if sol is None:
        return None
    best = sol
    for _ in range(iters):
        mid = 0.5 * (lo + hi)
        s = f_of(mid)
        if s is not None:
            hi, best = mid, s
        else:
            lo = mid
    return best


def solve_power(net: Network, m: int, j: int, st: ChannelState, gamma: float,
                e_budget: float, iters: int = 60) -> float:
    """(23)/(24): largest transmit power whose upload energy fits e_budget."""
    cfg = net.cfg
    if e_budget <= 0:
        return 0.0
    if net.uplink_energy(m, j, cfg.p_max, gamma, st) <= e_budget:
        return cfg.p_max
    lo, hi = 0.0, cfg.p_max
    for _ in range(iters):
        mid = 0.5 * (lo + hi)
        if net.uplink_energy(m, j, mid, gamma, st) <= e_budget:
            lo = mid
        else:
            hi = mid
    return lo


def solve_gateway(w: Workload, net: Network, m: int, j: int, st: ChannelState,
                  bcd_iters: int = 4) -> GatewaySolution:
    """Full BCD for one (m, j): returns Lambda_{m,j} and the resources."""
    cfg = net.cfg
    devs = net.devices_of(m)
    n_loc = len(devs)
    infeasible = GatewaySolution(False, np.inf, np.zeros(n_loc, int),
                                 np.zeros(n_loc), 0.0, np.zeros(n_loc), 0.0)
    if n_loc == 0:
        return infeasible

    cumf = _cum(w.flops)
    tot = cumf[-1]
    f_gw = np.full(n_loc, cfg.f_gw_max / n_loc)
    p_tx = cfg.p_max
    l = None
    for _ in range(bcd_iters):
        e_up = net.uplink_energy(m, j, p_tx, w.gamma, st)
        e_budget = st.e_gw[m] - e_up
        l_new = solve_partition(w, net, m, devs, f_gw, st, e_budget)
        if l_new is None:
            return infeasible
        l = l_new
        f_new = solve_frequency(w, net, devs, l, st, e_budget)
        if f_new is None:
            return infeasible
        f_gw = np.maximum(f_new, 1e3)
        e_tra_gw = float(np.sum(
            w.k_iters * w.d_tilde[devs] * cfg.v_gw / cfg.phi_gw
            * (tot - cumf[l]) * f_gw ** 2))
        p_tx = solve_power(net, m, j, st, w.gamma, st.e_gw[m] - e_tra_gw)
        if p_tx <= 0:
            return infeasible

    t_train = float(np.max(_train_times(w, devs, l, net.f_dev[devs],
                                        cfg.phi_dev, cfg.phi_gw, f_gw)))
    t_up = net.uplink_time(m, j, p_tx, w.gamma, st)
    t_down = net.downlink_time(m, j, w.gamma, st)
    lam = t_train + t_up + t_down                       # Eq. (18)
    e_dev = (w.k_iters * w.d_tilde[devs] * cfg.v_dev / cfg.phi_dev
             * cumf[l] * net.f_dev[devs] ** 2)
    e_gw = e_tra_gw + net.uplink_energy(m, j, p_tx, w.gamma, st)
    return GatewaySolution(True, lam, l, f_gw, p_tx, e_dev, e_gw)


# ---------------------------------------------------------------------------
# per-round DDSRA step
# ---------------------------------------------------------------------------


def ddsra_round(w: Workload, net: Network, st: ChannelState, queues: np.ndarray,
                gamma_rates: np.ndarray, v: float) -> RoundDecision:
    cfg = net.cfg
    m_gw, j_ch = cfg.n_gateways, cfg.n_channels

    lam = np.full((m_gw, j_ch), np.inf)
    sols = {}
    for m in range(m_gw):                 # "do in parallel" in Algorithm 1
        for j in range(j_ch):
            sol = solve_gateway(w, net, m, j, st)
            sols[(m, j)] = sol
            lam[m, j] = sol.delay

    # channel assignment (26)-(31): sweep the lambda cap down the frontier of
    # distinct delay values, solving the Theta assignment (28)-(29) with the
    # Hungarian method at each cap, and keep the best P3 objective. This is
    # the paper's iterative lambda/I(t) solve, run to exhaustion (M*J caps).
    finite = np.isfinite(lam)
    best_eye, best_obj = None, None
    caps = np.unique(lam[finite])[::-1] if finite.any() else []
    for cap in caps:
        theta = np.where(finite & (lam <= cap + 1e-12),
                         -queues[:, None], _PSI)
        # a feasible assignment needs >=1 allowed gateway per channel
        if (theta >= _PSI).all(axis=0).any():
            continue
        eye = assign_channels(theta)
        if (np.where(eye > 0, theta, 0.0) >= _PSI).any():
            continue                       # Hungarian forced a banned pair
        tau = float(np.where(eye > 0, lam, -np.inf).max())
        obj = v * tau - float(np.sum(queues * eye.sum(axis=1)))
        if best_obj is None or obj < best_obj - 1e-12:
            best_obj, best_eye = obj, eye

    if best_eye is None:                   # nothing feasible this round
        best_eye = np.zeros((m_gw, j_ch))
    eye = best_eye
    selected = eye.sum(axis=1) > 0
    sel_lam = np.where(eye > 0, lam, -np.inf)
    tau = float(sel_lam.max()) if selected.any() else 0.0
    new_q = update_queues(queues, selected, gamma_rates)
    return RoundDecision(eye, selected, lam, sols, tau, new_q)
