"""Hungarian method (Kuhn-Munkres, potentials variant, O(n^3)).

Used by DDSRA to solve the weighted bipartite channel-assignment problem
(26)-(29): each of the J channels must be assigned to exactly one gateway
(C3), each gateway takes at most one channel (C2).

Two implementations of the *same* algorithm live here:

* :func:`hungarian_min` / :func:`assign_channels` — the host-side numpy
  oracle (the seed implementation, kept as the parity reference);
* :func:`hungarian_min_jax` / :func:`assign_channels_jax` — a jittable
  port that mirrors the numpy control flow step for step (``lax.fori_loop``
  over rows, a bounded ``lax.while_loop`` for the alternating-tree growth,
  a second ``while_loop`` for the augmenting-path unroll), so potentials,
  argmin tie-breaks and therefore the *returned assignment* are identical
  — not merely cost-optimal. ``jax.vmap``-able; the jitted DDSRA cap sweep
  maps it over all Θ cost matrices at once.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp
import numpy as np
from jax import lax


def hungarian_min(cost: np.ndarray) -> Tuple[np.ndarray, float]:
    """Min-cost assignment of rows to columns.

    cost: (R, C) with R <= C. Returns (col_of_row (R,), total_cost).
    """
    cost = np.asarray(cost, float)
    r, c = cost.shape
    assert r <= c, "rows must be <= cols (pad the caller otherwise)"
    INF = 1e30
    u = np.zeros(r + 1)
    v = np.zeros(c + 1)
    p = np.zeros(c + 1, dtype=int)      # p[col] = row matched to col (1-based)
    way = np.zeros(c + 1, dtype=int)

    for i in range(1, r + 1):
        p[0] = i
        j0 = 0
        minv = np.full(c + 1, INF)
        used = np.zeros(c + 1, dtype=bool)
        while True:
            used[j0] = True
            i0 = p[j0]
            free = ~used[1:]                      # candidate columns 1..c
            # relax all free columns against row i0 at once
            cur = cost[i0 - 1, :] - u[i0] - v[1:]
            better = free & (cur < minv[1:])
            minv[1:] = np.where(better, cur, minv[1:])
            way[1:] = np.where(better, j0, way[1:])
            # masked argmin picks the next column to add to the tree
            masked = np.where(free, minv[1:], INF)
            j1 = int(np.argmin(masked)) + 1
            delta = masked[j1 - 1]
            # update potentials (matched rows of used columns are distinct)
            used_j = np.flatnonzero(used)
            u[p[used_j]] += delta
            v[used_j] -= delta
            minv[1:] = np.where(free, minv[1:] - delta, minv[1:])
            j0 = j1
            if p[j0] == 0:
                break
        while j0:
            j1 = way[j0]
            p[j0] = p[j1]
            j0 = j1

    col_of_row = np.full(r, -1, dtype=int)
    for j in range(1, c + 1):
        if p[j] > 0:
            col_of_row[p[j] - 1] = j - 1
    total = float(cost[np.arange(r), col_of_row].sum())
    return col_of_row, total


def assign_channels(theta: np.ndarray) -> np.ndarray:
    """Solve (28): theta (M, J) costs; returns I (M, J) in {0,1}.

    Channels are rows (each channel must be used exactly once, C3); gateways
    are columns (at most one channel each, C2). Requires J <= M.
    """
    m, j = theta.shape
    assert j <= m, "need at least as many gateways as channels"
    col_of_row, _ = hungarian_min(theta.T)     # (J,) gateway per channel
    eye = np.zeros((m, j))
    for ch, gw in enumerate(col_of_row):
        eye[gw, ch] = 1.0
    return eye


# ---------------------------------------------------------------------------
# jittable port (identical control flow -> identical assignments)
# ---------------------------------------------------------------------------


def hungarian_min_jax(cost) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Jittable :func:`hungarian_min`: same potentials algorithm, same
    tie-breaks (first-minimum ``argmin``), traceable under ``jit``/``vmap``.

    cost: (R, C) with R <= C (static shapes). Returns
    (col_of_row (R,) int32, total_cost scalar).
    """
    cost = jnp.asarray(cost)
    r, c = cost.shape
    assert r <= c, "rows must be <= cols (pad the caller otherwise)"
    inf = jnp.asarray(1e30, cost.dtype)

    def row_step(i, carry):
        u, v, p, way = carry
        p = p.at[0].set(i)

        def grow(st):
            """One alternating-tree extension (the numpy inner while body)."""
            j0, minv, used, u, v, p, way = st
            used = used.at[j0].set(True)
            i0 = p[j0]
            free = ~used[1:]
            # relax all free columns against row i0 at once
            cur = cost[i0 - 1, :] - u[i0] - v[1:]
            better = free & (cur < minv[1:])
            minv = minv.at[1:].set(jnp.where(better, cur, minv[1:]))
            way = way.at[1:].set(jnp.where(better, j0, way[1:]))
            # masked argmin picks the next column to add to the tree
            masked = jnp.where(free, minv[1:], inf)
            j1 = jnp.argmin(masked).astype(jnp.int32) + 1
            delta = masked[j1 - 1]
            # update potentials (matched rows of used columns are distinct,
            # so the scatter-add touches each row at most once; unused
            # columns contribute an exact 0)
            u = u.at[p].add(jnp.where(used, delta, 0.0))
            v = v - jnp.where(used, delta, 0.0)
            minv = minv.at[1:].set(jnp.where(free, minv[1:] - delta,
                                             minv[1:]))
            return (j1, minv, used, u, v, p, way)

        st = grow((jnp.int32(0), jnp.full(c + 1, inf),
                   jnp.zeros(c + 1, bool), u, v, p, way))
        j0, _, _, u, v, p, way = lax.while_loop(
            lambda s: s[5][s[0]] != 0, grow, st)

        def unroll(st):                    # augment: p[j0] = p[way[j0]]
            j0, p = st
            j1 = way[j0]
            return (j1, p.at[j0].set(p[j1]))

        _, p = lax.while_loop(lambda s: s[0] != 0, unroll, (j0, p))
        return (u, v, p, way)

    u = jnp.zeros(r + 1, cost.dtype)
    v = jnp.zeros(c + 1, cost.dtype)
    p = jnp.zeros(c + 1, jnp.int32)        # p[col] = row matched (1-based)
    way = jnp.zeros(c + 1, jnp.int32)
    u, v, p, way = lax.fori_loop(1, r + 1, row_step, (u, v, p, way))

    # p[1:][j] > 0 means column j matched to row p-1; scatter col index back
    rows = jnp.where(p[1:] > 0, p[1:] - 1, r)          # r = out of range
    col_of_row = (jnp.full(r, -1, jnp.int32)
                  .at[rows].set(jnp.arange(c, dtype=jnp.int32), mode="drop"))
    total = cost[jnp.arange(r), col_of_row].sum()
    return col_of_row, total


def assign_channels_jax(theta) -> jnp.ndarray:
    """Jittable :func:`assign_channels`: theta (M, J) -> I (M, J) in {0,1}."""
    m, j = theta.shape
    assert j <= m, "need at least as many gateways as channels"
    col_of_row, _ = hungarian_min_jax(theta.T)   # (J,) gateway per channel
    eye = jnp.zeros((m, j), theta.dtype)
    return eye.at[col_of_row, jnp.arange(j)].set(1.0)
