"""Hungarian method (Kuhn-Munkres, potentials variant, O(n^3)).

Used by DDSRA to solve the weighted bipartite channel-assignment problem
(26)-(29): each of the J channels must be assigned to exactly one gateway
(C3), each gateway takes at most one channel (C2).
"""
from __future__ import annotations

from typing import Tuple

import numpy as np


def hungarian_min(cost: np.ndarray) -> Tuple[np.ndarray, float]:
    """Min-cost assignment of rows to columns.

    cost: (R, C) with R <= C. Returns (col_of_row (R,), total_cost).
    """
    cost = np.asarray(cost, float)
    r, c = cost.shape
    assert r <= c, "rows must be <= cols (pad the caller otherwise)"
    INF = 1e30
    u = np.zeros(r + 1)
    v = np.zeros(c + 1)
    p = np.zeros(c + 1, dtype=int)      # p[col] = row matched to col (1-based)
    way = np.zeros(c + 1, dtype=int)

    for i in range(1, r + 1):
        p[0] = i
        j0 = 0
        minv = np.full(c + 1, INF)
        used = np.zeros(c + 1, dtype=bool)
        while True:
            used[j0] = True
            i0 = p[j0]
            free = ~used[1:]                      # candidate columns 1..c
            # relax all free columns against row i0 at once
            cur = cost[i0 - 1, :] - u[i0] - v[1:]
            better = free & (cur < minv[1:])
            minv[1:] = np.where(better, cur, minv[1:])
            way[1:] = np.where(better, j0, way[1:])
            # masked argmin picks the next column to add to the tree
            masked = np.where(free, minv[1:], INF)
            j1 = int(np.argmin(masked)) + 1
            delta = masked[j1 - 1]
            # update potentials (matched rows of used columns are distinct)
            used_j = np.flatnonzero(used)
            u[p[used_j]] += delta
            v[used_j] -= delta
            minv[1:] = np.where(free, minv[1:] - delta, minv[1:])
            j0 = j1
            if p[j0] == 0:
                break
        while j0:
            j1 = way[j0]
            p[j0] = p[j1]
            j0 = j1

    col_of_row = np.full(r, -1, dtype=int)
    for j in range(1, c + 1):
        if p[j] > 0:
            col_of_row[p[j] - 1] = j - 1
    total = float(cost[np.arange(r), col_of_row].sum())
    return col_of_row, total


def assign_channels(theta: np.ndarray) -> np.ndarray:
    """Solve (28): theta (M, J) costs; returns I (M, J) in {0,1}.

    Channels are rows (each channel must be used exactly once, C3); gateways
    are columns (at most one channel each, C2). Requires J <= M.
    """
    m, j = theta.shape
    assert j <= m, "need at least as many gateways as channels"
    col_of_row, _ = hungarian_min(theta.T)     # (J,) gateway per channel
    eye = np.zeros((m, j))
    for ch, gw in enumerate(col_of_row):
        eye[gw, ch] = 1.0
    return eye
