"""Wireless channel + energy model (paper Sec. III-C).

IID block-fading channels, OFDM uplink/downlink between gateways and the BS,
energy-harvesting arrivals at devices and gateways. The simulation
environment is host-side numpy (``Network.draw``); :func:`draw_state_jax`
is the same law expressed with ``jax.random`` (different stream), used by
the jitted control plane (``repro.core.ddsra_jax``) when whole sweeps stay
device-resident.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Sequence

import numpy as np


@dataclasses.dataclass
class NetworkConfig:
    n_gateways: int = 6
    n_devices: int = 12
    n_channels: int = 3
    # channel
    h0_db: float = -30.0          # path loss constant
    d0: float = 1.0               # reference distance (m)
    nu: float = 2.0               # path-loss exponent
    bandwidth_up: float = 1e6     # B^u (Hz)
    bandwidth_down: float = 20e6  # B^d (Hz)
    noise_psd_dbm: float = -174.0 # N0 (dBm/Hz)
    p_bs: float = 1.0             # BS transmit power (W)
    p_max: float = 0.2            # gateway max transmit power (W)
    # the paper only says interference is Gaussian "with different variances";
    # chosen here to sit near the thermal noise floor so SINRs land in the
    # 10-30 dB regime the paper's delays imply
    interference_up_var: float = 1e-26
    interference_down_var: float = 1e-25
    # energy
    e_dev_max: float = 5.0        # J per round (uniform arrival bound)
    e_gw_max: float = 30.0
    v_dev: float = 1e-27          # effective switched capacitance
    v_gw: float = 1e-27
    # compute
    phi_dev: float = 16.0         # FLOPs / cycle
    phi_gw: float = 32.0
    f_dev_range: tuple = (0.1e9, 1.0e9)
    f_gw_max: float = 4.0e9
    f_gw_min: float = 0.1e9
    # memory (bytes)
    g_dev_max: float = 2e9
    g_gw_max: float = 4e9
    dist_range: tuple = (1000.0, 2000.0)


@dataclasses.dataclass
class ChannelState:
    """Per-round draw: gains/interference for every (gateway, channel)."""
    h_up: np.ndarray       # (M, J)
    h_down: np.ndarray     # (M, J)
    i_up: np.ndarray       # (M, J)
    i_down: np.ndarray     # (M, J)
    e_dev: np.ndarray      # (N,) energy arrivals
    e_gw: np.ndarray       # (M,)


class ChannelStateT(NamedTuple):
    """:class:`ChannelState` as a traced pytree (the fused-simulation
    contract shared with ``repro.core.ddsra_jax`` and ``repro.fl.fused_sim``).

    Same six leaves as the dataclass; being a NamedTuple makes it a JAX
    pytree, so whole trajectories stack into leaves with leading
    ``(rounds,)`` / ``(seeds, rounds)`` axes and feed ``lax.scan`` /
    ``vmap`` directly (see :func:`stack_states`).
    """
    h_up: np.ndarray       # (..., M, J)
    h_down: np.ndarray     # (..., M, J)
    i_up: np.ndarray       # (..., M, J)
    i_down: np.ndarray     # (..., M, J)
    e_dev: np.ndarray      # (..., N)
    e_gw: np.ndarray       # (..., M)

    @classmethod
    def of(cls, st: "ChannelState", dtype=np.float64) -> "ChannelStateT":
        """Lift one host-drawn :class:`ChannelState` into the pytree form
        (x64 by default — the control plane's precision contract)."""
        return cls(*[np.asarray(getattr(st, f), dtype) for f in cls._fields])


def stack_states(states: Sequence["ChannelState"],
                 dtype=np.float64) -> ChannelStateT:
    """Stack host-drawn :class:`ChannelState` draws into one
    :class:`ChannelStateT` with a leading round axis — the ``xs`` a fused
    round loop scans over. Stacking nests: ``stack_states`` per seed, then
    ``jax.tree.map(np.stack, ...)`` over seeds, gives (S, T, ...) leaves
    for the seeds x V sweep."""
    return ChannelStateT(*[
        np.stack([np.asarray(getattr(s, f), dtype) for s in states])
        for f in ChannelStateT._fields])


class Network:
    def __init__(self, cfg: NetworkConfig, rng: Optional[np.random.Generator] = None):
        self.cfg = cfg
        self.rng = rng or np.random.default_rng(0)
        self.h0 = 10 ** (cfg.h0_db / 10)
        self.n0 = 10 ** (cfg.noise_psd_dbm / 10) / 1000.0   # W/Hz
        # static deployment
        self.dist = self.rng.uniform(*cfg.dist_range, size=cfg.n_gateways)
        self.f_dev = self.rng.uniform(*cfg.f_dev_range, size=cfg.n_devices)
        # devices -> gateways round-robin (2 per gateway in the paper setup)
        self.assign = np.arange(cfg.n_devices) % cfg.n_gateways
        self.a = np.zeros((cfg.n_devices, cfg.n_gateways))
        self.a[np.arange(cfg.n_devices), self.assign] = 1.0

    def devices_of(self, m: int) -> np.ndarray:
        return np.where(self.assign == m)[0]

    def draw(self) -> ChannelState:
        cfg, rng = self.cfg, self.rng
        m, j = cfg.n_gateways, cfg.n_channels
        path = self.h0 * (cfg.d0 / self.dist[:, None]) ** cfg.nu
        h_up = path * rng.exponential(1.0, size=(m, j))
        h_down = path * rng.exponential(1.0, size=(m, j))
        i_up = np.abs(rng.normal(0, np.sqrt(cfg.interference_up_var), (m, j)))
        i_down = np.abs(rng.normal(0, np.sqrt(cfg.interference_down_var), (m, j)))
        e_dev = rng.uniform(0, cfg.e_dev_max, cfg.n_devices)
        e_gw = rng.uniform(0, cfg.e_gw_max, cfg.n_gateways)
        return ChannelState(h_up, h_down, i_up, i_down, e_dev, e_gw)

    # rates / delays / energies -------------------------------------------------

    def uplink_rate(self, m: int, j: int, p: float, st: ChannelState) -> float:
        cfg = self.cfg
        sinr = p * st.h_up[m, j] / (cfg.bandwidth_up * self.n0 + st.i_up[m, j])
        return cfg.bandwidth_up * np.log2(1.0 + sinr)

    def downlink_rate(self, m: int, j: int, st: ChannelState) -> float:
        cfg = self.cfg
        sinr = cfg.p_bs * st.h_down[m, j] / (cfg.bandwidth_down * self.n0 + st.i_down[m, j])
        return cfg.bandwidth_down * np.log2(1.0 + sinr)

    def uplink_time(self, m: int, j: int, p: float, gamma: float, st: ChannelState) -> float:
        """Eq. (7): model upload time."""
        r = self.uplink_rate(m, j, p, st)
        return np.inf if r <= 0 else gamma * 8.0 / r

    def downlink_time(self, m: int, j: int, gamma: float, st: ChannelState) -> float:
        """Eq. (6)."""
        r = self.downlink_rate(m, j, st)
        return np.inf if r <= 0 else gamma * 8.0 / r

    def uplink_energy(self, m: int, j: int, p: float, gamma: float, st: ChannelState) -> float:
        """Eq. (8)."""
        return p * self.uplink_time(m, j, p, gamma, st)


def draw_state_jax(key, path, n_channels: int, n_devices: int, *,
                   e_dev_max, e_gw_max, i_up_var, i_down_var):
    """``Network.draw`` with ``jax.random``: same distributions (exponential
    fading on the path-loss factor, folded-normal interference, uniform
    energy arrivals), traced so a scheduling round can consume the draw
    without leaving device memory. ``path`` is the (M,) per-gateway
    path-loss factor ``h0 * (d0 / dist)^nu``. Returns a
    :class:`ChannelStateT` (h_up, h_down, i_up, i_down, e_dev, e_gw).

    The stream differs from the numpy generator's, so this is for fully
    fused sweeps (e.g. the vmapped V sweep), not oracle-parity runs.
    """
    import jax
    import jax.numpy as jnp

    m_gw = path.shape[0]
    k = jax.random.split(key, 6)
    shape = (m_gw, n_channels)
    h_up = path[:, None] * jax.random.exponential(k[0], shape)
    h_down = path[:, None] * jax.random.exponential(k[1], shape)
    i_up = jnp.abs(jax.random.normal(k[2], shape) * jnp.sqrt(i_up_var))
    i_down = jnp.abs(jax.random.normal(k[3], shape) * jnp.sqrt(i_down_var))
    e_dev = jax.random.uniform(k[4], (n_devices,)) * e_dev_max
    e_gw = jax.random.uniform(k[5], (m_gw,)) * e_gw_max
    return ChannelStateT(h_up, h_down, i_up, i_down, e_dev, e_gw)
