"""Lyapunov virtual queues and drift-plus-penalty (paper Sec. V-A).

``update_queues`` is the host-side (numpy) update used by the oracle
scheduler; ``update_queues_jax`` is its traced twin, used inside the
jitted DDSRA round (``repro.core.ddsra_jax``) so the queue recursion can
stay device-resident across a whole ``lax.scan``-ed run.

Queue contract for the fused simulation loop (``repro.fl.fused_sim``): the
(M,) float64 queue vector is the *only* state threaded between scheduling
rounds, carried as the ``queues`` leaf of the pytree-typed decision
(``repro.core.ddsra_jax.RoundDecisionT``). Both updates implement the same
Eq. (14) recursion, so a ``lax.scan`` over :func:`update_queues_jax` is
bit-identical (on the same backend) to the stepwise numpy loop — the
cross-engine parity matrix in ``tests/test_fused_sim.py`` pins this.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def update_queues(q: np.ndarray, selected: np.ndarray, gamma: np.ndarray) -> np.ndarray:
    """Eq. (14): Q_m(t+1) = max(Q_m(t) - 1_m^t + Gamma_m, 0)."""
    return np.maximum(q - selected.astype(float) + gamma, 0.0)


def update_queues_jax(q, selected, gamma):
    """Traced Eq. (14) (``selected`` may be bool; promoted like the oracle)."""
    return jnp.maximum(q - selected.astype(q.dtype) + gamma, 0.0)


def update_queues_realized(q: np.ndarray, realized: np.ndarray,
                           gamma: np.ndarray) -> np.ndarray:
    """Eq. (14) driven by *realized* (not scheduled) participation.

    Under asynchronous execution the scheduled indicator ``1_m^t`` and what
    actually happened diverge: a selected gateway whose update churned or
    was lost mid-round earned no queue relief, and a straggler's late
    update earns its relief in the round it actually *lands* at the server
    (which may be rounds after it was scheduled, and in a round where the
    gateway was not selected at all). Feeding this realized indicator into
    the queue recursion is how DDSRA reacts to churn: an unreliable
    gateway's virtual queue keeps growing past its scheduled credit, so the
    drift term re-prioritizes it. The arithmetic is identical to
    :func:`update_queues` — the contract here is *which* indicator feeds
    it (``repro.fl.async_engine`` supplies it per round).
    """
    return update_queues(q, np.asarray(realized, dtype=float), gamma)


def drift_plus_penalty(v: float, tau: float, q: np.ndarray,
                       selected: np.ndarray) -> float:
    """Objective of P2 (Eq. 17): V*tau - sum_m Q_m * 1_m."""
    return v * tau - float(np.sum(q * selected))


def queue_stability_gap(history: np.ndarray, gamma: np.ndarray) -> np.ndarray:
    """Empirical participation-rate shortfall after T rounds.

    history: (T, M) 0/1 selections. Returns Gamma_m - (1/T) sum_t 1_m^t
    (positive = constraint C11 violated so far).
    """
    rate = history.mean(axis=0)
    return gamma - rate
