"""Traced fixed-resource baselines: the whole decide trajectory as one scan.

``round_robin`` and ``random`` fix every resource (the Sec. VII-C baseline
contract: partition point ``l = round(0.5 L)``, even gateway-frequency
split, ``p_max`` transmit power) — their per-round work is just the
feasibility check + delay evaluation of
``repro.core.schedulers._fixed_resource_solution`` at the chosen gateways.
That makes the decide trajectory trivially traceable: gateway choice is
data (round-robin's is a closed form of ``t``; random's is pre-drawn
host-side from the policy RNG, preserving the stepwise stream), and the
evaluation reuses the link/cost algebra of ``repro.core.ddsra_jax`` over
the same padded :class:`~repro.core.ddsra_jax._Statics`.

:class:`BaselinePlan` is the baselines' twin of
:class:`~repro.core.ddsra_jax.DDSRAPlan`: built once per (Workload,
Network) pair, its :meth:`~BaselinePlan.decide_scan` runs all rounds as a
single jitted x64 ``lax.scan`` and returns the stacked resolved
:class:`~repro.core.ddsra_jax.RoundDecisionT` the fused simulation loop
consumes — so baseline sweeps fuse end-to-end instead of paying a
host decide loop per round.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental import enable_x64

from repro.core.ddsra import Workload
from repro.core.ddsra_jax import (DDSRAPlan, RoundDecisionT, _downlink_time,
                                  _Statics, _uplink_energy, _uplink_time)
from repro.core.lyapunov import update_queues_jax
from repro.core.network import ChannelStateT, Network

# incremented per decide-scan trace (compile-count tests read this)
TRACE_COUNTS = {"decide": 0}


def _solve_fixed(s: _Statics, st: ChannelStateT, l0: int, m, j):
    """Feasibility + delay of gateway ``m`` on channel ``j`` at the fixed
    baseline operating point — the traced twin of
    ``repro.core.schedulers._fixed_resource_solution``. Returns (ok, lam)."""
    c = s.cfg
    cumf, cumg = s.cumf, s.cumg
    tot_f, tot_g = cumf[-1], cumg[-1]
    kd, f_dev, valid = s.kd[m], s.f_dev[m], s.valid[m]
    n_loc = s.n_loc[m]
    f_gw = c.f_gw_max / jnp.maximum(n_loc, 1.0)
    e_dev = kd * c.v_dev / c.phi_dev * cumf[l0] * f_dev ** 2
    e_tra = jnp.sum(jnp.where(
        valid, kd * c.v_gw / c.phi_gw * (tot_f - cumf[l0]) * f_gw ** 2,
        0.0))
    h_up, i_up = st.h_up[m, j], st.i_up[m, j]
    e_up = _uplink_energy(c, c.p_max, h_up, i_up, s.gamma)
    e_state = jnp.where(valid, st.e_dev[s.dev_idx[m]], jnp.inf)
    ok = ((cumg[l0] <= c.g_dev_max)
          & (jnp.sum(jnp.where(valid, tot_g - cumg[l0], 0.0))
             <= c.g_gw_max)
          & jnp.all(jnp.where(valid, e_dev <= e_state, True))
          & ((e_tra + e_up) <= st.e_gw[m]))
    top = tot_f - cumf[l0]
    t_dev = cumf[l0] / (c.phi_dev * f_dev)
    t_gw = jnp.where(top > 0,
                     top / jnp.maximum(c.phi_gw * f_gw, 1e-9), 0.0)
    t_train = jnp.max(jnp.where(valid, kd * (t_dev + t_gw), -jnp.inf))
    lam = (t_train + _uplink_time(c, c.p_max, h_up, i_up, s.gamma)
           + _downlink_time(c, st.h_down[m, j], st.i_down[m, j],
                            s.gamma))
    return ok, lam


def _delay_chosen(s: _Statics, st: ChannelStateT, *, l0: int):
    """The delay-driven greedy pick, traced: evaluate every gateway on every
    channel at fixed resources, take each gateway's best-channel delay and
    choose the ``J`` smallest — the jnp twin of
    ``DelayDrivenScheduler.schedule``'s host argsort (jnp's stable argsort
    matches numpy's introselect whenever delays are distinct, which random
    channel draws make almost sure)."""
    m_gw, j_ch = st.h_up.shape

    def best_delay(m):
        _, lam = jax.vmap(lambda j: _solve_fixed(s, st, l0, m, j))(
            jnp.arange(j_ch))
        return jnp.min(lam)

    delays = jax.vmap(best_delay)(jnp.arange(m_gw))       # (M,)
    return jnp.argsort(delays)[:j_ch]


def _baseline_round(s: _Statics, st: ChannelStateT, queues, gamma_rates,
                    chosen, *, l0: int, n_devices: int) -> RoundDecisionT:
    """One fixed-resource baseline round, traced.

    The jnp twin of ``_fixed_resource_solution`` + ``_decision_for`` +
    ``resolve_decision``: evaluate each chosen gateway at the fixed
    ``(l0, f_gw_max/n_loc, p_max)`` operating point, fail infeasible
    selections, scatter the trained gateways' cut into the dense per-device
    vector and run the Eq. (14) queue update.
    """
    m_gw = s.kd.shape[0]
    j_idx = jnp.arange(chosen.shape[0])
    ok_j, lam_j = jax.vmap(
        lambda m, j: _solve_fixed(s, st, l0, m, j))(chosen, j_idx)    # (J,)

    selected = jnp.zeros(m_gw, bool).at[chosen].set(True)
    feas_m = jnp.zeros(m_gw, bool).at[chosen].set(ok_j)
    lam_m = jnp.full(m_gw, jnp.inf).at[chosen].set(lam_j)
    trained = selected & feas_m & jnp.isfinite(lam_m)
    failures = jnp.sum(selected & ~trained)
    gw_delay = jnp.where(trained, lam_m, 0.0)
    delay = jnp.where(trained.any(),
                      jnp.max(jnp.where(trained, lam_m, -jnp.inf)), 0.0)
    # the scheduler-reported tau includes infeasible selections' (finite)
    # delays — _decision_for's max over the assigned lanes
    tau = jnp.max(lam_j)
    vals = jnp.where(s.valid & trained[:, None], jnp.int32(l0), 0)
    l_dev = jnp.zeros((n_devices,), jnp.int32).at[
        s.dev_idx.ravel()].add(vals.ravel())
    new_q = update_queues_jax(queues, selected, gamma_rates)
    return RoundDecisionT(selected=selected, trained=trained, l_dev=l_dev,
                          gw_delay=gw_delay, delay=delay, tau=tau,
                          failures=failures, queues=new_q)


@functools.partial(jax.jit, static_argnames=("l0", "n_devices"))
def _decide_scan(s: _Statics, states: ChannelStateT, queues, gamma_rates,
                 chosen, *, l0: int, n_devices: int) -> RoundDecisionT:
    TRACE_COUNTS["decide"] += 1

    def step(q, xs):
        st, ch = xs
        dec = _baseline_round(s, st, q, gamma_rates, ch,
                              l0=l0, n_devices=n_devices)
        return dec.queues, dec

    _, decisions = lax.scan(step, queues, (states, chosen))
    return decisions


@functools.partial(jax.jit, static_argnames=("l0", "n_devices"))
def _decide_scan_delay(s: _Statics, states: ChannelStateT, queues,
                       gamma_rates, *, l0: int,
                       n_devices: int) -> RoundDecisionT:
    """Delay-driven decide trajectory: the greedy pick is computed in-scan
    from the round's channel draws instead of arriving as data."""
    TRACE_COUNTS["decide"] += 1

    def step(q, st):
        ch = _delay_chosen(s, st, l0=l0)
        dec = _baseline_round(s, st, q, gamma_rates, ch,
                              l0=l0, n_devices=n_devices)
        return dec.queues, dec

    _, decisions = lax.scan(step, queues, states)
    return decisions


@dataclasses.dataclass
class BaselinePlan:
    """Compiled fixed-resource baseline control plane for one
    (Workload, Network) pair — the baselines' :class:`DDSRAPlan` twin.

    Gateway choice is *data* (the ``chosen`` round axis), so one plan
    serves every choice rule: round-robin feeds its closed-form schedule,
    random feeds host-drawn picks from the policy RNG.
    """
    statics: _Statics
    n_devices: int
    n_gateways: int
    n_channels: int
    l0: int                 # the baselines' fixed cut round(0.5 * L)

    @classmethod
    def build(cls, w: Workload, net: Network,
              l_frac: float = 0.5) -> "BaselinePlan":
        d = DDSRAPlan.build(w, net)
        return cls(d.statics, d.n_devices, d.n_gateways, d.n_channels,
                   int(round(l_frac * w.n_layers)))

    def decide_scan(self, states: ChannelStateT, queues, gamma_rates, v, *,
                    chosen=None) -> RoundDecisionT:
        """All rounds' decisions as one compiled x64 program.

        ``chosen`` is the (rounds, J) int array of gateway picks (the only
        thing distinguishing the data-driven baseline policies: round-robin
        feeds its closed form, random its pre-drawn stream). ``chosen=None``
        selects the delay-driven rule, whose greedy pick is a function of
        the round's channel draws and is computed inside the scan. ``v`` is
        accepted for interface parity with :meth:`DDSRAPlan.decide_scan`
        but ignored — fixed-resource baselines have no Lyapunov trade-off.
        """
        del v
        with enable_x64():
            states = jax.tree.map(
                lambda a: jnp.asarray(np.asarray(a, np.float64)), states)
            queues = jnp.asarray(np.asarray(queues, np.float64))
            gamma_rates = jnp.asarray(np.asarray(gamma_rates, np.float64))
            if chosen is None:
                return _decide_scan_delay(
                    self.statics, states, queues, gamma_rates,
                    l0=self.l0, n_devices=self.n_devices)
            return _decide_scan(
                self.statics, states, queues, gamma_rates,
                jnp.asarray(np.asarray(chosen, np.int32)),
                l0=self.l0, n_devices=self.n_devices)
