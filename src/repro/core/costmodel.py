"""Layer-level memory-usage and FLOPs model (paper Table II + extensions).

The paper derives universal per-layer formulas for conv / pooling / fully-
connected layers from the backpropagation algorithm. ``o_l`` / ``o_l'`` are
the forward / backward FLOPs *per sample point*; ``g_l`` is the memory for
weights + forward outputs + backward errors (+ gradients) at training batch
size ``B_s``.

We keep the paper's formulas verbatim for its own VGG-11 experiment and add
entries for the layer types of the assigned architecture pool (attention,
SSM/SSD, dense & MoE FFN, norm, embedding), so the same partition machinery
(`repro.core.partition`) covers every arch.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class LayerCost:
    name: str
    kind: str
    flops_fwd: float          # o_l, per sample point
    flops_bwd: float          # o'_l, per sample point
    mem_weights: float        # bytes (incl. gradient buffers where Table II says so)
    mem_act_per_sample: float # bytes per sample (fwd outputs + bwd errors)
    sf: float = 4.0           # native precision, bytes/param (S_f in Table II)

    def flops(self) -> float:
        return self.flops_fwd + self.flops_bwd

    def mem(self, batch: int) -> float:
        return self.mem_weights + batch * self.mem_act_per_sample


# ---------------------------------------------------------------------------
# Table II entries (verbatim). S_f = precision bytes.
# ---------------------------------------------------------------------------


def conv_layer(name: str, ci: int, hi: int, wi: int, co: int,
               hf: int = 3, wf: int = 3, stride: int = 1, pad: int = 1,
               sf: int = 4) -> LayerCost:
    ho = (hi + 2 * pad - hf) // stride + 1
    wo = (wi + 2 * pad - wf) // stride + 1
    fwd = 2 * ci * hf * wf * co * ho * wo                       # B_s = 1
    err = 2 * (2 * wf + wf * wo - 2) * (2 * hf + hf * ho - 2)
    grad = 2 * ci * hf * wf * co * ho * wo
    weights = sf * ci * hf * wf * co
    acts = sf * (co * ho * wo + ci * hi * wi)                   # fwd out + bwd err
    return LayerCost(name, "conv", fwd, err + grad,
                     2 * weights,                               # weight + gradient
                     acts, sf=sf)


def pool_layer(name: str, ci: int, hi: int, wi: int, k: int = 2,
               sf: int = 4) -> LayerCost:
    ho, wo = hi // k, wi // k
    fwd = ci * hi * wi
    err = ci * hi * wi
    acts = sf * (ci * ho * wo + ci * hi * wi)
    return LayerCost(name, "pool", fwd, err, 0.0, acts, sf=sf)


def fc_layer(name: str, si: int, so: int, sf: int = 4) -> LayerCost:
    fwd = 2 * si * so
    bwd = 2 * si * so + si * so                                 # error + gradient
    weights = sf * si * so
    acts = sf * (so + si)
    return LayerCost(name, "fc", fwd, bwd, 2 * weights, acts, sf=sf)


# ---------------------------------------------------------------------------
# VGG-11 (the paper's experiment DNN), 32x32x3 inputs (SVHN / CIFAR-10)
# ---------------------------------------------------------------------------

VGG11_PLAN = [64, "M", 128, "M", 256, 256, "M", 512, 512, "M", 512, 512, "M"]


def vgg11_layers(width_mult: float = 1.0, sf: int = 4,
                 image: int = 32, classes: int = 10) -> List[LayerCost]:
    layers: List[LayerCost] = []
    ci, hw = 3, image
    idx = 0
    for item in VGG11_PLAN:
        if item == "M":
            layers.append(pool_layer(f"pool{idx}", ci, hw, hw, sf=sf))
            hw //= 2
        else:
            co = max(1, int(item * width_mult))
            layers.append(conv_layer(f"conv{idx}", ci, hw, hw, co, sf=sf))
            ci = co
            idx += 1
    feat = ci * hw * hw
    fc1 = max(16, int(4096 * width_mult))
    layers.append(fc_layer("fc0", feat, fc1, sf=sf))
    layers.append(fc_layer("fc1", fc1, fc1, sf=sf))
    layers.append(fc_layer("fc2", fc1, classes, sf=sf))
    return layers


# ---------------------------------------------------------------------------
# Extensions: per-layer costs for the assigned architecture pool
# (per token; sf bytes per element; seq enters attention's O(S) term)
# ---------------------------------------------------------------------------


def attention_layer(name: str, cfg: ArchConfig, seq: int, sf: int = 2) -> LayerCost:
    d, hd, nh, kv = cfg.d_model, cfg.hd, cfg.n_heads, cfg.n_kv_heads
    proj = 2 * d * (nh * hd) + 2 * 2 * d * (kv * hd) + 2 * (nh * hd) * d
    scores = 2 * nh * hd * seq + 2 * nh * seq * hd             # QK^T + AV per token
    fwd = proj + scores
    weights = sf * (d * nh * hd + 2 * d * kv * hd + nh * hd * d)
    acts = sf * (4 * nh * hd + 2 * d)
    return LayerCost(name, "attention", fwd, 2 * fwd, 2 * weights, acts,
                     sf=sf)


def ffn_layer(name: str, cfg: ArchConfig, sf: int = 2) -> LayerCost:
    d, f = cfg.d_model, cfg.d_ff
    if cfg.moe is not None:
        k, e = cfg.moe.top_k, cfg.moe.n_experts
        fwd = 2 * d * e + k * 3 * 2 * d * f                    # router + top-k experts
        weights = sf * (d * e + e * 3 * d * f)                 # ALL experts resident
        acts = sf * (k * (2 * f + d))
        return LayerCost(name, "moe_ffn", fwd, 2 * fwd, 2 * weights, acts,
                         sf=sf)
    fwd = 3 * 2 * d * f
    weights = sf * 3 * d * f
    acts = sf * (2 * f + d)
    return LayerCost(name, "ffn", fwd, 2 * fwd, 2 * weights, acts, sf=sf)


def ssm_layer(name: str, cfg: ArchConfig, sf: int = 2) -> LayerCost:
    s = cfg.ssm
    d = cfg.d_model
    d_in, n, p, ds = s.d_inner(d), s.n_heads(d), s.head_dim, s.d_state
    q = s.chunk_size
    proj = 2 * d * (2 * d_in + 2 * ds + n) + 2 * d_in * d
    conv = 2 * s.d_conv * (d_in + 2 * ds)
    # SSD per token: intra-chunk quadratic (O(q)) + state update (O(ds*p))
    ssd = 2 * q * ds + 2 * q * n * p + 4 * n * p * ds
    fwd = proj + conv + ssd
    weights = sf * (d * (2 * d_in + 2 * ds + n) + d_in * d
                    + s.d_conv * (d_in + 2 * ds))
    acts = sf * (4 * d_in + 4 * ds + 2 * n)
    return LayerCost(name, "ssm", fwd, 2 * fwd, 2 * weights, acts, sf=sf)


def arch_layers(cfg: ArchConfig, seq: int, sf: int = 2) -> List[LayerCost]:
    """Per-layer cost vector for an assigned architecture (decoder stack)."""
    out: List[LayerCost] = []
    emb = LayerCost("embed", "embed", 2 * cfg.d_model, 2 * cfg.d_model,
                    sf * cfg.vocab * cfg.d_model, sf * cfg.d_model, sf=sf)
    out.append(emb)
    for i in range(cfg.enc_layers):
        out.append(attention_layer(f"enc{i}.attn", cfg, seq, sf))
    for i in range(cfg.n_layers):
        kind = cfg.kind(i)
        if kind == "A":
            out.append(attention_layer(f"l{i}.attn", cfg, seq, sf))
        else:
            out.append(ssm_layer(f"l{i}.ssm", cfg, sf))
        if cfg.d_ff:
            out.append(ffn_layer(f"l{i}.ffn", cfg, sf))
    head_w = 0 if cfg.tie_embeddings else sf * cfg.d_model * cfg.vocab
    out.append(LayerCost("unembed", "fc", 2 * cfg.d_model * cfg.vocab,
                         4 * cfg.d_model * cfg.vocab, 2 * head_w,
                         sf * cfg.vocab, sf=sf))
    return out


# ---------------------------------------------------------------------------
# aggregates used by the optimizer (paper Eqs. (1)-(5))
# ---------------------------------------------------------------------------


def flops_vector(layers: Sequence[LayerCost]) -> np.ndarray:
    """(o_l + o'_l) per layer."""
    return np.array([l.flops() for l in layers], float)


def mem_vector(layers: Sequence[LayerCost], batch: int) -> np.ndarray:
    """g_l per layer at training batch size."""
    return np.array([l.mem(batch) for l in layers], float)


def model_size_bytes(layers: Sequence[LayerCost]) -> float:
    """gamma: DNN model size transmitted between tiers (weights only)."""
    return float(sum(l.mem_weights / 2 for l in layers))  # /2: exclude grad buffer


def param_count(layers: Sequence[LayerCost]) -> float:
    """Transmitted parameter count: the weight bytes of each layer divided
    by its native precision ``sf`` (so mixed-precision stacks sum
    correctly). Uses the same weights-only convention as
    :func:`model_size_bytes`."""
    return float(sum(l.mem_weights / 2 / l.sf for l in layers))


def upload_bytes(layers: Sequence[LayerCost],
                 bits_per_param: Optional[float] = None) -> float:
    """gamma at a chosen upload compression level.

    ``bits_per_param=None`` prices the upload at each layer's native
    precision — exactly :func:`model_size_bytes`, the historical behavior.
    Otherwise every transmitted parameter costs ``bits_per_param/8`` bytes
    (e.g. 16 for a bf16 data plane, 8 for int8-quantized uploads), which
    scales the DDSRA uplink/downlink delay and transmit-energy terms
    linearly since they are all linear in gamma.
    """
    if bits_per_param is None:
        return model_size_bytes(layers)
    if bits_per_param <= 0:
        raise ValueError(f"bits_per_param must be positive, "
                         f"got {bits_per_param}")
    return param_count(layers) * float(bits_per_param) / 8.0


def train_time_split(flops: np.ndarray, l_split: int, k_iters: int, d_batch: int,
                     phi_dev: float, f_dev: float,
                     phi_gw: float, f_gw: float) -> float:
    """Eq. (1) inner term: bottom l_split layers on device, rest on gateway."""
    bottom = flops[:l_split].sum()
    top = flops[l_split:].sum()
    return k_iters * d_batch * (bottom / (phi_dev * f_dev) + top / (phi_gw * f_gw))


def train_energy_device(flops: np.ndarray, l_split: int, k_iters: int,
                        d_batch: int, v_eff: float, phi: float, f: float) -> float:
    """Eq. (2)."""
    return k_iters * d_batch * v_eff / phi * flops[:l_split].sum() * f ** 2


def train_energy_gateway(flops: np.ndarray, l_split: int, k_iters: int,
                         d_batch: int, v_eff: float, phi: float, f: float) -> float:
    """Eq. (3)."""
    return k_iters * d_batch * v_eff / phi * flops[l_split:].sum() * f ** 2
