"""Device-scheduling policies: DDSRA + the paper's four baselines.

All schedulers share one interface: ``schedule(ctx) -> RoundDecision`` where
ctx carries the drawn channel state, queues and feedback (losses). Baselines
fix the partition point, transmit power and frequency split ("the baseline
schemes fix the transmit power, computation frequency and the DNN partition
point", Sec. VII-C); a baseline round *fails* for a gateway whose fixed
resources violate the energy/memory constraints.

Two class-level flags tell the fused simulation loop
(``repro.fl.fused_sim``) what a policy can do:

* ``traced_decide`` — the policy's whole decide trajectory can run as one
  compiled ``lax.scan`` (``ddsra_jax`` and, via
  ``repro.core.baseline_jax``, the fixed-resource ``round_robin`` /
  ``random`` / ``delay_driven`` baselines); other policies decide via a
  host loop in the fused path, which is still exact.
* ``reads_losses`` — the policy's decisions depend on training feedback
  (``ctx.losses``), so decide and train cannot be phase-separated; the
  fused path refuses such policies (only ``loss_driven``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple, Type

import numpy as np

from repro.core.ddsra import (GatewaySolution, RoundDecision, Workload, _cum,
                              _train_times, ddsra_round)
from repro.core.lyapunov import update_queues
from repro.core.network import ChannelState, Network


@dataclasses.dataclass
class RoundContext:
    t: int
    workload: Workload
    net: Network
    state: ChannelState
    queues: np.ndarray
    gamma_rates: np.ndarray        # participation-rate targets
    v: float
    losses: Optional[np.ndarray] = None   # (M,) last local losses
    # (M,) updates dispatched but not yet landed at the server, per gateway —
    # populated by the buffered async engine (None under synchronous
    # engines). Policies may use it to avoid double-dispatching a gateway
    # whose update is still in flight; the DDSRA family instead reacts to
    # churn through the queues, which the async round updates with
    # *realized* participation (lyapunov.update_queues_realized).
    inflight: Optional[np.ndarray] = None


def _fixed_resource_solution(ctx: RoundContext, m: int, j: int,
                             l_frac: float = 0.5) -> GatewaySolution:
    """Evaluate a gateway at FIXED resources (baselines)."""
    net, st, w = ctx.net, ctx.state, ctx.workload
    cfg = net.cfg
    devs = net.devices_of(m)
    n_loc = len(devs)
    big_l = w.n_layers
    l = np.full(n_loc, int(round(l_frac * big_l)), dtype=int)
    f_gw = np.full(n_loc, cfg.f_gw_max / max(n_loc, 1))
    p_tx = cfg.p_max

    cumf, cumg = _cum(w.flops), _cum(w.mem)
    tot_f, tot_g = cumf[-1], cumg[-1]
    e_dev = (w.k_iters * w.d_tilde[devs] * cfg.v_dev / cfg.phi_dev
             * cumf[l] * net.f_dev[devs] ** 2)
    e_tra_gw = float(np.sum(w.k_iters * w.d_tilde[devs] * cfg.v_gw / cfg.phi_gw
                            * (tot_f - cumf[l]) * f_gw ** 2))
    e_up = net.uplink_energy(m, j, p_tx, w.gamma, st)
    mem_dev_ok = (cumg[l] <= cfg.g_dev_max).all()
    mem_gw_ok = float(np.sum(tot_g - cumg[l])) <= cfg.g_gw_max
    ok = (mem_dev_ok and mem_gw_ok and (e_dev <= st.e_dev[devs]).all()
          and (e_tra_gw + e_up) <= st.e_gw[m])

    t_train = float(np.max(_train_times(w, devs, l, net.f_dev[devs],
                                        cfg.phi_dev, cfg.phi_gw, f_gw)))
    lam = (t_train + net.uplink_time(m, j, p_tx, w.gamma, st)
           + net.downlink_time(m, j, w.gamma, st))
    return GatewaySolution(bool(ok), lam, l, f_gw, p_tx, e_dev,
                           e_tra_gw + e_up)


def _decision_for(ctx: RoundContext, chosen: np.ndarray) -> RoundDecision:
    """Build a RoundDecision for baseline scheduler given chosen gateways."""
    net = ctx.net
    m_gw, j_ch = net.cfg.n_gateways, net.cfg.n_channels
    eye = np.zeros((m_gw, j_ch))
    lam = np.full((m_gw, j_ch), np.inf)
    sols: Dict = {}
    for j, m in enumerate(chosen[:j_ch]):
        sol = _fixed_resource_solution(ctx, int(m), j)
        sols[(int(m), j)] = sol
        lam[int(m), j] = sol.delay
        eye[int(m), j] = 1.0
    selected = eye.sum(axis=1) > 0
    tau = float(np.where(eye > 0, lam, -np.inf).max())
    new_q = update_queues(ctx.queues, selected, ctx.gamma_rates)
    return RoundDecision(eye, selected, lam, sols, tau, new_q)


# ---------------------------------------------------------------------------
# policy registry
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PolicySpec:
    """Registry entry: scheduler class + the constructor kwargs it accepts.

    ``kwargs`` names the simulation-provided values (e.g. ``seed``) threaded
    into the constructor by :func:`make_policy`, so stochastic policies get
    seeded uniformly instead of by name-matching at the call site.
    """
    name: str
    cls: Type
    kwargs: Tuple[str, ...] = ()


POLICIES: Dict[str, PolicySpec] = {}


def register_policy(name: str, *, kwargs: Sequence[str] = ()):
    """Class decorator registering a scheduling policy under ``name``.

    Registering a duplicate name raises — silent shadowing of a policy would
    corrupt every sweep that selects schedulers by name.
    """
    def deco(cls):
        if name in POLICIES:
            raise ValueError(f"policy {name!r} already registered "
                             f"(by {POLICIES[name].cls.__name__})")
        POLICIES[name] = PolicySpec(name, cls, tuple(kwargs))
        cls.name = name
        return cls
    return deco


def make_policy(name: str, **context: Any):
    """Instantiate policy ``name``, threading the registry-declared subset of
    ``context`` (e.g. ``seed=cfg.seed``) into its constructor."""
    if name not in POLICIES:
        raise KeyError(f"unknown policy {name!r}; known: {sorted(POLICIES)}")
    spec = POLICIES[name]
    return spec.cls(**{k: context[k] for k in spec.kwargs if k in context})


def policy_state(policy) -> Optional[dict]:
    """JSON-serializable internal state of a policy (None if stateless).

    Any policy carrying a ``numpy.random.Generator`` named ``rng`` is
    checkpointable by default; policies with richer state can override
    ``state_dict()`` / ``load_state_dict()``.
    """
    if hasattr(policy, "state_dict"):
        return policy.state_dict()
    rng = getattr(policy, "rng", None)
    if isinstance(rng, np.random.Generator):
        return {"rng": rng.bit_generator.state}
    return None


def set_policy_state(policy, state: Optional[dict]) -> None:
    if state is None:
        return
    if hasattr(policy, "load_state_dict"):
        policy.load_state_dict(state)
        return
    if "rng" in state and isinstance(getattr(policy, "rng", None),
                                     np.random.Generator):
        policy.rng.bit_generator.state = state["rng"]


# ---------------------------------------------------------------------------
# policies
# ---------------------------------------------------------------------------


@register_policy("ddsra")
class DDSRAScheduler:
    """The paper's Algorithm 1, host-side numpy (the parity oracle)."""

    def schedule(self, ctx: RoundContext) -> RoundDecision:
        return ddsra_round(ctx.workload, ctx.net, ctx.state, ctx.queues,
                           ctx.gamma_rates, ctx.v)


@register_policy("ddsra_jax")
class DDSRAJaxScheduler:
    """Algorithm 1 as one jitted x64 XLA program per round.

    Vectorizes the per-(m, j) solves with ``vmap``, the bisections with
    fixed-trip ``lax.scan`` and the lambda-cap assignment sweep with the
    jittable Hungarian (see ``repro.core.ddsra_jax``). Emits the same
    :class:`RoundDecision` as ``"ddsra"`` — identical assignments, Lambda
    and tau to ~1e-6 — while compiling exactly once per network shape.
    """

    # the decide trajectory is traceable end-to-end: the fused simulation
    # loop scans DDSRAPlan's round instead of calling schedule() per round.
    traced_decide = True

    def __init__(self):
        self._plans: Dict[int, Tuple[Any, Any, Any]] = {}

    def plan_for(self, workload, net):
        """One DDSRAPlan per (net, workload) pair, keyed by identity (both
        are built once per Simulation and reused across rounds). The fused
        loop calls this directly to reach ``decide_scan``/``sweep_states``."""
        from repro.core.ddsra_jax import DDSRAPlan
        key = (id(net), id(workload))
        hit = self._plans.get(key)
        if hit is None or hit[0] is not net or hit[1] is not workload:
            self._plans[key] = (net, workload,
                                DDSRAPlan.build(workload, net))
        return self._plans[key][2]

    def schedule(self, ctx: RoundContext) -> RoundDecision:
        return self.plan_for(ctx.workload, ctx.net).round(
            ctx.state, ctx.queues, ctx.gamma_rates, ctx.v)


class _TracedBaseline:
    """Mixin: fused-decide support for the fixed-resource baselines.

    A baseline round at fixed resources is pure data — the gateway picks —
    plus the feasibility/delay evaluation ``repro.core.baseline_jax``
    traces, so the fused simulation loop can scan the whole decide
    trajectory in one compiled program. Subclasses supply the picks via
    :meth:`traced_chosen`; the fused loop feeds them to
    :meth:`BaselinePlan.decide_scan` as the scan's round axis.
    """

    traced_decide = True

    def plan_for(self, workload, net):
        """One BaselinePlan per (net, workload) pair, keyed by identity
        (the DDSRAJaxScheduler caching contract)."""
        from repro.core.baseline_jax import BaselinePlan
        cache = getattr(self, "_plans", None)
        if cache is None:
            cache = self._plans = {}
        key = (id(net), id(workload))
        hit = cache.get(key)
        if hit is None or hit[0] is not net or hit[1] is not workload:
            cache[key] = (net, workload, BaselinePlan.build(workload, net))
        return cache[key][2]

    def traced_chosen(self, t0: int, rounds: int, net: Network) -> np.ndarray:
        """(rounds, J) gateway picks for rounds ``t0 .. t0+rounds-1``."""
        raise NotImplementedError


@register_policy("random", kwargs=("seed",))
class RandomScheduler(_TracedBaseline):
    """Random Scheduling [26]: uniform J gateways per round."""

    def __init__(self, seed: int = 0):
        self.rng = np.random.default_rng(seed)

    def schedule(self, ctx: RoundContext) -> RoundDecision:
        m, j = ctx.net.cfg.n_gateways, ctx.net.cfg.n_channels
        chosen = self.rng.choice(m, size=j, replace=False)
        return _decision_for(ctx, chosen)

    def traced_chosen(self, t0: int, rounds: int, net: Network) -> np.ndarray:
        """Pre-draw every round's picks from the policy RNG — one
        ``rng.choice`` per round, exactly the stepwise draws, so the
        policy RNG state after a fused block matches stepwise."""
        m, j = net.cfg.n_gateways, net.cfg.n_channels
        return np.stack([self.rng.choice(m, size=j, replace=False)
                         for _ in range(rounds)])


@register_policy("round_robin")
class RoundRobinScheduler(_TracedBaseline):
    """Round Robin [26]: consecutive groups of J gateways."""

    def schedule(self, ctx: RoundContext) -> RoundDecision:
        m, j = ctx.net.cfg.n_gateways, ctx.net.cfg.n_channels
        start = (ctx.t * j) % m
        chosen = (start + np.arange(j)) % m
        return _decision_for(ctx, chosen)

    def traced_chosen(self, t0: int, rounds: int, net: Network) -> np.ndarray:
        m, j = net.cfg.n_gateways, net.cfg.n_channels
        starts = (np.arange(t0, t0 + rounds) * j) % m
        return (starts[:, None] + np.arange(j)[None, :]) % m


@register_policy("loss_driven")
class LossDrivenScheduler:
    """Select the J gateways with the largest recent local loss."""

    # decisions depend on training feedback: decide/train cannot be
    # phase-separated, so the fused simulation loop refuses this policy.
    reads_losses = True

    def schedule(self, ctx: RoundContext) -> RoundDecision:
        m, j = ctx.net.cfg.n_gateways, ctx.net.cfg.n_channels
        losses = ctx.losses if ctx.losses is not None else np.zeros(m)
        chosen = np.argsort(-losses)[:j]
        return _decision_for(ctx, chosen)


@register_policy("delay_driven")
class DelayDrivenScheduler(_TracedBaseline):
    """Select the J gateways with the smallest fixed-resource delay."""

    def schedule(self, ctx: RoundContext) -> RoundDecision:
        m, j = ctx.net.cfg.n_gateways, ctx.net.cfg.n_channels
        # evaluate each gateway on its best channel at fixed resources
        delays = np.array([
            min(_fixed_resource_solution(ctx, mm, jj).delay for jj in range(j))
            for mm in range(m)])
        chosen = np.argsort(delays)[:j]
        return _decision_for(ctx, chosen)

    def traced_chosen(self, t0: int, rounds: int, net: Network) -> None:
        """The greedy pick is a *function of the round's channel draws*, not
        pre-computable data — returning None tells the fused loop to let
        ``BaselinePlan.decide_scan`` compute it inside the scan
        (``repro.core.baseline_jax._delay_chosen``)."""
        return None


# legacy name -> class view of the registry (prefer make_policy / POLICIES)
SCHEDULERS = {name: spec.cls for name, spec in POLICIES.items()}
