"""One-program multi-policy sweeps: policies x seeds x V x rounds.

``repro.core.ddsra_jax._sweep_scan`` fuses a seeds x V DDSRA sweep into one
XLA program, but the paper's headline figures (Figs. 4-6) compare DDSRA
against the fixed-resource baselines — which PR 8 still swept one compiled
program *per policy*. This module folds the policy axis in: every
registered traced-decide rule becomes a numbered branch of one
``lax.switch``, and the whole grid runs as

    vmap(policies) o vmap(seeds) o vmap(V) o lax.scan(rounds)

All three branches read the same padded :class:`~repro.core.ddsra_jax._Statics`
(:meth:`~repro.core.baseline_jax.BaselinePlan.build` already reuses
``DDSRAPlan``'s), so one statics pytree serves the whole grid:

* kind 0 — ``ddsra_jax``: the full Algorithm 1 round solve
  (:func:`repro.core.ddsra_jax._round`);
* kind 1 — fixed-chosen baselines (``round_robin``, ``random``): gateway
  picks are *data* fed down the scan's round axis (round-robin's closed
  form, random's pre-drawn per-seed policy-RNG stream), evaluated by
  :func:`repro.core.baseline_jax._baseline_round`;
* kind 2 — ``delay_driven``: the greedy pick is a function of the round's
  channel draws, computed in-scan by
  :func:`repro.core.baseline_jax._delay_chosen`.

The policy axis is unrolled at *trace* time (``kinds`` is a static tuple)
rather than dispatched through a runtime one-hot ``lax.switch``: under
``vmap`` a switch lowers to computing every branch for every lane and
masking — P x the control-plane work — while the unrolled form stays ONE
compiled program (one ``jit`` entry, the per-policy grids stacked inside)
in which each lane computes only its own branch. One compile per distinct
policy tuple; re-running with different seeds/V/queues never retraces.
Baseline lanes ignore V (no Lyapunov trade-off), so their rows repeat
across the V axis — the flat curves of Figs. 4-6.

Row (p, s, v) is pinned bit-identical (queues, selection) to a stepwise
``reset(seeds[s])`` run of policy ``policies[p]`` at ``v_values[v]``
(``tests/test_fused_sim.py``), and the cross-process digest test freezes
the whole grid.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental import enable_x64

from repro.core.baseline_jax import _baseline_round, _delay_chosen
from repro.core.ddsra_jax import (RoundContextT, _round,
                                  resolve_decision_arrays, _Statics)
from repro.core.network import ChannelStateT

# policy name -> switch branch index. Only traced-decide policies can ride
# the fused sweep; host-loop rules (``ddsra`` oracle, ``loss_driven``) are
# refused by Simulation.sweep with a pointer to Simulation.rounds().
POLICY_KINDS = {"ddsra_jax": 0, "round_robin": 1, "random": 1,
                "delay_driven": 2}

# incremented per sweep trace (compile-count tests read this): one compile
# per (topology, P, S, V, T) shape, never per policy.
TRACE_COUNTS = {"sweep": 0}


@functools.partial(jax.jit, static_argnames=("kinds", "l0", "n_devices"))
def _policy_sweep_scan(s: _Statics, states: ChannelStateT, queues0,
                       gamma_rates, chosen, v_values, *,
                       kinds: tuple, l0: int, n_devices: int):
    """The fused grid. ``states`` leaves carry (S, T, ...), ``kinds`` is a
    static tuple of branch indices (one per policy lane, unrolled at trace
    time), ``chosen`` (P, S, T, J) gateway picks (read only by kind-1
    lanes; zeros elsewhere). Returns (taus, selected, queues) with leading
    (P, S, V, T) axes."""
    TRACE_COUNTS["sweep"] += 1

    def policy_round(kind, q, st, ch, v):
        # every branch emits the *realized* round delay (max over trained
        # gateways, 0 when nobody trains) — the stepwise RoundRecord.delay
        # the parity test compares against. For ddsra the cap-sweep only
        # assigns feasible lanes so realized == scheduler tau; the
        # baselines can select infeasible gateways, where the two differ.
        if kind == 0:
            out = _round(s, st, RoundContextT(q, gamma_rates, v))
            dec = resolve_decision_arrays(s, out, n_devices)
            return dec.delay, out.selected, out.queues
        if kind == 2:
            ch = _delay_chosen(s, st, l0=l0)
        dec = _baseline_round(s, st, q, gamma_rates, ch,
                              l0=l0, n_devices=n_devices)
        return dec.delay, dec.selected, dec.queues

    def run_lane(kind, states_1, chosen_1, v):
        def step(q, xs):
            st, ch = xs
            tau, sel, new_q = policy_round(kind, q, st, ch, v)
            return new_q, (tau, sel, new_q)

        _, ys = lax.scan(step, queues0, (states_1, chosen_1))
        return ys

    def grid(kind, chosen_p):
        def over_v(states_1, chosen_1):
            return jax.vmap(lambda v: run_lane(kind, states_1, chosen_1,
                                               v))(v_values)
        return jax.vmap(over_v)(states, chosen_p)

    per_policy = [grid(kind, chosen[pi]) for pi, kind in enumerate(kinds)]
    return jax.tree.map(lambda *a: jnp.stack(a), *per_policy)


def sweep_policies(statics: _Statics, states: ChannelStateT, gamma_rates,
                   v_values, kinds, chosen, *, l0: int, n_devices: int,
                   n_gateways: int, queues=None):
    """Host entry: cast to the x64 control plane, run the fused grid and
    concretize. ``states`` leaves are (S, T, ...) host stacks; returns
    numpy (taus, selected, queues) shaped (P, S, V, T[, M])."""
    with enable_x64():
        states = jax.tree.map(
            lambda a: jnp.asarray(np.asarray(a, np.float64)), states)
        q0 = np.zeros(n_gateways) if queues is None else queues
        taus, sel, qs = _policy_sweep_scan(
            statics, states,
            jnp.asarray(np.asarray(q0, np.float64)),
            jnp.asarray(np.asarray(gamma_rates, np.float64)),
            jnp.asarray(np.asarray(chosen, np.int32)),
            jnp.asarray(np.asarray(v_values, np.float64)),
            kinds=tuple(int(k) for k in kinds),
            l0=l0, n_devices=n_devices)
        return np.asarray(taus), np.asarray(sel), np.asarray(qs)
