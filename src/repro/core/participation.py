"""Device-specific participation rate (paper Sec. IV).

Theorem 1 divergence bound:
    Phi_m = sum_n (a_mn D~_n / sum_n a_mn D~_n)
            * (sigma_n / (L_n sqrt(D~_n)) + delta_n / L_n)
            * ((beta L_n + 1)^K - 1)
Eq. (13):
    Gamma_m = min(J * (1/Phi_m) / sum_m (1/Phi_m), 1)
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass
class DataStats:
    """Per-device statistics estimated from the training process."""
    sigma: np.ndarray    # (N,) per-sample gradient variance bound
    delta: np.ndarray    # (N,) local-vs-global gradient divergence
    lipschitz: np.ndarray  # (N,) smoothness constants L_n
    d_tilde: np.ndarray  # (N,) training batch sizes


def divergence_bound(stats: DataStats, assign: np.ndarray,
                     beta: float, k_epochs: int) -> np.ndarray:
    """Phi_m per gateway. assign: (N,) device -> gateway index."""
    n = len(stats.sigma)
    m = int(assign.max()) + 1
    phi = np.zeros(m)
    for g in range(m):
        devs = np.where(assign == g)[0]
        w = stats.d_tilde[devs]
        w = w / w.sum()
        term = (stats.sigma[devs] / (stats.lipschitz[devs] * np.sqrt(stats.d_tilde[devs]))
                + stats.delta[devs] / stats.lipschitz[devs])
        growth = (beta * stats.lipschitz[devs] + 1.0) ** k_epochs - 1.0
        phi[g] = float(np.sum(w * term * growth))
    return phi


def participation_rates(phi: np.ndarray, n_channels: int) -> np.ndarray:
    """Eq. (13). Gateways with smaller divergence get larger Gamma_m."""
    inv = 1.0 / np.maximum(phi, 1e-12)
    gamma = n_channels * inv / inv.sum()
    return np.minimum(gamma, 1.0)


# ---------------------------------------------------------------------------
# online estimators (the paper "estimates by observing the model parameters")
# ---------------------------------------------------------------------------


def estimate_sigma(per_sample_grads: np.ndarray, mean_grad: np.ndarray) -> float:
    """Assumption 1: E || grad_i - grad_mean || <= sigma_n."""
    diffs = per_sample_grads - mean_grad[None]
    return float(np.mean(np.linalg.norm(diffs.reshape(len(diffs), -1), axis=1)))


def estimate_delta(local_grad: np.ndarray, global_grad: np.ndarray) -> float:
    """Assumption 2: || grad F_n - grad F || <= delta_n."""
    return float(np.linalg.norm(local_grad - global_grad))


def estimate_lipschitz(g1: np.ndarray, g2: np.ndarray,
                       w1: np.ndarray, w2: np.ndarray) -> float:
    """L_n >= ||∇F(w1) - ∇F(w2)|| / ||w1 - w2||."""
    dw = np.linalg.norm(w1 - w2)
    if dw < 1e-12:
        return 1.0
    return float(np.linalg.norm(g1 - g2) / dw)
