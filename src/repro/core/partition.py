"""Partition-point selection as a reusable primitive.

The paper's bisection over the DNN partition point (sub-problem 21) is
exposed here in a hardware-agnostic form: given a per-layer cost vector and
two tiers' capabilities, pick the cut minimizing the bottleneck tier time.
Used by

* the FL simulation (device/gateway tiers over WiFi), and
* the pod-axis pipeline split of the assigned architectures (tier-0 pod /
  tier-1 pod over ICI), where per-layer costs come from the TPU roofline
  terms of the compiled dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class Tier:
    """One side of the split."""
    throughput: float          # cost-units / s (e.g. FLOP/s * utilization)
    mem_capacity: float        # bytes
    energy_budget: float = np.inf
    energy_per_unit: float = 0.0


def split_time(costs: np.ndarray, l: int, bottom: Tier, top: Tier,
               boundary_bytes: np.ndarray, link_bw: float,
               objective: str = "serial") -> float:
    """Time if layers [0,l) run on `bottom` and [l,L) on `top`.

    objective='serial':     t_bottom + t_top + t_link — the paper's split
                            training (tiers alternate within an iteration).
    objective='bottleneck': max(t_bottom, t_top) + t_link — steady-state
                            pipeline throughput (GPipe over the pod axis).
    boundary_bytes[l] = activation+error traffic across a cut at l.
    """
    c = np.concatenate([[0.0], np.cumsum(costs)])
    t_b = c[l] / bottom.throughput
    t_t = (c[-1] - c[l]) / top.throughput
    t_link = boundary_bytes[l] / link_bw if link_bw > 0 else 0.0
    if objective == "bottleneck":
        return max(t_b, t_t) + t_link
    return t_b + t_t + t_link


def feasible_interval(mem: np.ndarray, bottom: Tier, top: Tier) -> Tuple[int, int]:
    """[lo, hi] cut positions satisfying both memory capacities."""
    g = np.concatenate([[0.0], np.cumsum(mem)])
    tot = g[-1]
    ok = np.where((g <= bottom.mem_capacity) & (tot - g <= top.mem_capacity))[0]
    if len(ok) == 0:
        return (1, 0)  # empty
    return int(ok.min()), int(ok.max())


def best_partition(costs: np.ndarray, mem: np.ndarray, bottom: Tier, top: Tier,
                   boundary_bytes: Optional[np.ndarray] = None,
                   link_bw: float = np.inf,
                   bisect_iters: int = 40,
                   objective: str = "serial") -> Optional[int]:
    """Bisection on the bottleneck time eta (paper's greedy for (21)).

    Returns the cut index l* in [0, L], or None if infeasible.
    The per-eta feasibility check mirrors the paper: compute the interval of
    cuts whose time <= eta, intersect with the memory interval, pick the
    largest (minimises top-tier load).
    """
    big_l = len(costs)
    if boundary_bytes is None:
        boundary_bytes = np.zeros(big_l + 1)
    lo_m, hi_m = feasible_interval(mem, bottom, top)
    if lo_m > hi_m:
        return None
    times = np.array([split_time(costs, l, bottom, top, boundary_bytes, link_bw,
                                 objective) for l in range(big_l + 1)])
    lo_eta, hi_eta = float(times.min()), float(times.max())
    eps = max(times.max(), 1e-300) * 1e-9          # relative tolerance

    def pick(eta: float) -> Optional[int]:
        ok = np.where((times <= eta + eps)
                      & (np.arange(big_l + 1) >= lo_m)
                      & (np.arange(big_l + 1) <= hi_m))[0]
        return int(ok.max()) if len(ok) else None

    best = pick(hi_eta)
    if best is None:
        return None
    for _ in range(bisect_iters):
        mid = 0.5 * (lo_eta + hi_eta)
        cand = pick(mid)
        if cand is not None:
            hi_eta, best = mid, cand
        else:
            lo_eta = mid
    return best


def brute_force_partition(costs: np.ndarray, mem: np.ndarray, bottom: Tier,
                          top: Tier, boundary_bytes: Optional[np.ndarray] = None,
                          link_bw: float = np.inf,
                          objective: str = "serial") -> Optional[int]:
    """Exact argmin, used by tests to validate the bisection."""
    big_l = len(costs)
    if boundary_bytes is None:
        boundary_bytes = np.zeros(big_l + 1)
    lo_m, hi_m = feasible_interval(mem, bottom, top)
    if lo_m > hi_m:
        return None
    ls = np.arange(lo_m, hi_m + 1)
    times = np.array([split_time(costs, l, bottom, top, boundary_bytes, link_bw,
                                 objective) for l in ls])
    # match the bisection's tie-break: largest l among minimal times
    best = times.min()
    eps = max(times.max(), 1e-300) * 1e-9
    return int(ls[np.where(times <= best + eps)[0].max()])
